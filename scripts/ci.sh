#!/usr/bin/env bash
# CI gate: tier-1 tests + the registry smoke suite + harness-perf floor.
#
#   scripts/ci.sh [LEDGER_PATH]
#
# Fails on: any pytest failure, any benchmark workload failure, a missing
# multi-axis scenario (mess_load_sweep / pointer_chase /
# spatter_nonuniform must run in smoke mode), or a process-wide
# translation-cache hit rate below 0.5 on the smoke suite (the
# parametric-ladder + staged-pipeline floor this repo maintains).
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
LEDGER="${1:-BENCH_PR3.json}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== benchmarks.run --smoke =="
python -m benchmarks.run --smoke --out "$LEDGER"

echo "== ledger gates ($LEDGER) =="
python - "$LEDGER" <<'EOF'
import json, sys

ledger = json.load(open(sys.argv[1]))
failures = ledger["failures"]
if failures:
    sys.exit(f"FAIL: benchmark workloads failed: {failures}")
seconds = ledger["module_seconds"]
missing = [s for s in ("mess_load_sweep", "pointer_chase",
                       "spatter_nonuniform") if s not in seconds]
if missing:
    sys.exit(f"FAIL: multi-axis scenarios did not run: {missing}")
tc = ledger["translation_cache"]
rate = tc["hit_rate"]
print(f"translation-cache hit rate: {rate:.3f} "
      f"(lower {tc['lower_hits']}/{tc['lower_hits']+tc['lower_misses']}, "
      f"compile {tc['compile_hits']}/{tc['compile_hits']+tc['compile_misses']}, "
      f"evictions {tc['evictions']}/{tc['capacity']}, "
      f"disk {tc['disk']})")
if rate < 0.5:
    sys.exit(f"FAIL: translation-cache hit rate {rate:.3f} < 0.5")
for scen in ("mess_load_sweep", "pointer_chase", "spatter_nonuniform"):
    print(f"{scen}: {seconds[scen]:.1f}s")
print("OK")
EOF
