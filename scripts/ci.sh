#!/usr/bin/env bash
# CI gate: tier-1 tests (fast lane first, slow lane after) + the registry
# smoke suite + harness-perf floors + docs drift.
#
#   scripts/ci.sh [LEDGER_PATH]
#
# Fails on: any pytest failure (the fast lane runs first so breakage is
# loud in seconds; the slow lane — registry-wide conformance and
# property sweeps — runs after), a docs-drift violation (every
# registered workload must appear in docs/PAPER_MAP.md), any benchmark
# workload failure, a missing multi-axis scenario (mess_load_sweep /
# pointer_chase / spatter_nonuniform / mess_calibrated must run in smoke
# mode), a process-wide translation-cache hit rate below 0.5 on the
# smoke suite, or a param_path probe violation: every strided-eligible
# probe ladder must run parametric with param_path == "strided" and
# exactly 1 compile miss, at a geometric-mean per-call cost <= 1.5x the
# specialized strided path (the regime-comparability floor this repo
# maintains — both sides donated, so the comparison is copy-free), with
# the 2D stencil ladder (jacobi2d_indep) additionally required to run
# rank-2 N-D windows.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
LEDGER="${1:-BENCH_PR5.json}"

echo "== tier-1 pytest (fast lane) =="
python -m pytest -x -q -m "not slow"

echo "== tier-1 pytest (slow lane: conformance + property sweeps) =="
python -m pytest -q -m slow

echo "== docs drift (docs/PAPER_MAP.md covers the registry) =="
python - <<'EOF2'
import pathlib, sys

from benchmarks.run import registered_names

doc = pathlib.Path("docs/PAPER_MAP.md")
if not doc.exists():
    sys.exit("FAIL: docs/PAPER_MAP.md is missing")
text = doc.read_text()
orphans = [n for n in registered_names() if f"`{n}`" not in text]
if orphans:
    sys.exit(
        "FAIL: registered workloads missing from docs/PAPER_MAP.md: "
        f"{orphans} — add a row per workload (name in backticks)"
    )
print(f"docs/PAPER_MAP.md covers all {len(registered_names())} workloads")
EOF2

echo "== benchmarks.run --smoke =="
python -m benchmarks.run --smoke --out "$LEDGER"

echo "== ledger gates ($LEDGER) =="
python - "$LEDGER" <<'EOF2'
import json, sys

ledger = json.load(open(sys.argv[1]))
failures = ledger["failures"]
if failures:
    sys.exit(f"FAIL: benchmark workloads failed: {failures}")
seconds = ledger["module_seconds"]
missing = [s for s in ("mess_load_sweep", "pointer_chase",
                       "spatter_nonuniform", "mess_calibrated")
           if s not in seconds]
if missing:
    sys.exit(f"FAIL: multi-axis scenarios did not run: {missing}")
tc = ledger["translation_cache"]
rate = tc["hit_rate"]
print(f"translation-cache hit rate: {rate:.3f} "
      f"(lower {tc['lower_hits']}/{tc['lower_hits']+tc['lower_misses']}, "
      f"compile {tc['compile_hits']}/{tc['compile_hits']+tc['compile_misses']}, "
      f"evictions {tc['evictions']}/{tc['capacity']}, "
      f"disk {tc['disk']})")
if rate < 0.5:
    sys.exit(f"FAIL: translation-cache hit rate {rate:.3f} < 0.5")
probe = ledger.get("param_path_probe", {})
if not probe or "error" in probe:
    sys.exit(f"FAIL: param_path probe did not run: {probe}")
# the 2D stencil ladder must be probed, and with N-D (rank-2) windows
WANT_RANKS = {"jacobi2d_indep": [2]}
for name in WANT_RANKS:
    if name not in probe:
        sys.exit(f"FAIL: probe ladder {name} missing from the ledger")
for name, p in probe.items():
    print(f"{name}: strided/specialized ratio {p['ratio']:.3f} "
          f"(per rung {p['per_point_ratio']}), "
          f"paths {p['param_path']}, rank {p.get('window_rank')}, "
          f"compile misses {p['compile_misses']}")
    if p["param_path"] != ["strided"]:
        sys.exit(f"FAIL: {name} did not run the strided regime: "
                 f"{p['param_path']}")
    if p["compile_misses"] != 1:
        sys.exit(f"FAIL: {name} ladder compiled {p['compile_misses']}x "
                 "(expected one shared executable)")
    if p["ratio"] > 1.5:
        sys.exit(f"FAIL: {name} strided-parametric per-call cost "
                 f"{p['ratio']:.3f}x specialized (> 1.5x floor)")
    want = WANT_RANKS.get(name)
    if want is not None and p.get("window_rank") != want:
        sys.exit(f"FAIL: {name} expected window rank {want}, got "
                 f"{p.get('window_rank')} (N-D windows regressed)")
for scen in ("mess_load_sweep", "pointer_chase", "spatter_nonuniform",
             "mess_calibrated"):
    print(f"{scen}: {seconds[scen]:.1f}s")
print("OK")
EOF2
