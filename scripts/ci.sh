#!/usr/bin/env bash
# CI gate: tier-1 tests (fast lane first, slow lane after) + the registry
# smoke suite + harness-perf floors + docs drift.
#
#   scripts/ci.sh [LEDGER_PATH]
#
# Fails on: any pytest failure (the fast lane runs first so breakage is
# loud in seconds; the slow lane — registry-wide conformance and
# property sweeps — runs after), a docs-drift violation (every
# registered workload must appear in docs/PAPER_MAP.md), a
# fault-injection gate violation (a plan with a poisoned point must
# still emit every other row and a schema-correct RunReport), any
# benchmark workload failure (the smoke ledger's structured `failures`
# list must be empty on the clean run), a missing multi-axis scenario
# (mess_load_sweep / pointer_chase / spatter_nonuniform /
# mess_calibrated must run in smoke mode), a process-wide
# translation-cache hit rate below 0.5 on the smoke suite, or a
# param_path probe violation: every strided-eligible probe ladder must
# run parametric with param_path == "strided" and exactly 1 compile
# miss, at a geometric-mean per-call cost within its floor of the
# specialized strided path (the regime-comparability floor this repo
# maintains — both sides donated, so the comparison is copy-free):
# 1.5x for the rank-1 stream ladders, 2.0x for the rank-2 stencil
# ladder (jacobi2d_indep, additionally required to run rank-2 N-D
# windows) — see the FLOORS note in the gate for the single-core
# recalibration evidence. Every probe entry must carry timing_quality.
# Also fails on a pallas probe violation: every pallas probe ladder
# must run the strided regime with exactly 1 compile miss on the
# pallas cache, report a pallas_mode consistent with the platform
# probe (compiled wherever the platform lowers pallas natively), carry
# per-side timing_quality, and stay under the calibrated
# backend-overhead ceiling (geomean pallas/jax <= 3.0 — see the
# CEILING note in the gate).
# PR-8 adds three concurrency gates: the smoke run executes through the
# ThreadPoolBackend (--jobs 4) and its ledger must carry the executor
# block + per-workload stage/measure phase split with zero failures;
# a serial-vs-threadpool run of the same multi-group plan must produce
# identical records (modulo the timing payload) with the threadpool
# reaching its first measurement no later than serial (overlapped
# staging actually overlaps); and the collective ladder re-runs under a
# forced 8-device host mesh, where ring-accounting wire bytes must
# agree with launch/hlo_analysis.analyze_collectives within 10% on
# every (op, shard-size) point.
# PR-9 adds the derived-workloads gate: the ledger's `derived` block
# must show >= 2 application-derived workloads (including one
# attention-derived and one MoE-derived) that ran failure-free with
# non-degenerate feature vectors (stride entropy / reuse distance /
# gather fraction all finite, not all zero) and a mined source op.
# PR-10 adds the trace-replay gates: spatter_ms1 and mess_contended
# must run in smoke mode; the ledger's `trace` block must show every
# trace pattern replaying BIT-exactly against the direct numpy replay
# of its JSON (with both an affine and a value-dependent form present);
# the `contended` block must show a nonzero per-pattern byte split on
# every mixed record and a contended/isolated primary-bandwidth ratio
# visibly below 1 (< 0.9); a committed Spatter capture must replay end
# to end through `benchmarks.run --pattern-file` (and a malformed file
# must be rejected up front with the parser's typed reason slug); and a
# journal-resume pass over both trace workloads must replay every point
# byte-identically — the trace/mix-aware pattern fingerprints are
# rebuild-stable, so a resumed sweep trusts its journal.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
LEDGER="${1:-BENCH_PR10.json}"

echo "== tier-1 pytest (fast lane) =="
python -m pytest -x -q -m "not slow"

echo "== tier-1 pytest (slow lane: conformance + property sweeps) =="
python -m pytest -q -m slow

echo "== docs drift (docs/PAPER_MAP.md covers the registry) =="
python - <<'EOF2'
import pathlib, sys

from benchmarks.run import registered_names

doc = pathlib.Path("docs/PAPER_MAP.md")
if not doc.exists():
    sys.exit("FAIL: docs/PAPER_MAP.md is missing")
text = doc.read_text()
orphans = [n for n in registered_names() if f"`{n}`" not in text]
if orphans:
    sys.exit(
        "FAIL: registered workloads missing from docs/PAPER_MAP.md: "
        f"{orphans} — add a row per workload (name in backticks)"
    )
if "| Pallas backend |" not in text:
    sys.exit(
        "FAIL: docs/PAPER_MAP.md lost the 'Pallas backend' eligibility "
        "column — every workload row must state how --backend pallas "
        "treats it (eligible / demotes / skips)"
    )
print(f"docs/PAPER_MAP.md covers all {len(registered_names())} workloads "
      "(+ backend-eligibility column)")
EOF2

echo "== fault-injection gate (poisoned point must not abort the sweep) =="
python - <<'EOF2'
import sys

from repro.core import DriverConfig, TranslationCache, gather
from repro.suite import SweepPlan, VariantSpec, env_axis, pattern_axis
from repro.suite.engine import run_plan


def factory(env, stride=2):
    if stride == 13:
        raise RuntimeError("ci fault injection: poisoned point")
    return gather(stride=stride)


plan = SweepPlan.product(pattern_axis("stride", (2, 13, 8)),
                         env_axis((256, 1024)))
report = run_plan(
    factory,
    [VariantSpec("g", DriverConfig(template="unified", programs=4,
                                   ntimes=2, reps=1, validate_n=64))],
    plan, cache=TranslationCache())
rows = {r.point.label for r in report.rows}
want = {f"stride{s}/n{n}" for s in (2, 8) for n in (256, 1024)}
if rows != want:
    sys.exit(f"FAIL: surviving rows wrong: {sorted(rows)} != {sorted(want)}")
if {f.label for f in report.failures} != {"stride13/n256", "stride13/n1024"}:
    sys.exit(f"FAIL: wrong failed points: "
             f"{[(f.variant, f.label) for f in report.failures]}")
for f in report.failures:
    if f.stage != "lower" or f.error != "LowerFailure":
        sys.exit(f"FAIL: poison misclassified: {f.stage}:{f.error}")
    if f.attempts < 2 or not f.demotions:
        sys.exit("FAIL: poisoned group skipped the demotion ladder: "
                 f"attempts={f.attempts} demotions={f.demotions}")
summary = report.summary()
for key in ("rows", "replayed", "failures", "demotions"):
    if key not in summary:
        sys.exit(f"FAIL: RunReport.summary() missing {key!r}")
fr = summary["failures"][0]
for key in ("variant", "label", "stage", "error", "message", "pattern",
            "template", "schedule", "backend", "env", "axis_point",
            "context", "attempts", "demotions"):
    if key not in fr:
        sys.exit(f"FAIL: FailureRecord schema missing {key!r}")
for row in report.rows:
    if "timing_quality" not in row.record.extra:
        sys.exit(f"FAIL: {row.point.label} record has no timing_quality")
print(f"fault isolation OK: {len(report.rows)} rows survived, "
      f"{len(report.failures)} recorded failures, "
      f"{len(report.demotions)} demotion steps")
EOF2

echo "== backend equivalence + staging overlap gate =="
python - <<'EOF2'
import dataclasses, sys

from repro.core import DriverConfig, TranslationCache, triad
from repro.suite import (SerialBackend, SweepPlan, ThreadPoolBackend,
                         VariantSpec, config_axis, env_axis, run_plan)

# a 3-group plan (config axis) so overlapped staging has work to overlap
plan = SweepPlan.product(config_axis("programs", (1, 2, 4)),
                         env_axis((4096, 16384)))
variants = [VariantSpec("t", DriverConfig(template="independent", ntimes=8,
                                          reps=2, validate_n=64))]

TIMING_FIELDS = {"seconds", "gbs", "gflops"}
TIMING_EXTRA = {"timing_quality", "compile_seconds", "lower_seconds",
                "cache_hit"}


def norm(report):
    out = []
    for row in report.rows:
        rec = row.record
        fields = tuple((f.name, getattr(rec, f.name))
                       for f in dataclasses.fields(rec)
                       if f.name not in TIMING_FIELDS and f.name != "extra")
        extra = tuple(sorted(((k, v) for k, v in rec.extra.items()
                              if k not in TIMING_EXTRA), key=str))
        out.append((row.variant, row.point.label, fields, extra))
    return out


ser = run_plan(lambda env: triad(), variants, plan,
               cache=TranslationCache(), backend=SerialBackend())
tp = run_plan(lambda env: triad(), variants, plan,
              cache=TranslationCache(), backend=ThreadPoolBackend(4))
if not (ser.ok and tp.ok):
    sys.exit(f"FAIL: backend gate plans must run clean: "
             f"serial={ser.summary()['failures']} "
             f"threadpool={tp.summary()['failures']}")
if norm(ser) != norm(tp):
    sers, tps = norm(ser), norm(tp)
    diff = [(a, b) for a, b in zip(sers, tps) if a != b]
    sys.exit(f"FAIL: threadpool records differ from serial: {diff[:3]}")
se, te = ser.executor, tp.executor
print(f"serial:     stage_wall {se['stage_wall_seconds']:.3f}s, "
      f"first measure at {se['first_measure_seconds']:.3f}s, "
      f"overlap {se['staging_overlap_seconds']:.3f}s, "
      f"wall {se['wall_seconds']:.3f}s")
print(f"threadpool: stage_wall {te['stage_wall_seconds']:.3f}s, "
      f"first measure at {te['first_measure_seconds']:.3f}s, "
      f"overlap {te['staging_overlap_seconds']:.3f}s, "
      f"wall {te['wall_seconds']:.3f}s")
if se["staging_overlap_seconds"] != 0.0:
    sys.exit("FAIL: serial backend reported nonzero staging overlap "
             f"({se['staging_overlap_seconds']}) — the stage barrier broke")
# Overlapped staging means the threadpool starts measuring before all
# staging is done; serial by construction stages everything first. The
# robust signal is time-to-first-measurement (1.1x + 50ms headroom for
# scheduler noise on a loaded container), not total wall, which is
# dominated by the measurement phase.
if te["first_measure_seconds"] > se["first_measure_seconds"] * 1.1 + 0.05:
    sys.exit(f"FAIL: threadpool first measurement at "
             f"{te['first_measure_seconds']:.3f}s vs serial "
             f"{se['first_measure_seconds']:.3f}s — staging no longer "
             "overlaps measurement")
print(f"backend equivalence OK: {len(tp.rows)} identical records")
EOF2

echo "== collective ladder gate (8-device host mesh) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'EOF2'
import sys

from repro.suite import collective_sizes, measure_collectives

rows = measure_collectives(quick=True)
want = 2 * len(collective_sizes(quick=True))
if len(rows) != want:
    sys.exit(f"FAIL: expected {want} collective points, got {len(rows)}")
for r in rows:
    print(f"{r['op']}/k{r['devices']}/s{r['shard_elems']}: "
          f"wire {int(r['wire_bytes'])}B, hlo {int(r['hlo_bytes'])}B, "
          f"agreement {r['agreement']:.3f}, {r['gbs']:.3f} GB/s")
    if r["devices"] != 8:
        sys.exit(f"FAIL: ladder ran on {r['devices']} devices, wanted 8")
    if abs(r["agreement"] - 1.0) > 0.10:
        sys.exit(f"FAIL: {r['op']}/s{r['shard_elems']} ring-vs-hlo byte "
                 f"agreement {r['agreement']:.3f} outside 10%")
print("collective ladder OK: ring accounting matches analyze_collectives")
EOF2

echo "== trace replay e2e (--pattern-file) =="
python -m benchmarks.run --pattern-file benchmarks/patterns/spatter_ms1.json \
    --only trace_spatter_ms1 | tee /tmp/trace_e2e.csv
python - <<'EOF2'
import sys

rows = [ln for ln in open("/tmp/trace_e2e.csv") if ln.startswith("trace/")]
if len(rows) < 4:
    sys.exit(f"FAIL: --pattern-file replay emitted {len(rows)} rows (< 4)")
forms = {ln.split("form=")[1].split(";")[0] for ln in rows if "form=" in ln}
if forms != {"ms1", "uniform"}:
    sys.exit(f"FAIL: expected ms1+uniform trace rows, got forms {forms}")
from repro.suite.spatter_io import load_spatter, replay_exact
for sp in load_spatter("benchmarks/patterns/spatter_ms1.json"):
    if not replay_exact(sp, n=256):
        sys.exit(f"FAIL: committed capture entry {sp.entry} ({sp.form}) "
                 "is not bit-exact against its numpy replay")
print(f"--pattern-file e2e OK: {len(rows)} rows, forms {sorted(forms)}, "
      "bit-exact")
EOF2

echo "== trace replay rejection (malformed --pattern-file) =="
echo '[{"pattern": [3, -1]}]' > /tmp/bad_spatter.json
if python -m benchmarks.run --pattern-file /tmp/bad_spatter.json \
        >/tmp/bad_spatter.out 2>&1; then
    echo "FAIL: malformed pattern file was accepted"; exit 1
fi
grep -q "negative_index" /tmp/bad_spatter.out || {
    echo "FAIL: rejection lost the typed reason slug:"; \
    cat /tmp/bad_spatter.out; exit 1; }
echo "malformed capture rejected with typed reason (negative_index)"

echo "== journal resume gate (trace/mix fingerprints replay byte-identically) =="
python - <<'EOF2'
import pathlib, sys, tempfile

from repro.suite import load_builtins, workload
from repro.suite.runner import collect_report

load_builtins()
tmp = pathlib.Path(tempfile.mkdtemp(prefix="ci_journal_"))
for name in ("spatter_ms1", "mess_contended"):
    w = workload(name)
    j = str(tmp / f"{name}.jsonl")
    r1 = collect_report(w, quick=True, journal=j)
    r1.raise_if_failed()
    if r1.replayed:
        sys.exit(f"FAIL: {name} first pass replayed {r1.replayed} points "
                 "from an empty journal")
    # second pass rebuilds every factory (fresh closures, fresh specs):
    # if the trace/mix-aware fingerprints were not rebuild-stable the
    # journal keys would miss and points would re-measure
    r2 = collect_report(w, quick=True, journal=j)
    r2.raise_if_failed()
    if not r2.rows or r2.replayed != len(r2.rows):
        sys.exit(f"FAIL: {name} resume replayed {r2.replayed}/"
                 f"{len(r2.rows)} points — fingerprints not rebuild-stable")
    rec1 = {(row.variant, row.point.label): row.record for row in r1.rows}
    for row in r2.rows:
        a = rec1[(row.variant, row.point.label)]
        if a != row.record:
            sys.exit(f"FAIL: {name}/{row.point.label} replayed record "
                     "differs from the measured one")
    stamps = [row.record.extra for row in r2.rows]
    if name == "spatter_ms1" and not all("trace" in e for e in stamps):
        sys.exit("FAIL: replayed spatter_ms1 records lost extra.trace")
    if name == "mess_contended" and not all("mix" in e for e in stamps):
        sys.exit("FAIL: replayed mess_contended records lost extra.mix")
    print(f"{name}: {r2.replayed}/{len(r2.rows)} points replayed "
          "byte-identically")
print("journal resume OK")
EOF2

echo "== benchmarks.run --smoke (--jobs 4, threadpool backend) =="
python -m benchmarks.run --smoke --jobs 4 --out "$LEDGER"

echo "== ledger gates ($LEDGER) =="
python - "$LEDGER" <<'EOF2'
import json, sys

ledger = json.load(open(sys.argv[1]))
failures = ledger["failures"]
if failures:
    # structured entries: {workload, stage, error, point?, message}
    brief = [f"{f.get('workload')}[{f.get('stage')}:{f.get('error')}]"
             for f in failures]
    sys.exit(f"FAIL: smoke run must be failure-free, got {brief}")
seconds = ledger["module_seconds"]
missing = [s for s in ("mess_load_sweep", "pointer_chase",
                       "spatter_nonuniform", "mess_calibrated",
                       "device_sweep", "collective_ladder",
                       "spatter_ms1", "mess_contended")
           if s not in seconds]
if missing:
    sys.exit(f"FAIL: multi-axis scenarios did not run: {missing}")
ex = ledger.get("executor", {})
if ex.get("backend") != "threadpool" or ex.get("workers") != 4:
    sys.exit("FAIL: smoke must run --jobs 4 through the threadpool "
             f"backend, executor block says {ex}")
for key in ("stage_seconds", "measure_seconds", "stage_wall_seconds",
            "staging_overlap_seconds", "wall_seconds"):
    if not isinstance(ex.get(key), (int, float)) or ex[key] < 0:
        sys.exit(f"FAIL: executor block missing/negative {key!r}: {ex}")
phases = ledger.get("module_phases", {})
for scen in ("mess_load_sweep", "spatter_nonuniform", "device_sweep"):
    p = phases.get(scen, {})
    if not {"stage_seconds", "measure_seconds",
            "staging_overlap_seconds"} <= set(p):
        sys.exit(f"FAIL: {scen} has no stage/measure phase split: {p}")
print(f"executor: {ex['backend']} x{ex['workers']}, "
      f"stage {ex['stage_seconds']:.1f}s / measure "
      f"{ex['measure_seconds']:.1f}s (summed), staging overlap "
      f"{ex['staging_overlap_seconds']:.1f}s across "
      f"{ex.get('workloads')} workloads")
tc = ledger["translation_cache"]
rate = tc["hit_rate"]
print(f"translation-cache hit rate: {rate:.3f} "
      f"(lower {tc['lower_hits']}/{tc['lower_hits']+tc['lower_misses']}, "
      f"compile {tc['compile_hits']}/{tc['compile_hits']+tc['compile_misses']}, "
      f"evictions {tc['evictions']}/{tc['capacity']}, "
      f"disk {tc['disk']})")
if rate < 0.5:
    sys.exit(f"FAIL: translation-cache hit rate {rate:.3f} < 0.5")
probe = ledger.get("param_path_probe", {})
if not probe or "error" in probe:
    sys.exit(f"FAIL: param_path probe did not run: {probe}")
# the 2D stencil ladder must be probed, and with N-D (rank-2) windows
WANT_RANKS = {"jacobi2d_indep": [2]}
# Regime-comparability floors, per ladder. 1.5x is the PR-4 contract
# for rank-1 stream ladders and still holds everywhere. The rank-2
# floor is recalibrated for single-core containers: the 2D window
# path's dynamic hull-slice copies parallelize across XLA:CPU intra-op
# threads on multi-core hosts (PR-5 measured 1.33x there) but
# serialize on a 1-core VM, where the *committed PR-5 code* measures
# 1.54-1.66x — a hardware envelope, not a harness regression. 2.0x
# still catches every regression class this gate exists for (gather
# fallback is 100-400x, a lost donation is 5-50x, a broken hull fusion
# is 3-10x).
FLOORS = {"jacobi2d_indep": 2.0}
for name in WANT_RANKS:
    if name not in probe:
        sys.exit(f"FAIL: probe ladder {name} missing from the ledger")
for name, p in probe.items():
    print(f"{name}: strided/specialized ratio {p['ratio']:.3f} "
          f"(per rung {p['per_point_ratio']}), "
          f"paths {p['param_path']}, rank {p.get('window_rank')}, "
          f"compile misses {p['compile_misses']}")
    if p["param_path"] != ["strided"]:
        sys.exit(f"FAIL: {name} did not run the strided regime: "
                 f"{p['param_path']}")
    if p["compile_misses"] != 1:
        sys.exit(f"FAIL: {name} ladder compiled {p['compile_misses']}x "
                 "(expected one shared executable)")
    floor = FLOORS.get(name, 1.5)
    if p["ratio"] > floor:
        sys.exit(f"FAIL: {name} strided-parametric per-call cost "
                 f"{p['ratio']:.3f}x specialized (> {floor}x floor)")
    want = WANT_RANKS.get(name)
    if want is not None and p.get("window_rank") != want:
        sys.exit(f"FAIL: {name} expected window rank {want}, got "
                 f"{p.get('window_rank')} (N-D windows regressed)")
    tq = p.get("timing_quality")
    if not tq or not tq.get("specialized") or not tq.get("strided"):
        sys.exit(f"FAIL: {name} probe entry has no timing_quality")
    for side in ("specialized", "strided"):
        for q in tq[side]:
            if not {"median_s", "min_s", "cv", "reps"} <= set(q):
                sys.exit(f"FAIL: {name} {side} timing_quality malformed: {q}")
pp = ledger.get("pallas_probe", {})
if not pp or "error" in pp:
    sys.exit(f"FAIL: pallas probe did not run: {pp}")
from repro.core.codegen import pallas_platform_mode
platform_mode = pallas_platform_mode()
if pp.get("pallas_mode") != platform_mode:
    sys.exit(f"FAIL: probe pallas_mode {pp.get('pallas_mode')!r} disagrees "
             f"with the platform probe ({platform_mode!r})")
# Backend-overhead ceiling, geomean pallas/jax per-call cost across the
# probe ladder. CEILING note: both probe ladders run the same strided
# parametric regime on both backends (donated, 1 executable each), so
# the ratio isolates pallas-call overhead. Calibrated on this 1-core
# container (interpret mode — the grid loop is still XLA-compiled):
# triad_indep 1.02x, jacobi2d_indep 1.10x. 3.0x leaves load-noise
# headroom while catching every regression class the gate exists for
# (a non-compiled eager fallback is 50-1000x, a lost donation 5-50x,
# a per-rung recompile shows up in compile_misses anyway).
CEILING = 3.0
for name in ("triad_indep", "jacobi2d_indep"):
    if name not in pp.get("workloads", {}):
        sys.exit(f"FAIL: pallas probe ladder {name} missing from the ledger")
for name, p in pp["workloads"].items():
    print(f"{name}: pallas/jax ratio {p['ratio']:.3f} "
          f"(per rung {p['per_point_ratio']}), mode {p['pallas_mode']}, "
          f"paths {p['param_path']}, compile misses {p['compile_misses']}")
    if p["param_path"] != ["strided"]:
        sys.exit(f"FAIL: {name} pallas ladder did not run the strided "
                 f"regime: {p['param_path']}")
    if p["compile_misses"] != 1:
        sys.exit(f"FAIL: {name} pallas ladder compiled "
                 f"{p['compile_misses']}x (expected one shared grid "
                 "executable)")
    if any(m != platform_mode for m in p["pallas_mode"]):
        sys.exit(f"FAIL: {name} ran pallas_mode {p['pallas_mode']} on a "
                 f"platform that probes {platform_mode!r} — compiled "
                 "execution regressed" if platform_mode == "compiled"
                 else f"FAIL: {name} claims modes {p['pallas_mode']} but "
                      f"the platform probe says {platform_mode!r}")
    if p["ratio"] > CEILING:
        sys.exit(f"FAIL: {name} pallas per-call cost {p['ratio']:.3f}x "
                 f"jax (> {CEILING}x ceiling)")
    tq = p.get("timing_quality")
    if not tq or not tq.get("jax") or not tq.get("pallas"):
        sys.exit(f"FAIL: {name} pallas probe entry has no per-side "
                 "timing_quality")
    for side in ("jax", "pallas"):
        for q in tq[side]:
            if not {"median_s", "min_s", "cv", "reps"} <= set(q):
                sys.exit(f"FAIL: {name} {side} timing_quality malformed: {q}")
for scen in ("mess_load_sweep", "pointer_chase", "spatter_nonuniform",
             "mess_calibrated"):
    print(f"{scen}: {seconds[scen]:.1f}s")
# derived-workloads gate: >= 2 application-derived workloads ran
# failure-free with non-degenerate feature vectors + mined source ops
import math
derived = ledger.get("derived", {})
if "error" in derived:
    sys.exit(f"FAIL: derived block did not build: {derived['error']}")
FEATURES = ("stride_entropy", "reuse_distance", "gather_fraction")
clean = {}
for name, entry in derived.items():
    if entry.get("failed"):
        continue
    fv = entry.get("feature_vector", {})
    vals = [fv.get(k) for k in FEATURES]
    if not all(isinstance(v, (int, float)) and math.isfinite(v)
               for v in vals):
        sys.exit(f"FAIL: {name} feature vector malformed: {fv}")
    if not any(abs(v) > 1e-9 for v in vals):
        sys.exit(f"FAIL: {name} feature vector degenerate (all zero): {fv}")
    if not entry.get("source_op") or not entry.get("source_model"):
        sys.exit(f"FAIL: {name} derived entry has no mined provenance: "
                 f"{entry}")
    clean[name] = entry
if len(clean) < 2:
    sys.exit(f"FAIL: need >= 2 failure-free derived workloads, got "
             f"{sorted(clean)}")
models = {e["source_model"] for e in clean.values()}
if not {"attention", "moe"} <= models:
    sys.exit(f"FAIL: derived block must include attention- and "
             f"MoE-derived workloads, got models {sorted(models)}")
for name, e in sorted(clean.items()):
    fv = e["feature_vector"]
    print(f"{name}: {e['source_model']}/{e['source_op']} "
          f"entropy {fv['stride_entropy']:.3f}b, reuse "
          f"{fv['reuse_distance']:.2f}, gather {fv['gather_fraction']:.3f}")
print(f"derived workloads OK: {len(clean)} mined from compiled HLO")
# trace gate: every trace pattern must replay bit-exactly against the
# direct numpy replay of its JSON, with both regimes represented
trace = ledger.get("trace", {})
if "error" in trace:
    sys.exit(f"FAIL: trace block did not build: {trace['error']}")
if "spatter_ms1" not in trace:
    sys.exit(f"FAIL: trace block has no spatter_ms1 entry: {sorted(trace)}")
affine_seen, kernel_seen = False, False
for name, entry in trace.items():
    if entry.get("failed"):
        sys.exit(f"FAIL: trace workload {name} failed in the smoke run")
    pats = entry.get("patterns", [])
    if not pats:
        sys.exit(f"FAIL: trace workload {name} reports no patterns")
    for p in pats:
        if not p.get("bitexact"):
            sys.exit(f"FAIL: {name} pattern {p.get('entry')} "
                     f"({p.get('form')}) is not bit-exact vs numpy replay")
        if not p.get("pattern_hash"):
            sys.exit(f"FAIL: {name} pattern {p.get('entry')} has no "
                     "provenance hash")
        affine_seen |= bool(p.get("affine"))
        kernel_seen |= not p.get("affine")
    print(f"{name}: {len(pats)} pattern(s) bit-exact "
          f"({entry.get('source')})")
if not (affine_seen and kernel_seen):
    sys.exit("FAIL: trace block must cover both the affine and the "
             f"value-dependent regime (affine={affine_seen}, "
             f"kernel={kernel_seen})")
# contended gate: mixed records carry a nonzero per-pattern byte split
# and the primary's bandwidth under load sits visibly below isolated
cont = ledger.get("contended", {})
if "error" in cont or "skipped" in cont:
    sys.exit(f"FAIL: contended block did not run: {cont}")
if not cont.get("split_ok"):
    sys.exit(f"FAIL: contended records lack a nonzero >=2-way "
             f"per-pattern byte split: {cont}")
ratio = cont.get("ratio")
if not isinstance(ratio, (int, float)):
    sys.exit(f"FAIL: contended block has no isolated/contended pairing: "
             f"{cont}")
if ratio >= 0.9:
    sys.exit(f"FAIL: contended primary bandwidth ratio {ratio:.3f} >= 0.9 "
             "— the contention curve is not visibly distinct from the "
             "isolated baseline")
print(f"contended OK: {cont['records']} mixed records, per-pattern split "
      f"intact, primary under load at {ratio:.3f}x isolated "
      f"({cont['contended_gbs']} vs {cont['isolated_gbs']} GB/s)")
print("OK")
EOF2
