#!/usr/bin/env bash
# CI gate: tier-1 tests + the registry smoke suite + harness-perf floor.
#
#   scripts/ci.sh [LEDGER_PATH]
#
# Fails on: any pytest failure, any benchmark workload failure, or a
# process-wide translation-cache hit rate below 0.5 on the smoke suite
# (the parametric-ladder + staged-pipeline floor this repo maintains).
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
LEDGER="${1:-BENCH_PR2.json}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== benchmarks.run --smoke =="
python -m benchmarks.run --smoke --out "$LEDGER"

echo "== ledger gates ($LEDGER) =="
python - "$LEDGER" <<'EOF'
import json, sys

ledger = json.load(open(sys.argv[1]))
failures = ledger["failures"]
if failures:
    sys.exit(f"FAIL: benchmark workloads failed: {failures}")
tc = ledger["translation_cache"]
rate = tc["hit_rate"]
print(f"translation-cache hit rate: {rate:.3f} "
      f"(lower {tc['lower_hits']}/{tc['lower_hits']+tc['lower_misses']}, "
      f"compile {tc['compile_hits']}/{tc['compile_hits']+tc['compile_misses']}, "
      f"disk {tc['disk']})")
if rate < 0.5:
    sys.exit(f"FAIL: translation-cache hit rate {rate:.3f} < 0.5")
print("OK")
EOF
