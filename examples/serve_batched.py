"""Batched serving example: continuous-batching-style loop over request
groups with prefill + decode phases against shared KV caches.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import get_config  # noqa: E402
from repro.launch.steps import make_prefill_step, make_serve_step  # noqa: E402
from repro.models import lm  # noqa: E402

ARCH = "internlm2-1.8b"
BATCH, PROMPT, GEN, ROUNDS = 4, 24, 12, 3


def main() -> None:
    cfg = get_config(ARCH).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    prefill = jax.jit(make_prefill_step(cfg, None), donate_argnums=(1,))
    decode = jax.jit(make_serve_step(cfg, None), donate_argnums=(1,))

    print(f"serving {cfg.name}: {ROUNDS} rounds x {BATCH} requests "
          f"(prompt {PROMPT}, gen {GEN})")
    total_tok, t_start = 0, time.time()
    for rnd in range(ROUNDS):
        key, k = jax.random.split(key)
        prompts = jax.random.randint(k, (BATCH, PROMPT), 0, cfg.vocab_size)
        cache = lm.init_cache(cfg, BATCH, PROMPT + GEN)
        t0 = time.time()
        tok, cache = prefill(params, cache, {"tokens": prompts})
        toks = [np.asarray(tok)]
        for _ in range(GEN - 1):
            tok, cache = decode(params, cache, {"tokens": tok})
            toks.append(np.asarray(tok))
        jax.block_until_ready(tok)
        dt = time.time() - t0
        gen = np.concatenate(toks, axis=1)
        total_tok += gen.size
        print(f"  round {rnd}: {gen.size} tokens in {dt:.2f}s | "
              f"seq0: {gen[0][:10].tolist()}")
    dt = time.time() - t_start
    print(f"total: {total_tok} tokens in {dt:.2f}s "
          f"({total_tok/dt:.1f} tok/s on CPU-interpret substrate)")


if __name__ == "__main__":
    main()
