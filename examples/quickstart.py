"""AdaptMemBench quickstart: define a pattern, pick a driver template,
measure it across working sets, and test an optimization — the paper's
whole workflow in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    Driver, DriverConfig, Variant, identity, sweep, triad,
)

# 1. A pattern specification (the paper's header + ISCC files).
#    triad() is built in; see repro/core/pattern.py for how to write one.
pattern = lambda env: triad()  # noqa: E731

# 2. A driver template: independent data spaces, 4 parallel programs
#    (paper Listing 2), fused repetition loop (the `nowait` analogue).
config = DriverConfig(template="independent", programs=4, ntimes=16, reps=3)
driver = Driver(pattern, config)

# 3. Validation against the serial oracle (the <kernel>_val.in stage).
driver.validate()
print("validation: OK")

# 4. Measure across working sets (bytes per stream crosses cache levels).
print("\nworking-set sweep:")
print("n,level,GB/s,us_per_sweep")
for rec in driver.run([1 << 10, 1 << 13, 1 << 16, 1 << 19]):
    print(f"{rec.n},{rec.level},{rec.gbs:.3f},{rec.seconds*1e6:.1f}")

# 5. Test an optimization: the paper's interleave-by-2 schedule (Fig. 9)
#    is one line — fork the schedule, sweep both, keep the winner.
result = sweep(
    pattern,
    [Variant("naive", config),
     Variant("interleave2",
             DriverConfig(template="independent", programs=4, ntimes=16,
                          reps=3, schedule=identity().interleave("i", 2)))],
    [1 << 13],
)
print("\noptimization sweep:")
print(result.table())
print(f"\nbest variant: {result.best[0]} "
      f"({result.best[1].gbs:.3f} GB/s)")
