"""End-to-end training driver: a ~100M-param LM trained for a few hundred
steps on CPU with the full production stack — sharded-ready step
functions, AdamW + cosine schedule, gradient compression, async
checkpointing, and the fault-tolerant loop (with an injected transient
fault to show the retry path).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--dim 256]

(The same code path scales to the pod configs — see launch/train.py and
the dry-run artifacts; this example keeps shapes CPU-friendly.)
"""
import argparse
import dataclasses
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.config import Shape, get_config  # noqa: E402
from repro.data.pipeline import Loader, SyntheticSource  # noqa: E402
from repro.launch.steps import make_train_step  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.optim import adamw, cosine_schedule, error_feedback  # noqa: E402
from repro.runtime.fault_tolerance import (  # noqa: E402
    FTConfig, FaultTolerantLoop,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    # a ~100M-param InternLM2-family config (vocab dominates at this scale)
    cfg = dataclasses.replace(
        get_config("internlm2-1.8b"),
        name="internlm2-100m", n_layers=args.layers, d_model=args.dim,
        n_heads=max(4, args.dim // 64), n_kv_heads=max(2, args.dim // 128),
        d_ff=args.dim * 4, head_dim=0, vocab_size=92544 // 2,
    )
    cfg = dataclasses.replace(cfg, head_dim=cfg.d_model // cfg.n_heads)

    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    import numpy as np
    n = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n/1e6:.1f}M  "
          f"({cfg.n_layers}L d{cfg.d_model})")

    opt = adamw(cosine_schedule(3e-4, warmup=20, total=args.steps),
                weight_decay=0.01)
    if args.compress:
        opt = error_feedback(opt)
    step = jax.jit(make_train_step(cfg, None, opt), donate_argnums=0)
    state = {"params": params, "opt": opt.init(params)}

    src = SyntheticSource(cfg.vocab_size, args.batch, args.seq, seed=11)
    loader = Loader(src, None)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    faults = {args.steps // 2: "transient"}  # show the retry path once
    loop = FaultTolerantLoop(
        step, state, FTConfig(ckpt_dir, ckpt_every=100),
        failure_hook=lambda s: faults.get(s))

    t0 = time.time()
    out = loop.run(loader, args.steps)
    loader.close()
    losses = [float(m["loss"]) for m in out["metrics"]]
    dt = time.time() - t0
    print(f"steps={len(losses)}  wall={dt:.1f}s "
          f"({dt/len(losses)*1e3:.0f} ms/step)")
    for i in range(0, len(losses), max(1, len(losses) // 10)):
        print(f"  step {i:4d}  loss {losses[i]:.4f}")
    print(f"  step {len(losses)-1:4d}  loss {losses[-1]:.4f}")
    print(f"events: {out['events']}")
    assert losses[-1] < losses[0], "loss did not improve"
    print("OK: loss improved "
          f"{losses[0]:.3f} -> {losses[-1]:.3f}; ckpts in {ckpt_dir}")


if __name__ == "__main__":
    main()
