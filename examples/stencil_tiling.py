"""Stencil tiling study (paper §III-B) through the framework: generate
Jacobi-3D benchmark drivers for several tiling schedules via the
polyhedral engine, validate each against the serial oracle, and measure —
then run the dedicated Pallas kernels (blocked vs streaming) and report
the halo-traffic model that explains the result.

    PYTHONPATH=src python examples/stencil_tiling.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import Driver, DriverConfig, identity, jacobi3d  # noqa: E402
from repro.core.measure import time_fn  # noqa: E402
from repro.kernels import ops  # noqa: E402

N = 34  # grid (interior 32^3); the paper uses up to 256^3 on a Xeon

print(f"Jacobi 3D, grid {N}^3 — schedule variants via the polyhedral engine")
print("variant,GB/s,us_per_sweep")
variants = {
    "naive": identity(),
    "xyz_16": identity().tile("i", 16).tile("j", 16).tile("k", 16),
    "partial_16x16": identity().tile("j", 16).tile("k", 16),
    "partial_8x32": identity().tile("j", 8).tile("k", 32),
}
for name, sch in variants.items():
    gb = [b for b in ("i_T", "j_T", "k_T") if b in [
        f"{d}_T" for d in ("i", "j", "k")]]
    grid_bands = tuple(b for b in ("i_T", "j_T", "k_T")
                       if any(t.dim + "_T" == b
                              for t in sch.transforms if hasattr(t, "size")))
    cfg = DriverConfig(template="unified", programs=1, ntimes=2, reps=2,
                       backend="pallas" if grid_bands else "jax",
                       schedule=sch, grid_bands=grid_bands or None,
                       validate_n=34)  # interior 32: divisible by all tiles
    d = Driver(lambda env: jacobi3d(), cfg)
    d.validate()
    rec = d.run([N])[0]
    print(f"{name},{rec.gbs:.3f},{rec.seconds*1e6:.1f}")

print("\ndedicated Pallas kernels (blocked vs streaming):")
x = jax.random.normal(jax.random.PRNGKey(0), (N, N, N), jnp.float32)
bytes_moved = 2 * (N - 2) ** 3 * 4
for name, fn in {
    "xyz_blocked_8x8x16": lambda: ops.jacobi3d(x, block=(8, 8, 16)),
    "streaming_8x16": lambda: ops.jacobi3d_streaming(x, block=(8, 16)),
    "streaming_16x32": lambda: ops.jacobi3d_streaming(x, block=(16, 32)),
}.items():
    t = time_fn(fn, reps=3)
    print(f"{name},{bytes_moved/t.seconds/1e9:.3f}GB/s,{t.seconds*1e6:.1f}us")

print("""
halo-traffic model (why streaming wins on TPU, DESIGN.md §2):
  xyz blocking  reads (1+2/b)^3 x minimal bytes  (~42% extra at b=16)
  streaming     reads (1+2/bj)(1+2/bk) x minimal (the i dim is exact)
The paper's negative result for spatial tiling on large-cache CPUs maps
to: on TPU, pick the layout that keeps the streamed dim un-tiled.""")
