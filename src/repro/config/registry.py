"""Registry mapping --arch ids to ArchConfig constructors."""
from __future__ import annotations

import importlib
from typing import Callable

from .base import ArchConfig

__all__ = ["register", "get_config", "list_archs"]

_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}

# module name (repro.configs.<mod>) per arch id
_ARCH_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "gemma3-27b": "gemma3_27b",
    "internlm2-1.8b": "internlm2_1_8b",
    "starcoder2-15b": "starcoder2_15b",
    "mistral-large-123b": "mistral_large_123b",
    "musicgen-large": "musicgen_large",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
}


def register(arch_id: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        mod = _ARCH_MODULES.get(arch_id)
        if mod is None:
            raise KeyError(
                f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}"
            )
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[arch_id]()


def list_archs() -> list[str]:
    return sorted(_ARCH_MODULES)
