"""Architecture / run configuration system.

One frozen dataclass describes every supported architecture; per-arch
modules in ``repro.configs`` instantiate it with the published numbers.
``reduced()`` produces the CPU-smoke-test version of the same family
(same block structure, tiny dims). ``Shape`` describes the assigned
input-shape cells (train / prefill / decode / long-context-decode).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["MLAConfig", "MoEConfig", "SSMConfig", "ArchConfig", "Shape", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/Kimi-K2 family)."""

    q_lora_rank: int = 0          # 0 = no q compression (DSv2-Lite)
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 64
    n_shared: int = 2
    top_k: int = 6
    d_ff_expert: int = 1408
    first_k_dense: int = 1        # leading dense layers (DS family)
    capacity_factor: float = 1.25
    router_scale: float = 1.0     # routed_scaling_factor


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"          # mamba2 | xlstm
    d_state: int = 64
    head_dim: int = 64            # SSM head size (d_inner // head_dim heads)
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256              # SSD / chunked-parallel block length
    slstm_every: int = 0          # xlstm: every k-th block is an sLSTM


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    act: str = "silu"             # silu | gelu
    rope_theta: float = 1e4
    rmsnorm_eps: float = 1e-5
    tie_embeddings: bool = False
    # local/global attention (gemma3): window size + pattern period
    window: int = 0               # 0 = full attention everywhere
    global_every: int = 0         # e.g. 6 -> layers 5,11,... are global
    # MoE / MLA
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # SSM / hybrid
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0    # zamba2: shared attn block every k blocks
    # modality frontends (stubs; input_specs() provides embeddings)
    frontend: Optional[str] = None  # audio | vision
    n_codebooks: int = 4          # audio: EnCodec codebooks
    vision_tokens: int = 1024     # vlm: patch-embedding count in specs
    # scan the layer stack (memory-efficient compile); hybrids scan groups
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (sliding-window / SSM / hybrid)."""
        if self.ssm is not None:
            return True
        return self.window > 0

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-style

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            scan_layers=self.scan_layers,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_routed=8, n_shared=min(self.moe.n_shared, 1),
                top_k=2, d_ff_expert=32,
            )
        if self.mla:
            kw["mla"] = MLAConfig(
                q_lora_rank=(32 if self.mla.q_lora_rank else 0),
                kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                v_head_dim=16,
            )
        if self.ssm:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=16,
            )
        if self.window:
            kw["window"] = 8
        if self.global_every:
            # keep >=2 full local/global groups + a tail for coverage
            kw["global_every"] = 3
            kw["n_layers"] = 7
        if self.frontend == "vision":
            kw["vision_tokens"] = 8
        if self.hybrid_attn_every:
            kw["hybrid_attn_every"] = 2
            kw["n_layers"] = 4
        if self.ssm and self.ssm.slstm_every:
            kw["ssm"] = dataclasses.replace(kw["ssm"], slstm_every=2)
            kw["n_layers"] = 4
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class Shape:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}
