from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig, Shape, SHAPES
from .registry import get_config, list_archs, register

__all__ = [
    "ArchConfig", "MLAConfig", "MoEConfig", "SSMConfig", "Shape", "SHAPES",
    "get_config", "list_archs", "register",
]
