"""The declarative experiment record.

A :class:`Workload` bundles everything the plan engine needs to
reproduce one paper figure (or any new scenario): a pattern factory, the
driver-config variants to contrast, the sweep plan (or the legacy
one-axis working-set ladder), and the validation/parametric policies.
Fully custom experiments (e.g. the Pallas tile sweep) register a
``runner`` instead and bypass the generic loop while still living in the
same registry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core import DriverConfig, PatternSpec, Record

from .axes import SweepPlan
from .ladders import Ladder

__all__ = ["VariantSpec", "Workload"]

PatternFactory = Callable[..., PatternSpec]  # factory(env, **pattern_kwargs)


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    """One measured configuration of a workload.

    ``pattern`` overrides the workload-level factory (used by sweeps
    whose pattern changes per variant, e.g. the stream-count sweep).
    Factories take ``(env, **kwargs)``; pattern-axis points arrive as
    the keyword arguments.

    ``backend`` overrides ``config.backend`` when set — the CLI's
    ``--backend`` rewrite (``benchmarks.run``) uses it to re-target a
    registered workload at the pallas backend without rebuilding its
    ``DriverConfig``s.
    """

    label: str
    config: DriverConfig
    pattern: PatternFactory | None = None
    backend: str | None = None

    def resolved_config(self) -> DriverConfig:
        """``config`` with the ``backend`` override applied."""
        if self.backend is None or self.backend == self.config.backend:
            return self.config
        return dataclasses.replace(self.config, backend=self.backend)


@dataclasses.dataclass(frozen=True)
class Workload:
    """One registered experiment.

    Declarative fields drive the shared plan engine; ``runner`` (if set)
    replaces it wholesale. ``variants`` may be a callable of ``quick``
    for sweeps whose variant list depends on the mode.

    Exactly one of ``plan``/``ladder`` describes the sweep: ``plan`` is
    the general multi-axis form, ``ladder`` the one-working-set-axis
    compatibility form (internally ``ladder.plan()`` — identical CSVs).

    ``parametric`` is the env-axis-sharing policy applied to variants
    that leave ``DriverConfig.parametric`` at its default: "auto"
    (default) shares one executable across the env-axis ladder whenever
    the schedule lowers symbolically, False always specializes, True
    requires sharing.

    ``tags`` group scenario families (``paper-figs``, ``spatter``,
    ``mess``, ``latency``) for ``benchmarks.run --tag`` filtering.
    """

    name: str                                  # registry key
    figure: str = ""                           # CSV label prefix
    title: str = ""                            # one-line description
    pattern: PatternFactory | None = None
    variants: "tuple[VariantSpec, ...] | Callable[[bool], Sequence[VariantSpec]]" = ()
    ladder: Ladder | None = None
    plan: SweepPlan | None = None
    tags: tuple[str, ...] = ()
    validate: bool = True
    parametric: bool | str = "auto"
    derived: Callable[[Record], str] | None = None   # CSV derived column
    post: Callable[[bool], list[str]] | None = None  # extra lines after sweep
    runner: Callable[[bool], list[str]] | None = None  # full custom escape

    def variant_list(self, quick: bool) -> tuple[VariantSpec, ...]:
        v = self.variants(quick) if callable(self.variants) else self.variants
        return tuple(v)

    def sweep_plan(self) -> SweepPlan:
        """The executed plan: ``plan`` as given, or the ladder's
        one-axis equivalent."""
        if self.plan is not None:
            return self.plan
        assert self.ladder is not None  # enforced by __post_init__
        return self.ladder.plan()

    def __post_init__(self) -> None:
        if self.runner is None:
            if self.pattern is None and not self.variants:
                raise ValueError(
                    f"workload {self.name!r} needs either a runner or "
                    "pattern+variants+plan"
                )
            if (self.ladder is None) == (self.plan is None):
                raise ValueError(
                    f"workload {self.name!r} needs exactly one of "
                    "ladder/plan"
                )
