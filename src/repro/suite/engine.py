"""The plan-based execution engine — one executor for every sweep.

Everything that measures (the workload runner, ``autotune.sweep``, the
registered scenarios) converges here: a :class:`~repro.suite.axes.SweepPlan`
expands into labelled points, the engine partitions them into *driver
groups* (all points sharing config overrides and pattern kwargs — i.e.
differing only along env axes — regardless of axis order; results are
re-emitted in plan order), and each group executes through the staged
lower→compile pipeline:

* **env axes** form the group's working-set ladder. Where the schedule
  lowers symbolically the whole group shares ONE parametric executable
  (the PR 2 regime); otherwise each env point specializes, with the
  translation cache deduplicating identical tuples across groups,
  variants, and re-runs.
* **config / pattern axes** change the executable's structure, so each
  distinct combination is its own specialization — staged up front so
  the XLA compiles overlap on worker threads.

Each distinct executable is validated once against the serial oracle
(memoized in the cache), and every record is annotated with
``extra["axis_point"]`` — the axis-name → point mapping — so CSVs stay
self-describing however many axes a scenario sweeps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core import Driver, GLOBAL_CACHE, Record, TranslationCache, precompile

from .axes import PlanPoint, SweepPlan
from .workload import VariantSpec

__all__ = ["PlanRow", "run_plan"]


@dataclasses.dataclass(frozen=True)
class PlanRow:
    """One measured (variant, plan point) result."""

    variant: str
    point: PlanPoint
    record: Record


@dataclasses.dataclass
class _Group:
    """Plan points differing only along env axes: one driver, one
    (possibly parametric) prepare/run call. ``order`` holds each point's
    index in the expanded plan so results can be re-emitted in plan
    order whatever the axis ordering was."""

    variant: VariantSpec
    points: list[PlanPoint]
    order: list[int]
    driver: Driver

    @property
    def envs(self) -> list[dict]:
        return [dict(p.env) for p in self.points]


def _wrap_factory(base: Callable, kwargs: tuple) -> Callable:
    """Bind pattern-axis kwargs onto a factory; identity when empty so
    kwarg-less legacy factories (``lambda env: triad()``) keep working."""
    if not kwargs:
        return base
    kw = dict(kwargs)
    return lambda env: base(env, **kw)


def _grouped(variant: VariantSpec, base_factory: Callable | None,
             points: Sequence[PlanPoint], cache: TranslationCache,
             parametric, param_path: str | None) -> list[_Group]:
    """Partition a variant's plan points by (config, pattern) identity.

    Grouping is global, not run-length: an env axis ordered *before* a
    config/pattern axis still lands all of a combination's env points in
    one group, so parametric sharing never depends on axis order."""
    factory = variant.pattern or base_factory
    if factory is None:
        raise ValueError(f"variant {variant.label!r} has no pattern factory")
    groups: dict[tuple, _Group] = {}
    for i, pt in enumerate(points):
        if "n" not in dict(pt.env):
            raise ValueError(
                f"plan point {pt.label!r} has no 'n' env entry; every plan "
                "needs an env axis targeting the working-set parameter 'n' "
                "(further env axes may add other parameters on top)"
            )
        g = groups.get(pt.group_key)
        if g is not None:
            g.points.append(pt)
            g.order.append(i)
            continue
        cfg = variant.config
        if pt.config:
            cfg = dataclasses.replace(cfg, **dict(pt.config))
        if cfg.parametric is None and parametric is not None:
            cfg = dataclasses.replace(cfg, parametric=parametric)
        if param_path is not None and cfg.param_path == "auto":
            cfg = dataclasses.replace(cfg, param_path=param_path)
        drv = Driver(_wrap_factory(factory, pt.pattern_kwargs), cfg,
                     cache=cache)
        groups[pt.group_key] = _Group(
            variant=variant, points=[pt], order=[i], driver=drv
        )
    return list(groups.values())


def run_plan(
    factory: Callable | None,
    variants: Sequence[VariantSpec],
    plan: SweepPlan,
    *,
    quick: bool = True,
    cache: TranslationCache | None = None,
    validate: bool = True,
    parametric: "bool | str | None" = None,
    param_path: str | None = None,
    max_check_n: int = 4096,
) -> list[PlanRow]:
    """Execute ``plan`` under every variant; returns rows in
    variant-major, plan-point order.

    ``parametric`` is the env-axis-sharing policy applied to configs
    that leave ``DriverConfig.parametric`` unset (None leaves them
    unset — the driver then specializes). ``param_path`` likewise pins
    the parametric lowering regime ("strided"/"gather") on configs that
    leave it at "auto" — the conformance tests use it to run a whole
    registry under one regime. Every group's executables are staged
    before any timing starts; validation runs once per distinct
    executable (cache-memoized), with the parametric oracle replay
    bounded to points ``<= max_check_n``.
    """
    cache = cache if cache is not None else GLOBAL_CACHE
    points = plan.points(quick)
    per_variant = [
        (v, _grouped(v, factory, points, cache, parametric, param_path))
        for v in variants
    ]
    groups = [g for _, gs in per_variant for g in gs]
    # stage every group's executables before any timing starts
    precompile([
        (lambda g=g: g.driver.prepare(g.envs, parallel=False))
        for g in groups
    ])
    rows: list[PlanRow] = []
    for v, gs in per_variant:
        indexed: list[tuple[int, PlanRow]] = []
        for g in gs:
            d = g.driver
            envs = g.envs
            if validate and d.cfg.validate_n:
                # non-"n" env entries (extra env axes) must reach the
                # oracle too; take them from the group's smallest point
                extra = {k: v for k, v in
                         min(envs, key=lambda e: e["n"]).items() if k != "n"}
                d.validate({**extra, "n": d.cfg.validate_n})
            recs = d.run(envs)
            if validate and d.cfg.validate_n and any(
                    r.extra.get("parametric") for r in recs):
                # the executable that produced these numbers is the shared
                # parametric one — oracle-check it too (small points only:
                # the serial oracle's guarded fallback is O(points) Python);
                # memoized per ladder, so re-runs don't re-pay it.
                d.validate_parametric(envs, max_check_n=max_check_n)
            for i, pt, rec in zip(g.order, g.points, recs):
                rec.extra["axis_point"] = pt.axis_point()
                indexed.append((i, PlanRow(v.label, pt, rec)))
        # emit in plan order regardless of how grouping reordered work
        rows.extend(row for _, row in sorted(indexed, key=lambda t: t[0]))
    return rows
