"""The plan-based execution engine — one executor for every sweep.

Everything that measures (the workload runner, ``autotune.sweep``, the
registered scenarios) converges here: a :class:`~repro.suite.axes.SweepPlan`
expands into labelled points, the engine partitions them into *driver
groups* (all points sharing config overrides and pattern kwargs — i.e.
differing only along env axes — regardless of axis order; results are
re-emitted in plan order), and each group executes through the staged
lower→compile pipeline:

* **env axes** form the group's working-set ladder. Where the schedule
  lowers symbolically the whole group shares ONE parametric executable
  (the PR 2 regime); otherwise each env point specializes, with the
  translation cache deduplicating identical tuples across groups,
  variants, and re-runs.
* **config / pattern axes** change the executable's structure, so each
  distinct combination is its own specialization — staged up front so
  the XLA compiles overlap on worker threads.

Each distinct executable is validated once against the serial oracle
(memoized in the cache), and every record is annotated with
``extra["axis_point"]`` — the axis-name → point mapping — so CSVs stay
self-describing however many axes a scenario sweeps.

Fault isolation (``on_error="demote"``, the default): a faulting group
never aborts the sweep. Transient faults retry with bounded exponential
backoff (:class:`~repro.core.errors.ResiliencePolicy`); persistent ones
walk the **demotion ladder** — strided→gather, parametric→per-size
specialized, donated→undonated — re-attempting only the group's still
-pending points at each rung; a group that exhausts the ladder marks
*its own* points failed and the sweep continues. The result is a
:class:`RunReport` (rows + failures + demotions) instead of a bare row
list; ``on_error="raise"`` reproduces the strict legacy behavior
(original exceptions propagate — the conformance tests depend on the
exact classes). Plan-*shape* errors (missing 'n' env axis, zip-length
mismatch, unknown variant wiring) always raise: a malformed plan is a
bug, not a fault to survive.

Resumability: ``run_plan(journal=path)`` appends each completed point
to a :class:`~repro.suite.journal.RunJournal`; re-invocation replays
completed keys (byte-identical records, zero compiles) and executes
only the remainder.

Execution backends (``run_plan(backend=...)``): *how* the live groups
stage and measure is pluggable. :class:`SerialBackend` (the default)
reproduces the legacy order exactly — one process-wide staging barrier,
then groups measured one at a time in plan order. :class:`ThreadPool
Backend` removes the barrier: each worker stages its group and
immediately measures it, so group N+1's lower/compile overlaps group
N's timing loop (XLA compiles release the GIL). The determinism
contract both backends honour: the merged record set is byte-identical
modulo timing (rows re-emitted in plan order, per-group fault isolation
and the demotion ladder unchanged, journal appends serialized). To keep
the timings themselves trustworthy, ThreadPoolBackend serializes the
*measurement* phase per resolved device — groups pinned to distinct
devices (the plan's device axis) time genuinely in parallel, while
groups sharing a device never time against each other's noise; the
concurrency win comes from overlapping staging with measurement, not
from timing concurrently on shared hardware.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

import jax

from repro.core import (
    Driver,
    GLOBAL_CACHE,
    Record,
    TranslationCache,
    identity,
    precompile,
)
from repro.core.errors import (
    BenchFailure,
    Demotion,
    FailureRecord,
    ResiliencePolicy,
    SweepFailures,
    classify_failure,
)

from .axes import PlanPoint, SweepPlan
from .journal import RunJournal
from .workload import VariantSpec

__all__ = [
    "PlanRow",
    "RunReport",
    "run_plan",
    "ExecutionBackend",
    "SerialBackend",
    "ThreadPoolBackend",
]


@dataclasses.dataclass(frozen=True)
class PlanRow:
    """One measured (variant, plan point) result."""

    variant: str
    point: PlanPoint
    record: Record


@dataclasses.dataclass
class RunReport:
    """What a fault-isolated sweep actually produced.

    Iterates like the row list ``run_plan`` used to return (existing
    callers keep working); ``failures`` holds one
    :class:`~repro.core.errors.FailureRecord` per point that exhausted
    the demotion ladder, ``demotions`` the ladder steps taken, and
    ``replayed`` the number of points served from the journal."""

    rows: list[PlanRow]
    failures: list[FailureRecord] = dataclasses.field(default_factory=list)
    demotions: list[Demotion] = dataclasses.field(default_factory=list)
    replayed: int = 0
    # Execution-phase accounting from the backend that ran the sweep:
    # {backend, workers, groups, stage_seconds, measure_seconds,
    #  stage_wall_seconds, first_measure_seconds,
    #  staging_overlap_seconds, wall_seconds}. staging_overlap_seconds
    # is the staging time spent while some group was measuring — 0.0 by
    # construction under SerialBackend (barrier first), positive when
    # ThreadPoolBackend actually pipelined.
    executor: dict = dataclasses.field(default_factory=dict)

    def __iter__(self):
        return iter(self.rows)

    def __len__(self) -> int:
        return len(self.rows)

    def __getitem__(self, i):
        return self.rows[i]

    @property
    def ok(self) -> bool:
        return not self.failures

    def summary(self) -> dict:
        return {
            "rows": len(self.rows),
            "replayed": self.replayed,
            "failures": [f.as_dict() for f in self.failures],
            "demotions": [dataclasses.asdict(d) for d in self.demotions],
            "executor": dict(self.executor),
        }

    def raise_if_failed(self) -> None:
        """Strictness on demand: aggregate the failures into one
        :class:`~repro.core.errors.SweepFailures` (carrying them on
        ``.failures``) after the surviving rows were already emitted."""
        if self.failures:
            raise SweepFailures(self.failures)


@dataclasses.dataclass
class _Group:
    """Plan points differing only along env axes: one driver, one
    (possibly parametric) prepare/run call. ``order`` holds each point's
    index in the expanded plan so results can be re-emitted in plan
    order whatever the axis ordering was."""

    variant: VariantSpec
    points: list[PlanPoint]
    order: list[int]
    driver: Driver

    @property
    def envs(self) -> list[dict]:
        return [dict(p.env) for p in self.points]


def _wrap_factory(base: Callable, kwargs: tuple) -> Callable:
    """Bind pattern-axis kwargs onto a factory; identity when empty so
    kwarg-less legacy factories (``lambda env: triad()``) keep working."""
    if not kwargs:
        return base
    kw = dict(kwargs)
    return lambda env: base(env, **kw)


def _grouped(variant: VariantSpec, base_factory: Callable | None,
             points: Sequence[PlanPoint], cache: TranslationCache,
             parametric, param_path: str | None) -> list[_Group]:
    """Partition a variant's plan points by (config, pattern) identity.

    Grouping is global, not run-length: an env axis ordered *before* a
    config/pattern axis still lands all of a combination's env points in
    one group, so parametric sharing never depends on axis order."""
    factory = variant.pattern or base_factory
    if factory is None:
        raise ValueError(f"variant {variant.label!r} has no pattern factory")
    groups: dict[tuple, _Group] = {}
    for i, pt in enumerate(points):
        if "n" not in dict(pt.env):
            raise ValueError(
                f"plan point {pt.label!r} has no 'n' env entry; every plan "
                "needs an env axis targeting the working-set parameter 'n' "
                "(further env axes may add other parameters on top)"
            )
        g = groups.get(pt.group_key)
        if g is not None:
            g.points.append(pt)
            g.order.append(i)
            continue
        cfg = variant.resolved_config()
        if pt.config:
            cfg = dataclasses.replace(cfg, **dict(pt.config))
        if cfg.parametric is None and parametric is not None:
            cfg = dataclasses.replace(cfg, parametric=parametric)
        if param_path is not None and cfg.param_path == "auto":
            cfg = dataclasses.replace(cfg, param_path=param_path)
        drv = Driver(_wrap_factory(factory, pt.pattern_kwargs), cfg,
                     cache=cache)
        groups[pt.group_key] = _Group(
            variant=variant, points=[pt], order=[i], driver=drv
        )
    return list(groups.values())


# ---------------------------------------------------------------------------
# Fault-isolated group execution
# ---------------------------------------------------------------------------


def _demotion_ladder(cfg) -> list[tuple]:
    """The (config, step-name) sequence a failing group walks, most
    capable config first. Each rung trades capability for robustness:

    * ``pallas->jax``         structural backend demotion: patterns the
                              pallas backend refuses (custom kernels,
                              guarded schedules, non-unit vector
                              strides) re-run on the jax backend
                              instead of failing the group;
    * ``strided->gather``     keep sharing one executable, drop the
                              dynamic-slice fast path for the masked
                              gather form that is safe at every env;
    * ``parametric->specialized``  give up executable sharing, one
                              per-size compile per point (isolates both
                              compile faults and capacity-sized
                              allocations to single points);
    * ``donated->undonated``  per-call buffer copies, but no donation
                              stream to corrupt.
    """
    rungs = [(cfg, None)]
    if cfg.backend == "pallas":
        # every later rung runs on jax too: a fault that survives the
        # backend demotion is not a pallas-specific fault
        cfg = dataclasses.replace(cfg, backend="jax")
        rungs.append((cfg, "pallas->jax"))
    if cfg.parametric and cfg.param_path != "gather":
        rungs.append((dataclasses.replace(cfg, param_path="gather"),
                      "strided->gather"))
    if cfg.parametric:
        rungs.append((dataclasses.replace(cfg, parametric=False),
                      "parametric->specialized"))
    if cfg.donate is not False and cfg.backend == "jax":
        rungs.append((dataclasses.replace(cfg, parametric=False,
                                          donate=False),
                      "donated->undonated"))
    return rungs


def _validate_group(d: Driver, envs: list[dict], validate: bool) -> None:
    if validate and d.cfg.validate_n:
        # non-"n" env entries (extra env axes) must reach the
        # oracle too; take them from the group's smallest point
        extra = {k: v for k, v in
                 min(envs, key=lambda e: e["n"]).items() if k != "n"}
        d.validate({**extra, "n": d.cfg.validate_n})


def _attempt_strict(d: Driver, envs: list[dict], validate: bool,
                    max_check_n: int) -> list[Record]:
    """Legacy semantics: any fault propagates with its original class."""
    preps = d.prepare(envs, parallel=False)
    _validate_group(d, envs, validate)
    recs = [d.measure_point(p) for p in preps]
    if validate and d.cfg.validate_n and any(
            r.extra.get("parametric") for r in recs):
        # the executable that produced these numbers is the shared
        # parametric one — oracle-check it too (small points only:
        # the serial oracle's guarded fallback is O(points) Python);
        # memoized per ladder, so re-runs don't re-pay it.
        d.validate_parametric(envs, max_check_n=max_check_n)
    return recs


def _attempt(d: Driver, envs: list[dict], validate: bool, max_check_n: int,
             ctx: dict):
    """One fault-isolated pass over a group's pending envs.

    Group-scope faults (prepare / oracle validation) raise a classified
    ``BenchFailure``; point-scope faults (measurement) are captured per
    point. Returns ``(successes, point_failures)`` as lists of
    (env-index, Record) / (env-index, BenchFailure)."""
    try:
        preps = d.prepare(envs, parallel=False)
    except Exception as e:
        raise classify_failure(e, "lower", **ctx)
    try:
        _validate_group(d, envs, validate)
    except Exception as e:
        raise classify_failure(e, "validate", **ctx)
    recs: list[tuple[int, Record]] = []
    fails: list[tuple[int, BenchFailure]] = []
    for i, p in enumerate(preps):
        try:
            recs.append((i, d.measure_point(p)))
        except Exception as e:
            fails.append((i, classify_failure(e, "measure", **ctx,
                                              env=dict(p.env))))
    if validate and d.cfg.validate_n and any(
            r.extra.get("parametric") for _, r in recs):
        try:
            d.validate_parametric(envs, max_check_n=max_check_n)
        except Exception as e:
            # the shared executable is untrustworthy: every record it
            # produced goes back to pending via the group-scope raise
            raise classify_failure(e, "validate", **ctx)
    return recs, fails


def _run_group_isolated(g: _Group, validate: bool, max_check_n: int,
                        policy: ResiliencePolicy):
    """Walk the demotion ladder for one group; returns
    ``(results, failures, demotions)`` where results maps the group-local
    point index to its Record and failures maps it to the final
    BenchFailure."""
    ctx = {
        "variant": g.variant.label,
        "template": g.driver.cfg.template,
        "backend": g.driver.cfg.backend,
    }
    pending = list(range(len(g.points)))
    results: dict[int, Record] = {}
    last_fail: dict[int, BenchFailure] = {}
    attempts: dict[int, int] = {i: 0 for i in pending}
    demotions: list[Demotion] = []
    steps: tuple[str, ...] = ()
    ladder = _demotion_ladder(g.driver.cfg) if policy.demote \
        else [(g.driver.cfg, None)]
    for cfg, step in ladder:
        if not pending:
            break
        if step is None:
            driver = g.driver
        else:
            trigger = last_fail.get(pending[0])
            demotions.append(Demotion(
                variant=g.variant.label,
                labels=tuple(g.points[i].label for i in pending),
                step=step,
                stage=trigger.stage if trigger else "",
                error=type(trigger).__name__ if trigger else "",
            ))
            steps += (step,)
            driver = Driver(g.driver.factory, cfg, cache=g.driver.cache)
        retry = 0
        while pending:
            if retry:
                time.sleep(policy.backoff_s * (2 ** (retry - 1)))
            cur = list(pending)
            envs = [dict(g.points[i].env) for i in cur]
            try:
                recs, fails = _attempt(driver, envs, validate, max_check_n,
                                       ctx)
            except BenchFailure as e:
                for i in cur:
                    last_fail[i] = e
                    attempts[i] += 1
                if not (e.transient and retry < policy.max_retries):
                    break  # next ladder rung
                retry += 1
                continue
            for li, rec in recs:
                gi = cur[li]
                if steps:
                    rec.extra["demotions"] = list(steps)
                results[gi] = rec
                attempts[gi] += 1
            transient_left = False
            pending = []
            for li, exc in fails:
                gi = cur[li]
                last_fail[gi] = exc
                attempts[gi] += 1
                pending.append(gi)
                transient_left = transient_left or exc.transient
            if not pending:
                break
            if not (transient_left and retry < policy.max_retries):
                break  # next ladder rung
            retry += 1
    failures = {i: last_fail[i] for i in pending}
    return results, failures, demotions, attempts, steps


def _failure_record(g: _Group, i: int, exc: BenchFailure, attempts: int,
                    steps: tuple) -> FailureRecord:
    pt = g.points[i]
    cfg = g.driver.cfg
    try:
        pattern = g.driver.factory(dict(pt.env)).name
    except Exception:
        pattern = str(exc.context.get("pattern", ""))
    return FailureRecord(
        variant=g.variant.label,
        label=pt.label,
        stage=exc.stage,
        error=type(exc).__name__,
        message=str(exc),
        pattern=pattern,
        template=cfg.template,
        schedule=(cfg.schedule or identity()).name,
        backend=cfg.backend,
        env=dict(pt.env),
        axis_point=pt.axis_point(),
        context={**exc.context,
                 "cause": type(exc.cause).__name__ if exc.cause else None},
        attempts=attempts,
        demotions=list(steps),
    )


# ---------------------------------------------------------------------------
# Execution backends
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _GroupRun:
    """One live group's unit of work: a staging step plus a measured
    run, with the outcome captured on the unit itself so backends can
    execute in any order and ``run_plan`` merges deterministically."""

    variant: VariantSpec
    group: _Group
    validate: bool
    max_check_n: int
    policy: ResiliencePolicy
    strict: bool
    jr: "RunJournal | None"
    keys: "list | None"
    rows: list = dataclasses.field(default_factory=list)   # (plan idx, PlanRow)
    failures: list = dataclasses.field(default_factory=list)
    demotions: list = dataclasses.field(default_factory=list)
    # journal lines built during run(), written by flush_journal()
    pending_journal: list = dataclasses.field(default_factory=list)
    error: "BaseException | None" = None
    measure_interval: "tuple | None" = None

    @property
    def device_key(self):
        """Measurement-serialization key: the id of the *physical*
        device this group's kernels run on, not the raw ``cfg.device``
        index. Drivers resolve pins modulo the visible device count
        (``Driver._device``) and ``None`` executes on the process
        default device, so dev0/dev1 on a one-device host — or a pinned
        dev0 group next to an unpinned group — must share one lock;
        keying on the raw index would let them time concurrently on the
        same hardware."""
        dev = self.group.driver._device()
        if dev is None:
            dev = jax.devices()[0]
        return dev.id

    def stage(self) -> None:
        """Lower + compile this group's executables (cache-deduplicated
        against every other group). In the fault-isolated mode a staging
        error is swallowed here and re-surfaces (classified) inside
        ``run``'s own attempt, so one bad group cannot abort staging."""
        try:
            self.group.driver.prepare(self.group.envs, parallel=False)
        except Exception:
            if self.strict:
                raise

    def run(self) -> None:
        """Measure the group (everything below is today's per-group loop
        body, unchanged — demotion ladder and all). Journal lines are
        only *queued* here; the backend calls :meth:`flush_journal`
        afterwards so the journal's flush+fsync never runs under a
        measurement lock, where a slow disk would serialize into other
        groups' time-to-measure."""
        v, g = self.variant, self.group
        if self.strict:
            recs = _attempt_strict(g.driver, g.envs, self.validate,
                                   self.max_check_n)
            for i, pt, rec in zip(g.order, g.points, recs):
                rec.extra["axis_point"] = pt.axis_point()
                self.rows.append((i, PlanRow(v.label, pt, rec)))
        else:
            results, failures, demotions, attempts, steps = \
                _run_group_isolated(g, self.validate, self.max_check_n,
                                    self.policy)
            self.demotions.extend(demotions)
            for li, rec in sorted(results.items()):
                pt = g.points[li]
                rec.extra["axis_point"] = pt.axis_point()
                self.rows.append((g.order[li], PlanRow(v.label, pt, rec)))
            for li, exc in sorted(failures.items()):
                fr = _failure_record(g, li, exc, attempts[li], steps)
                self.failures.append(fr)
                if self.jr is not None:
                    self.pending_journal.append(
                        ("failure", self.keys[li], g.points[li], fr))
        if self.jr is not None:
            for order_i, row in self.rows:
                li = g.order.index(order_i)
                self.pending_journal.append(
                    ("row", self.keys[li], row.point, row.record))

    def flush_journal(self) -> None:
        """Append this unit's queued journal lines (failures first, then
        rows — the order the inline appends used to produce). Backends
        call this exactly once per successfully-run unit, after
        releasing any measurement serialization."""
        if self.jr is None:
            return
        v = self.variant
        for kind, key, point, payload in self.pending_journal:
            if kind == "row":
                self.jr.append_row(key, v.label, point, payload)
            else:
                self.jr.append_failure(key, v.label, point, payload)
        self.pending_journal.clear()


class ExecutionBackend:
    """How live driver groups stage and measure.

    ``execute(units, strict)`` must (1) call every unit's ``stage``,
    then ``run``, then — once ``run`` succeeded and any measurement
    serialization is released — ``flush_journal``, each exactly once,
    (2) record each unit's measurement span on
    ``unit.measure_interval``, (3) return the list of staging
    ``(start, end)`` spans it spent, and (4) surface unit errors: under
    ``strict`` the first error in unit (= plan) order propagates after
    all workers settle; outside strict any escaped exception is a plan
    bug and propagates too. Result *merging* is not the backend's job —
    outcomes accumulate on the units and ``run_plan`` re-emits them in
    plan order, which is what keeps the record set byte-identical
    across backends."""

    name = "?"
    workers = 1

    def execute(self, units: "list[_GroupRun]",
                strict: bool) -> "list[tuple[float, float]]":
        raise NotImplementedError


class SerialBackend(ExecutionBackend):
    """The legacy order, exactly: stage every group's executables behind
    one ``precompile`` barrier (compiles overlap on worker threads, as
    before), then measure the groups one at a time in plan order."""

    name = "serial"
    workers = 1

    def execute(self, units, strict):
        if not units:
            return []
        t0 = time.perf_counter()
        precompile([u.stage for u in units])
        stage_intervals = [(t0, time.perf_counter())]
        for u in units:
            m0 = time.perf_counter()
            u.run()
            u.measure_interval = (m0, time.perf_counter())
            u.flush_journal()
        return stage_intervals


class ThreadPoolBackend(ExecutionBackend):
    """Overlapped staging: no global barrier. Each worker stages its
    group then immediately measures it, so group N+1's lower/compile
    (GIL-released XLA) runs while group N times. Measurement itself is
    serialized per resolved *physical* device — a per-device lock keyed
    on the device each group actually runs on — so timings are never
    taken concurrently on shared hardware; device-axis groups pinned to
    distinct devices do measure in parallel.

    CPU-backend caveat: on CPU-only hosts (the CI configuration) the
    overlapped XLA compiles run on the same cores as the kernel under
    test, so the per-device lock cannot stop compile threads from
    adding measurement noise — the adaptive ``target_cv`` rep
    escalation absorbs it, at the cost of extra reps. On accelerator
    backends compiles burn host cores while kernels time on the device,
    and the overlap is noise-free."""

    name = "threadpool"

    def __init__(self, workers: int = 4):
        if workers < 1:
            raise ValueError(f"ThreadPoolBackend needs >=1 worker, got "
                             f"{workers}")
        self.workers = int(workers)
        self._locks: dict = {}
        self._locks_guard = threading.Lock()

    def _measure_lock(self, key) -> threading.Lock:
        with self._locks_guard:
            return self._locks.setdefault(key, threading.Lock())

    def execute(self, units, strict):
        stage_intervals: list[tuple[float, float]] = []
        si_guard = threading.Lock()

        def work(u: _GroupRun) -> None:
            s0 = time.perf_counter()
            try:
                u.stage()          # swallows faults unless strict
            except Exception as e:
                u.error = e
                with si_guard:
                    stage_intervals.append((s0, time.perf_counter()))
                return
            with si_guard:
                stage_intervals.append((s0, time.perf_counter()))
            with self._measure_lock(u.device_key):
                m0 = time.perf_counter()
                try:
                    u.run()
                except Exception as e:
                    u.error = e
                finally:
                    u.measure_interval = (m0, time.perf_counter())
            if u.error is None:
                try:
                    u.flush_journal()   # outside the measure lock
                except Exception as e:
                    u.error = e

        if units:
            with ThreadPoolExecutor(max_workers=self.workers,
                                    thread_name_prefix="plan-exec") as pool:
                list(pool.map(work, units))
        # deterministic error surfacing: first failed unit in plan order
        # (under strict these are the legacy exception classes; outside
        # strict an escaped exception is a plan bug, not a fault)
        for u in units:
            if u.error is not None:
                raise u.error
        return stage_intervals


def _overlap_seconds(stage_intervals, measure_intervals) -> float:
    """Total staging time that ran while some measurement was running —
    the pipelining the ThreadPoolBackend exists to create."""
    measure_intervals = [m for m in measure_intervals if m is not None]
    if not stage_intervals or not measure_intervals:
        return 0.0
    merged: list[list[float]] = []
    for a, b in sorted(measure_intervals):
        if merged and a <= merged[-1][1]:
            merged[-1][1] = max(merged[-1][1], b)
        else:
            merged.append([a, b])
    total = 0.0
    for s0, s1 in stage_intervals:
        for m0, m1 in merged:
            lo, hi = max(s0, m0), min(s1, m1)
            if hi > lo:
                total += hi - lo
    return total


def run_plan(
    factory: Callable | None,
    variants: Sequence[VariantSpec],
    plan: SweepPlan,
    *,
    quick: bool = True,
    cache: TranslationCache | None = None,
    validate: bool = True,
    parametric: "bool | str | None" = None,
    param_path: str | None = None,
    max_check_n: int = 4096,
    on_error: str = "demote",
    resilience: ResiliencePolicy | None = None,
    journal: "RunJournal | str | None" = None,
    backend: "ExecutionBackend | None" = None,
) -> RunReport:
    """Execute ``plan`` under every variant; returns a :class:`RunReport`
    whose rows iterate in variant-major, plan-point order.

    ``parametric`` is the env-axis-sharing policy applied to configs
    that leave ``DriverConfig.parametric`` unset (None leaves them
    unset — the driver then specializes). ``param_path`` likewise pins
    the parametric lowering regime ("strided"/"gather") on configs that
    leave it at "auto" — the conformance tests use it to run a whole
    registry under one regime. Every group's executables are staged
    before any timing starts; validation runs once per distinct
    executable (cache-memoized), with the parametric oracle replay
    bounded to points ``<= max_check_n``.

    ``on_error="demote"`` (default) isolates faults per driver group —
    retry/backoff per ``resilience``, then the demotion ladder, then
    only that group's points land in ``report.failures``;
    ``on_error="raise"`` propagates the first fault with its original
    exception class (strict legacy behavior). ``journal`` (a path or
    :class:`~repro.suite.journal.RunJournal`) makes the run resumable:
    completed points replay, only the remainder executes.

    ``backend`` picks the execution backend (default
    :class:`SerialBackend`). :class:`ThreadPoolBackend` stages and
    measures groups concurrently with staging overlapped into
    measurement; the merged record set is byte-identical modulo timing
    either way, and ``report.executor`` carries the phase accounting.
    """
    if on_error not in ("demote", "raise"):
        raise ValueError(
            f"unknown on_error {on_error!r} (expected 'demote' or 'raise')")
    cache = cache if cache is not None else GLOBAL_CACHE
    policy = resilience if resilience is not None else ResiliencePolicy()
    exec_backend = backend if backend is not None else SerialBackend()
    strict = on_error == "raise"
    jr = None
    if journal is not None:
        jr = journal if isinstance(journal, RunJournal) else RunJournal(journal)
    points = plan.points(quick)
    per_variant = [
        (v, _grouped(v, factory, points, cache, parametric, param_path))
        for v in variants
    ]
    report = RunReport(rows=[])

    # journal replay: resolve every already-completed point up front and
    # shrink the groups to the remainder
    keyed: dict[int, list] = {}
    replayed: dict[int, list] = {}
    if jr is not None:
        for vi, (v, gs) in enumerate(per_variant):
            for gi, g in enumerate(gs):
                keys = [RunJournal.key_for(v.label, pt, g.driver.cfg,
                                           g.driver.factory)
                        for pt in g.points]
                keyed[id(g)] = keys
                live_points, live_order, live_keys = [], [], []
                rep: list[tuple[int, PlanRow]] = []
                for pt, order_i, key in zip(g.points, g.order, keys):
                    entry = jr.seen(key)
                    if entry is None:
                        live_points.append(pt)
                        live_order.append(order_i)
                        live_keys.append(key)
                        continue
                    report.replayed += 1
                    if entry["kind"] == "row":
                        rec = Record(**entry["record"])
                        rep.append((order_i, PlanRow(v.label, pt, rec)))
                    else:
                        report.failures.append(
                            FailureRecord(**entry["failure"]))
                replayed[id(g)] = rep
                g.points, g.order = live_points, live_order
                keyed[id(g)] = live_keys

    # one work unit per live group, in variant-major plan order — the
    # order SerialBackend executes in and every backend's error /
    # merge order
    units: list[_GroupRun] = []
    unit_by_group: dict[int, _GroupRun] = {}
    for v, gs in per_variant:
        for g in gs:
            if not g.points:
                continue
            u = _GroupRun(
                variant=v, group=g, validate=validate,
                max_check_n=max_check_n, policy=policy, strict=strict,
                jr=jr, keys=keyed.get(id(g)),
            )
            units.append(u)
            unit_by_group[id(g)] = u

    t_run0 = time.perf_counter()
    stage_intervals = exec_backend.execute(units, strict)

    for v, gs in per_variant:
        indexed: list[tuple[int, PlanRow]] = []
        if jr is not None:
            for g in gs:
                indexed.extend(replayed.get(id(g), []))
        for g in gs:
            u = unit_by_group.get(id(g))
            if u is None:
                continue
            report.demotions.extend(u.demotions)
            report.failures.extend(u.failures)
            indexed.extend(u.rows)
        # emit in plan order regardless of how grouping reordered work
        report.rows.extend(
            row for _, row in sorted(indexed, key=lambda t: t[0]))

    measure_intervals = [u.measure_interval for u in units
                         if u.measure_interval is not None]
    report.executor = {
        "backend": exec_backend.name,
        "workers": int(exec_backend.workers),
        "groups": len(units),
        "stage_seconds": sum(b - a for a, b in stage_intervals),
        "measure_seconds": sum(b - a for a, b in measure_intervals),
        "stage_wall_seconds": (
            max(b for _, b in stage_intervals)
            - min(a for a, _ in stage_intervals)
        ) if stage_intervals else 0.0,
        "first_measure_seconds": (
            min(a for a, _ in measure_intervals) - t_run0
        ) if measure_intervals else 0.0,
        "staging_overlap_seconds": _overlap_seconds(stage_intervals,
                                                    measure_intervals),
        "wall_seconds": time.perf_counter() - t_run0,
    }
    return report
