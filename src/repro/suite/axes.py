"""Sweep axes and plans — the multi-axis generalization of ``Ladder``.

AdaptMemBench explores memory behaviour along *application-specific*
axes, but a :class:`~repro.suite.ladders.Ladder` can only model one of
them: the working-set size. The scenarios the suite needs next sweep
other things — Mess-style load points vary ``programs``/``ntimes``
pressure, Spatter stride ladders vary a pattern-factory kwarg — so the
sweep dimension itself has to be declarative.

An :class:`Axis` is a named, typed sequence of points. Its ``kind`` says
where each point lands when a plan point is materialized:

    env       an environment parameter. Every plan needs one env axis
              targeting the working-set parameter ``n`` (the engine
              enforces this); further env axes may supply other domain/
              shape parameters on top. Env axes are the ones the engine
              can share one parametric executable across (the sharing
              itself is along ``n``).
    config    a :class:`~repro.core.DriverConfig` field (``programs``,
              ``ntimes``, ``pad``, ...). Each distinct value is its own
              specialized executable.
    pattern   a keyword argument of the workload's pattern factory
              (``stride`` for the Spatter ladders). Also specializes.
    device    a device shard: each point pins its driver group to
              ``jax.devices()[index % len(jax.devices())]`` (the value
              lands in ``DriverConfig.device``, so device groups are
              distinct executables bound to distinct devices and the
              concurrent execution backends run them genuinely in
              parallel across a host/accelerator mesh). Labels default
              to ``dev<index>``.

A :class:`SweepPlan` combines axes by ``product`` (the full grid) or
``zip`` (lockstep tuples) and expands, per mode, into labelled
:class:`PlanPoint` values the engine executes. ``Ladder`` is re-expressed
as a one-env-axis plan (see :meth:`Ladder.plan`), so every pre-existing
workload runs through the same machinery unchanged.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable

__all__ = [
    "Axis",
    "PlanPoint",
    "SweepPlan",
    "env_axis",
    "config_axis",
    "pattern_axis",
    "device_axis",
]


@dataclasses.dataclass(frozen=True)
class Axis:
    """One named sweep dimension.

    ``quick``/``full`` are the measurement points per mode (``full``
    defaults to ``quick``). ``field`` is the target name — the env key,
    DriverConfig field, or factory kwarg — and defaults to ``name``.
    ``transform`` maps a labelled point to the applied value (the ladder
    ``env_n`` analogue, e.g. Jacobi's ``n + 2`` halo); labels always
    report the *un*-transformed point. ``fmt`` overrides the label
    fragment (default ``f"{name}{point}"``). Both must be top-level
    functions (or None) so axes stay hashable values.
    """

    name: str
    kind: str                       # env | config | pattern | device
    quick: tuple
    full: tuple = ()
    field: str = ""
    transform: Callable[[Any], Any] | None = None
    fmt: Callable[[Any], str] | None = None

    def __post_init__(self) -> None:
        if self.kind not in ("env", "config", "pattern", "device"):
            raise ValueError(f"axis {self.name!r}: unknown kind {self.kind!r}")
        if not self.quick:
            raise ValueError(f"axis {self.name!r} has no points")
        if not self.full:
            object.__setattr__(self, "full", tuple(self.quick))

    @property
    def target(self) -> str:
        return self.field or self.name

    def points(self, quick: bool) -> tuple:
        return self.quick if quick else self.full

    def value(self, point):
        return self.transform(point) if self.transform else point

    def label(self, point) -> str:
        return self.fmt(point) if self.fmt else f"{self.name}{point}"


def env_axis(quick, full=(), *, name: str = "n", field: str = "",
             transform: Callable | None = None,
             fmt: Callable | None = None) -> Axis:
    """An environment-parameter axis (default: the working set ``n``)."""
    return Axis(name, "env", tuple(quick), tuple(full), field,
                transform, fmt)


def config_axis(name: str, quick, full=(), *, field: str = "",
                fmt: Callable | None = None) -> Axis:
    """A DriverConfig-field axis (``programs``, ``ntimes``, ``pad``, ...)."""
    return Axis(name, "config", tuple(quick), tuple(full), field,
                None, fmt)


def pattern_axis(name: str, quick, full=(), *, field: str = "",
                 fmt: Callable | None = None) -> Axis:
    """A pattern-factory keyword axis (``stride`` for Spatter ladders)."""
    return Axis(name, "pattern", tuple(quick), tuple(full), field,
                None, fmt)


def _dev_fmt(p) -> str:
    return f"dev{p}"


def device_axis(quick, full=(), *, name: str = "device",
                fmt: Callable | None = None) -> Axis:
    """A device-shard axis: points are device indices resolved modulo
    ``len(jax.devices())`` at execution time, so a plan written for an
    8-device mesh still runs (collapsed) on a 1-device box."""
    return Axis(name, "device", tuple(quick), tuple(full), "device",
                None, fmt or _dev_fmt)


@dataclasses.dataclass(frozen=True)
class PlanPoint:
    """One fully-resolved measurement point of a plan.

    ``coords`` is the self-describing identity (axis name -> labelled
    point) that lands in ``Record.extra["axis_point"]``; ``env``/
    ``config``/``pattern_kwargs`` are the applied (transformed) values
    split by destination. Points sharing ``group_key`` can run on one
    driver, with their env entries forming the ladder the parametric
    path may collapse onto a single executable.
    """

    coords: tuple[tuple[str, Any], ...]
    env: tuple[tuple[str, Any], ...]
    config: tuple[tuple[str, Any], ...]
    pattern_kwargs: tuple[tuple[str, Any], ...]
    label: str

    def axis_point(self) -> dict:
        return dict(self.coords)

    @property
    def group_key(self) -> tuple:
        return (self.config, self.pattern_kwargs)


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """A combination of axes: ``product`` (full grid, axis order = label
    order = iteration order, last axis fastest) or ``zip`` (lockstep —
    all axes must have equal point counts per mode)."""

    axes: tuple[Axis, ...]
    mode: str = "product"

    def __post_init__(self) -> None:
        if self.mode not in ("product", "zip"):
            raise ValueError(f"unknown plan mode {self.mode!r}")
        if not self.axes:
            raise ValueError("a SweepPlan needs at least one axis")
        names = [a.name for a in self.axes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate axis names in plan: {names}")

    @classmethod
    def product(cls, *axes: Axis) -> "SweepPlan":
        return cls(tuple(axes), "product")

    @classmethod
    def zip(cls, *axes: Axis) -> "SweepPlan":
        return cls(tuple(axes), "zip")

    @property
    def env_axes(self) -> tuple[Axis, ...]:
        return tuple(a for a in self.axes if a.kind == "env")

    def points(self, quick: bool) -> tuple[PlanPoint, ...]:
        per_axis = [a.points(quick) for a in self.axes]
        if self.mode == "zip":
            counts = {len(p) for p in per_axis}
            if len(counts) != 1:
                raise ValueError(
                    "zip plan axes disagree on point counts: "
                    f"{[(a.name, len(p)) for a, p in zip(self.axes, per_axis)]}"
                )
            tuples = zip(*per_axis)
        else:
            tuples = itertools.product(*per_axis)
        out = []
        for tup in tuples:
            coords, env, config, pat = [], [], [], []
            frags = []
            for a, p in zip(self.axes, tup):
                coords.append((a.name, p))
                frags.append(a.label(p))
                dest = {"env": env, "config": config, "pattern": pat,
                        "device": config}[a.kind]
                dest.append((a.target, a.value(p)))
            out.append(PlanPoint(
                coords=tuple(coords), env=tuple(env), config=tuple(config),
                pattern_kwargs=tuple(pat), label="/".join(frags),
            ))
        return tuple(out)
