"""repro.suite — the declarative workload registry layer.

AdaptMemBench's core claim is that access patterns should be *specified*,
not hand-coded, and replayed through kernel-independent drivers. This
package extends that discipline from single kernels to whole experiment
suites (the registry-driven design of Spatter and of Mess-style load
sweeps): each experiment is a declarative :class:`Workload` record —
pattern x schedule variants x working-set ladder x validation policy —
registered by name, and one generic runner executes every entry, so a
new scenario is ~10 lines of data instead of a hand-rolled script.

    Axis/SweepPlan   multi-axis sweep dimensions (env/config/pattern/device)
    Ladder           named working-set ladders — one-env-axis plans
    Workload         one experiment: variants + plan (or ladder) + policies
    register/...     the process-wide registry
    run_plan         the plan engine (stage -> validate -> measure), with
                     pluggable execution backends (Serial / ThreadPool)
    run_workload     the workload-level executor emitting the CSV contract
"""
from .axes import (
    Axis,
    PlanPoint,
    SweepPlan,
    config_axis,
    device_axis,
    env_axis,
    pattern_axis,
)
from .ladders import (
    FULL_GRID,
    FULL_SETS,
    GRID2,
    GRID3,
    INTERIOR_SETS,
    QUICK_GRID,
    QUICK_SETS,
    WORKING_SETS,
    Ladder,
    fixed,
)
from .workload import VariantSpec, Workload
from .registry import (
    all_tags,
    load_builtins,
    names,
    register,
    workload,
    workloads,
)
from .collectives import (
    collective_runner,
    collective_sizes,
    expected_wire_bytes,
    measure_collectives,
)
from .engine import (
    ExecutionBackend,
    PlanRow,
    RunReport,
    SerialBackend,
    ThreadPoolBackend,
    run_plan,
)
from .journal import RunJournal, stable_fingerprint
from .spatter_io import (
    SpatterParseError,
    SpatterPattern,
    load_spatter,
    parse_spatter,
    replay_exact,
    trace_workload,
)
from .runner import (
    collect_records,
    collect_report,
    csv_line,
    emit,
    run_module,
    run_workload,
)

__all__ = [
    "Axis", "PlanPoint", "SweepPlan",
    "env_axis", "config_axis", "pattern_axis", "device_axis",
    "Ladder", "fixed",
    "WORKING_SETS", "INTERIOR_SETS", "GRID2", "GRID3",
    "QUICK_SETS", "FULL_SETS", "QUICK_GRID", "FULL_GRID",
    "VariantSpec", "Workload",
    "register", "workload", "workloads", "names", "all_tags",
    "load_builtins",
    "PlanRow", "RunReport", "run_plan",
    "ExecutionBackend", "SerialBackend", "ThreadPoolBackend",
    "RunJournal", "stable_fingerprint",
    "SpatterParseError", "SpatterPattern", "parse_spatter", "load_spatter",
    "replay_exact", "trace_workload",
    "run_workload", "run_module", "collect_records", "collect_report",
    "csv_line", "emit",
    "collective_runner", "collective_sizes", "expected_wire_bytes",
    "measure_collectives",
]
