"""repro.suite — the declarative workload registry layer.

AdaptMemBench's core claim is that access patterns should be *specified*,
not hand-coded, and replayed through kernel-independent drivers. This
package extends that discipline from single kernels to whole experiment
suites (the registry-driven design of Spatter and of Mess-style load
sweeps): each experiment is a declarative :class:`Workload` record —
pattern x schedule variants x working-set ladder x validation policy —
registered by name, and one generic runner executes every entry, so a
new scenario is ~10 lines of data instead of a hand-rolled script.

    Ladder           named working-set ladders (quick/full points)
    Workload         one experiment: variants + ladder + policies
    register/...     the process-wide registry
    run_workload     the single shared executor (stage -> validate ->
                     measure -> CSV), parametric-by-default
"""
from .ladders import (
    FULL_GRID,
    FULL_SETS,
    GRID2,
    GRID3,
    INTERIOR_SETS,
    QUICK_GRID,
    QUICK_SETS,
    WORKING_SETS,
    Ladder,
    fixed,
)
from .workload import VariantSpec, Workload
from .registry import load_builtins, names, register, workload, workloads
from .runner import collect_records, csv_line, emit, run_module, run_workload

__all__ = [
    "Ladder", "fixed",
    "WORKING_SETS", "INTERIOR_SETS", "GRID2", "GRID3",
    "QUICK_SETS", "FULL_SETS", "QUICK_GRID", "FULL_GRID",
    "VariantSpec", "Workload",
    "register", "workload", "workloads", "names", "load_builtins",
    "run_workload", "run_module", "collect_records",
    "csv_line", "emit",
]
