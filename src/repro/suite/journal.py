"""Resumable run journal — the sweep's crash-recovery substrate.

``run_plan(journal=path)`` appends one JSONL entry per *completed* plan
point (a measured ``PlanRow`` or a final ``FailureRecord``), keyed by a
process-stable fingerprint of (variant label, axis-point coordinates,
driver config, pattern factory). Re-invoking the same plan against the
same journal replays the completed keys verbatim — byte-identical
records, zero lowers/compiles — and executes only the remainder. This
is the substrate the ROADMAP's benchmark-as-a-service daemon needs: a
killed sweep resumes instead of restarting.

Why not ``staging._freeze``'s fingerprints? Those feed an *in-process*
cache and lean on Python's ``hash()``, which is salted per process —
useless as a journal key. Here every key is a sha1 over a canonical
byte encoding (sorted dict items, tagged scalar reprs, code-object
bytecode + consts + closure for callables), so a key computed by the
re-invocation matches the one the crashed run wrote.

File format — one JSON object per line, append-only::

    {"v": 1, "key": "<sha1>", "kind": "row",     "variant": ..., "label": ..., "record":  {...}}
    {"v": 1, "key": "<sha1>", "kind": "failure", "variant": ..., "label": ..., "failure": {...}}

A torn final line (the crash happened mid-write) is skipped on load;
that point simply re-executes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import threading
import types

import numpy as np

__all__ = ["RunJournal", "stable_fingerprint"]


def _feed(h, obj, depth: int = 0) -> None:
    """Feed a canonical, process-stable byte encoding of ``obj`` into
    hash ``h``. Type-tagged so e.g. 1 and "1" and True differ."""
    if depth > 12:          # cyclic/degenerate closures: stop descending
        h.update(b"\x00...")
        return
    if obj is None:
        h.update(b"\x00N")
    elif isinstance(obj, bool):
        h.update(b"\x00B1" if obj else b"\x00B0")
    elif isinstance(obj, int):
        h.update(b"\x00I" + str(obj).encode())
    elif isinstance(obj, float):
        h.update(b"\x00F" + repr(obj).encode())
    elif isinstance(obj, str):
        h.update(b"\x00S" + obj.encode())
    elif isinstance(obj, bytes):
        h.update(b"\x00Y" + obj)
    elif isinstance(obj, (tuple, list)):
        h.update(b"\x00T")
        for x in obj:
            _feed(h, x, depth + 1)
        h.update(b"\x00t")
    elif isinstance(obj, (dict,)):
        h.update(b"\x00D")
        for k in sorted(obj, key=str):
            _feed(h, str(k), depth + 1)
            _feed(h, obj[k], depth + 1)
        h.update(b"\x00d")
    elif isinstance(obj, (set, frozenset)):
        _feed(h, sorted(obj, key=repr), depth + 1)
    elif isinstance(obj, np.ndarray):
        h.update(b"\x00A" + str(obj.dtype).encode() + str(obj.shape).encode())
        h.update(obj.tobytes())
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        h.update(b"\x00C" + type(obj).__qualname__.encode())
        for f in dataclasses.fields(obj):
            _feed(h, f.name, depth + 1)
            _feed(h, getattr(obj, f.name), depth + 1)
    elif isinstance(obj, types.CodeType):
        h.update(b"\x00K" + obj.co_code)
        for c in obj.co_consts:
            _feed(h, c, depth + 1)
    elif callable(obj):
        h.update(b"\x00L")
        _feed(h, getattr(obj, "__module__", ""), depth + 1)
        _feed(h, getattr(obj, "__qualname__", ""), depth + 1)
        code = getattr(obj, "__code__", None)
        if code is not None:
            _feed(h, code, depth + 1)
            for cell in (getattr(obj, "__closure__", None) or ()):
                try:
                    _feed(h, cell.cell_contents, depth + 1)
                except ValueError:  # empty cell
                    h.update(b"\x00E")
            _feed(h, getattr(obj, "__defaults__", None), depth + 1)
        else:
            # bound method / functools.partial / callable object
            _feed(h, getattr(obj, "__func__", None) or repr(type(obj)),
                  depth + 1)
    else:
        # Fraction, Affine-free scalars, enums, ... — repr is stable for
        # everything the driver configs actually carry.
        h.update(b"\x00R" + repr(obj).encode())


def stable_fingerprint(*objs) -> str:
    """sha1 hex digest of a canonical encoding — identical across
    processes for identical plan/config structure."""
    h = hashlib.sha1()
    for o in objs:
        _feed(h, o)
    return h.hexdigest()


class RunJournal:
    """Append-only JSONL journal of completed plan points."""

    VERSION = 1

    def __init__(self, path: "str | os.PathLike"):
        self.path = pathlib.Path(path)
        # Writer lock: the plan engine's ThreadPoolBackend appends from
        # several group workers at once. One serialized write per entry
        # keeps every JSONL line whole (append-mode writes from separate
        # fds may interleave mid-line once json.dumps output crosses the
        # pipe-buffer atomicity limit) and keeps the in-memory entry map
        # consistent with the file.
        self._write_lock = threading.Lock()
        self._entries: dict[str, dict] = {}
        if self.path.exists():
            for line in self.path.read_text().splitlines():
                line = line.strip()
                if not line:
                    continue
                try:
                    e = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn tail write from a crash: re-execute
                if isinstance(e, dict) and e.get("v") == self.VERSION \
                        and "key" in e:
                    self._entries[e["key"]] = e

    # -- keys ---------------------------------------------------------------

    @staticmethod
    def key_for(variant_label: str, point, cfg, factory=None) -> str:
        """Journal key: (variant, axis point, config fingerprint) — the
        *original* group config, never the demoted one, so a resumed run
        matches points before walking any ladder.

        Pallas groups additionally key on the platform-resolved
        execution mode: a journal written on a compiled-capable box must
        not replay into a resumed run on an interpret-only box (or vice
        versa) — those records carry different ``extra.pallas_mode``
        stamps and different timings. Jax keys are unchanged, so
        journals from before the pallas backend still replay.
        """
        extra = ()
        if getattr(cfg, "backend", None) == "pallas":
            from repro.core.codegen import pallas_platform_mode
            extra = ("pallas_mode", pallas_platform_mode())
        return stable_fingerprint(
            variant_label, tuple(point.coords), point.label, cfg, factory,
            *extra)

    # -- queries ------------------------------------------------------------

    def seen(self, key: str) -> dict | None:
        return self._entries.get(key)

    def __len__(self) -> int:
        return len(self._entries)

    # -- appends ------------------------------------------------------------

    def _append(self, entry: dict) -> None:
        line = json.dumps(entry, default=str) + "\n"
        with self._write_lock:
            self._entries[entry["key"]] = entry
            with open(self.path, "a") as f:
                f.write(line)
                f.flush()
                os.fsync(f.fileno())

    def append_row(self, key: str, variant: str, point, record) -> None:
        self._append({
            "v": self.VERSION, "key": key, "kind": "row",
            "variant": variant, "label": point.label,
            "record": dataclasses.asdict(record),
        })

    def append_failure(self, key: str, variant: str, point, failure) -> None:
        self._append({
            "v": self.VERSION, "key": key, "kind": "failure",
            "variant": variant, "label": point.label,
            "failure": failure.as_dict(),
        })
