"""Application-derived access patterns: registry workloads mined from HLO.

AdaptMemBench's premise is emulating *application-specific* access
patterns, yet the registry's patterns are hand-declared. This module
closes that premise end-to-end against the repo's real applications:

1. **Extract** — compile tiny-config forwards of the actual models
   (``models/attention.py`` flash-style chunked attention,
   ``models/moe.py`` top-k expert dispatch, ``models/lm.py`` with its
   embedding gather) and the ``launch/steps.py`` train step, then run
   ``compiled.cost_analysis()`` plus the ``launch/hlo_analysis`` text
   parser (``analyze_memory_ops``: trip-weighted per-opcode result
   traffic) over ``compiled.as_text()``.
2. **Classify** — bucket each dominant op into an access shape:
   attention's strided KV-chunk reads (``dynamic-slice``/``dot`` inside
   the KV scan), MoE's value-dependent gather + scatter-add expert
   dispatch, the LM embedding ``gather``, the train step's elementwise
   update streams.
3. **Synthesize** — emit :class:`~repro.core.PatternSpec` entries that
   replay those shapes at tunable working-set sizes through the
   existing three-regime lowering: affine shapes (attention KV stream,
   optimizer update) ride the strided-parametric path; value-dependent
   shapes (expert dispatch, embedding lookup) ride the
   ``PatternSpec.kernel``/``oracle`` hook, exactly like
   ``pointer_chase``.

Every synthesized spec carries ``PatternSpec.derived = {source_model,
source_op, access_class, feature_vector}``; drivers merge it into each
record's ``extra["derived"]``. The feature vector is
architecture-independent (cf. arXiv 2003.06064): **stride entropy**
(Shannon entropy of the address-delta distribution of the replayed
index trace), **reuse distance** (log2 mean access distance between
repeated addresses; 0 when nothing is reused), and **gather fraction**
(indexed bytes / total op bytes, straight from the mined HLO) — so
hand-written and application-derived records classify across origins.

Extraction is memoized per process; registering the workloads is pure
data, and nothing compiles a model until a derived pattern factory is
first staged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping

import numpy as np

from repro.core import DriverConfig, PatternSpec
from repro.core.domain import Affine, domain
from repro.core.pattern import Access, DataSpace, Statement
from repro.launch.hlo_analysis import OpTraffic, analyze_memory_ops

from .axes import SweepPlan, env_axis
from .registry import register
from .workload import VariantSpec, Workload

__all__ = [
    "DERIVED_MODELS",
    "DerivedSpec",
    "attention_kv_pattern",
    "derive_spec",
    "derived_report",
    "feature_vector",
    "lm_embed_pattern",
    "model_traffic",
    "moe_dispatch_pattern",
    "register_derived",
    "train_update_pattern",
]

# workload name -> (source model, access class it replays)
DERIVED_MODELS: dict[str, tuple[str, str]] = {
    "derived_attention_kv": ("attention", "strided"),
    "derived_moe_dispatch": ("moe", "gather_scatter"),
    "derived_lm_embed": ("lm", "gather"),
    "derived_train_update": ("train", "stream"),
}

_TRACE_N = 2048          # nominal working set for the feature-vector trace


# ---------------------------------------------------------------------------
# 1. Extraction — compile the real applications, mine their HLO
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelTraffic:
    """One compiled application's mined memory behavior."""

    model: str
    flops: float
    bytes_accessed: float
    ops: Mapping[str, OpTraffic]
    meta: tuple[tuple[str, int], ...]   # traced-config facts, hashable

    def meta_value(self, key: str) -> int:
        return dict(self.meta)[key]


def _trace_attention():
    import functools as ft

    import jax.numpy as jnp

    from repro.models.attention import chunked_attention

    B, Sq, H, Hkv, D, Sk = 1, 64, 4, 2, 16, 128
    kv_chunk = q_chunk = 32
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, Hkv, D)), jnp.float32)
    fn = ft.partial(chunked_attention, causal=True, kv_chunk=kv_chunk,
                    q_chunk=q_chunk)
    meta = (("n_heads", H), ("n_kv_heads", Hkv), ("head_dim", D),
            ("kv_chunk", kv_chunk), ("q_passes", Sq // q_chunk),
            ("seq", Sk))
    return fn, (q, k, v), meta


def _trace_moe():
    import jax
    import jax.numpy as jnp

    from repro.config.base import MoEConfig
    from repro.models.moe import moe_apply, moe_init

    moe_cfg = MoEConfig(n_routed=8, n_shared=1, top_k=2, d_ff_expert=16)
    d, B, S = 32, 1, 32
    p = moe_init(jax.random.PRNGKey(0), d, moe_cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)

    def fn(p, x):
        return moe_apply(p, x, moe_cfg, par=None)[0]

    meta = (("n_experts", moe_cfg.n_routed), ("top_k", moe_cfg.top_k),
            ("d_ff_expert", moe_cfg.d_ff_expert), ("tokens", B * S))
    return fn, (p, x), meta


def _micro_lm_config():
    from repro.config.base import ArchConfig

    return ArchConfig(
        name="derived-micro", family="dense", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=128, head_dim=16,
    )


def _trace_lm():
    import jax
    import jax.numpy as jnp

    from repro.models import lm

    cfg = _micro_lm_config()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(
        np.random.default_rng(2).integers(0, cfg.vocab_size, (1, 32)),
        jnp.int32)

    def fn(params, tokens):
        return lm.apply(params, cfg, tokens=tokens)[0]

    meta = (("vocab_size", cfg.vocab_size), ("d_model", cfg.d_model),
            ("seq", 32))
    return fn, (params, tokens), meta


def _trace_train():
    import jax
    import jax.numpy as jnp

    from repro.launch.steps import make_train_step
    from repro.models import lm
    from repro.optim import adamw

    cfg = _micro_lm_config()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw()
    state = {"params": params, "opt": opt.init(params)}
    toks = np.random.default_rng(3).integers(0, cfg.vocab_size, (2, 16))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(toks, jnp.int32)}
    fn = make_train_step(cfg, None, opt, num_microbatches=1)
    # optimizer state streams the update touches besides params + grads
    meta = (("update_streams", 4), ("d_model", cfg.d_model),
            ("n_layers", cfg.n_layers))
    return fn, (state, batch), meta


_TARGETS = {
    "attention": _trace_attention,
    "moe": _trace_moe,
    "lm": _trace_lm,
    "train": _trace_train,
}


@functools.lru_cache(maxsize=None)
def model_traffic(model: str) -> ModelTraffic:
    """Compile the named application at a tiny config and mine its HLO.

    ``cost_analysis()`` supplies whole-program flops/bytes (scan bodies
    once); ``analyze_memory_ops`` supplies the trip-weighted per-opcode
    result traffic the classifier works from. Memoized — the suite pays
    one compile per application per process.
    """
    import jax

    if model not in _TARGETS:
        raise KeyError(f"no extraction target {model!r}; "
                       f"have {sorted(_TARGETS)}")
    fn, args, meta = _TARGETS[model]()
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(sum(v for k, v in ca.items()
                       if str(k).startswith("bytes accessed")))
    ops = analyze_memory_ops(compiled.as_text())
    return ModelTraffic(model, flops, nbytes, ops, tuple(meta))


# ---------------------------------------------------------------------------
# 2. Classification — dominant ops and the feature vector
# ---------------------------------------------------------------------------

# value-dependent (indexed) access opcodes vs affine strided/stream ones
_INDEXED_OPS = ("gather", "scatter", "dynamic-update-slice")
_STRIDED_OPS = ("dynamic-slice", "dot", "convolution", "slice")
_STREAM_OPS = ("add", "multiply", "subtract", "divide", "reduce", "copy")

_CLASS_PREFERENCE = {
    "gather": ("gather",),
    "scatter": ("scatter",),
    "gather_scatter": ("gather", "scatter"),
    "strided": _STRIDED_OPS,
    "stream": _STREAM_OPS,
}


def _dominant_op(ops: Mapping[str, OpTraffic], preferred) -> str:
    """The highest-traffic opcode among ``preferred`` (falling back to
    any op) — the ``source_op`` stamped on derived records."""
    pool = [o for o in preferred if o in ops]
    if not pool:
        pool = list(ops)
    if not pool:
        return "unknown"
    return max(pool, key=lambda o: ops[o].result_bytes)


def _indexed_fraction(ops: Mapping[str, OpTraffic]) -> float:
    total = sum(t.result_bytes for t in ops.values())
    if total <= 0:
        return 0.0
    indexed = sum(ops[o].result_bytes for o in _INDEXED_OPS if o in ops)
    return indexed / total


def _entropy_bits(deltas: np.ndarray) -> float:
    """Shannon entropy (bits) of the address-delta distribution."""
    if deltas.size == 0:
        return 0.0
    _, counts = np.unique(deltas, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())


def _reuse_distance(trace: np.ndarray) -> float:
    """log2 of the mean access distance between repeats (0 = no reuse)."""
    last: dict[int, int] = {}
    dists = []
    for t, a in enumerate(trace.tolist()):
        if a in last:
            dists.append(t - last[a])
        last[a] = t
    if not dists:
        return 0.0
    return float(np.log2(np.mean(dists)))


def _index_trace(model: str, access_class: str,
                 traffic: ModelTraffic, n: int = _TRACE_N) -> np.ndarray:
    """The element-index trace of the dominant read stream the derived
    pattern replays at working set ``n`` — deterministic per (model,
    config), so the feature vector is too."""
    meta = dict(traffic.meta)
    if access_class == "strided":
        # r query passes re-streaming the head-strided KV cache
        sk = max(1, meta.get("n_kv_heads", 1))
        r = max(2, meta.get("q_passes", 2))
        return np.tile(np.arange(n, dtype=np.int64) * sk, r)
    if access_class == "gather_scatter":
        # expert dispatch: every token gathered once per selecting
        # expert, visited in expert-major (dispatch) order
        e = max(2, meta.get("n_experts", 8))
        k = max(1, meta.get("top_k", 2))
        rng = np.random.default_rng(0xD15A ^ n)
        assign = rng.integers(0, e, size=(n, k))
        toks = np.tile(np.arange(n, dtype=np.int64)[:, None], (1, k))
        order = np.argsort(assign.ravel(), kind="stable")
        return toks.ravel()[order]
    if access_class == "gather":
        # embedding lookups: zipf-skewed rows of the table
        rng = np.random.default_rng(0x3E6 ^ n)
        return ((rng.zipf(1.5, size=n) - 1) % n).astype(np.int64)
    # stream: the optimizer update's interleaved param/grad/moment reads
    s = max(2, meta.get("update_streams", 3))
    base = np.arange(n, dtype=np.int64)[:, None]
    return (base + n * np.arange(s, dtype=np.int64)[None, :]).ravel()


def feature_vector(model: str, access_class: str,
                   traffic: ModelTraffic) -> tuple[tuple[str, float], ...]:
    """The architecture-independent nest descriptor (arXiv 2003.06064):
    stride entropy + reuse distance from the replayed index trace,
    gather fraction straight from the mined per-op HLO traffic."""
    trace = _index_trace(model, access_class, traffic)
    return (
        ("stride_entropy", round(_entropy_bits(np.diff(trace)), 6)),
        ("reuse_distance", round(_reuse_distance(trace), 6)),
        ("gather_fraction", round(_indexed_fraction(traffic.ops), 6)),
    )


@dataclasses.dataclass(frozen=True)
class DerivedSpec:
    """Classified + synthesized description of one mined access shape."""

    model: str
    access_class: str
    source_op: str
    params: tuple[tuple[str, int], ...]
    feature_vector: tuple[tuple[str, float], ...]

    def param(self, key: str) -> int:
        return dict(self.params)[key]

    def stamp(self) -> dict:
        """The ``PatternSpec.derived`` / ``extra["derived"]`` payload."""
        return {
            "source_model": self.model,
            "source_op": self.source_op,
            "access_class": self.access_class,
            "feature_vector": dict(self.feature_vector),
        }


@functools.lru_cache(maxsize=None)
def derive_spec(model: str, access_class: str) -> DerivedSpec:
    """Extract + classify one application's shape (memoized)."""
    traffic = model_traffic(model)
    meta = dict(traffic.meta)
    source_op = _dominant_op(traffic.ops,
                             _CLASS_PREFERENCE[access_class])
    params = {
        "kv_stride": max(1, meta.get("n_kv_heads", 1)),
        "n_experts": max(2, meta.get("n_experts", 8)),
        "top_k": max(1, meta.get("top_k", 2)),
        "update_streams": max(2, meta.get("update_streams", 3)),
    }
    return DerivedSpec(
        model=model,
        access_class=access_class,
        source_op=source_op,
        params=tuple(sorted(params.items())),
        feature_vector=feature_vector(model, access_class, traffic),
    )


# ---------------------------------------------------------------------------
# 3. Synthesis — PatternSpecs replaying the mined shapes
# ---------------------------------------------------------------------------


def _randf(seed: int):
    """Position-stable pseudo-random floats in [0, 1): the value at index
    ``i`` is independent of the allocation size, so capacity-allocated
    parametric arrays agree with rung-allocated specialized ones."""
    def init(i):
        h = (i * 1103515245 + seed) % 1000003
        return (h / 1000003.0).astype(np.float32)
    return init


def attention_kv_pattern() -> PatternSpec:
    """Attention's strided KV reads as an affine nest: one query block
    streaming the K and V caches at the head-group stride (consecutive
    reads of one KV head are ``n_kv_heads`` rows apart in a
    (seq, heads, dim) cache), writing the attention state. Pure strided
    reads -> a fresh output, so the nest is eligible for every
    parametric regime including strided (a read of the write space
    would demote it to gather)."""
    spec = derive_spec("attention", "strided")
    sk = spec.param("kv_stride")
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("K", (i * sk,)), Access("V", (i * sk,))),
        write=Access("A", ("i",)),
        combine=lambda vals, env: vals[0] * 0.125 + vals[1],
    )
    return PatternSpec(
        "derived_attention_kv",
        (
            DataSpace("K", (Affine.of("n") * sk,), "float32", _randf(11)),
            DataSpace("V", (Affine.of("n") * sk,), "float32", _randf(13)),
            DataSpace("A", ("n",), "float32", 0.0),
        ),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=2,
        derived=spec.stamp(),
    )


def _route_perm(n_experts: int):
    """Expert-major dispatch order: tokens sorted by their (deterministic
    pseudo-random) expert assignment — a permutation, so the replayed
    scatter-add has no duplicate-index float-ordering hazard."""
    def init(i):
        n = int(i.shape[0])
        rng = np.random.default_rng(0xD15A ^ n)
        experts = rng.integers(0, n_experts, size=n)
        return np.argsort(experts, kind="stable").astype(np.int32)
    return init


def _dispatch_kernel(pattern: PatternSpec, env: Mapping[str, int]):
    def step(arrays):
        arrays = dict(arrays)
        r = arrays["R"]
        xg = arrays["X"][r]                       # dispatch: token gather
        arrays["O"] = arrays["O"].at[r].add(xg)   # combine: scatter-add
        return arrays
    return step


def _dispatch_oracle(pattern: PatternSpec, arrays: Mapping[str, np.ndarray],
                     env: Mapping[str, int], ntimes: int) -> dict:
    out = {k: np.array(v) for k, v in arrays.items()}
    r = out["R"]
    for _ in range(int(ntimes)):
        np.add.at(out["O"], r, out["X"][r])
    return out


def moe_dispatch_pattern() -> PatternSpec:
    """MoE expert dispatch as a value-dependent kernel: gather every
    token in expert-major routing order, then scatter-add the expert
    outputs back — ``jnp.take`` + ``.at[].add``, the exact ops mined
    from ``moe_apply``'s compiled HLO. Rides the ``kernel``/``oracle``
    hook (non-affine indices can't lower through the strided regime)."""
    spec = derive_spec("moe", "gather_scatter")
    stmt = Statement(
        reads=(Access("X", ("i",)), Access("R", ("i",)),
               Access("O", ("i",))),
        write=Access("O", ("i",)),
        combine=lambda vals, env: vals[2] + vals[0],
    )
    return PatternSpec(
        "derived_moe_dispatch",
        (
            DataSpace("X", ("n",), "float32", _randf(17)),
            DataSpace("R", ("n",), "int32",
                      _route_perm(spec.param("n_experts"))),
            DataSpace("O", ("n",), "float32", 0.0),
        ),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=1,
        kernel=_dispatch_kernel,
        oracle=_dispatch_oracle,
        derived=spec.stamp(),
    )


def _zipf_ids():
    def init(i):
        n = int(i.shape[0])
        rng = np.random.default_rng(0x3E6 ^ n)
        return ((rng.zipf(1.5, size=n) - 1) % n).astype(np.int32)
    return init


def _embed_kernel(pattern: PatternSpec, env: Mapping[str, int]):
    def step(arrays):
        arrays = dict(arrays)
        arrays["O"] = arrays["T"][arrays["I"]]    # embedding row gather
        return arrays
    return step


def _embed_oracle(pattern: PatternSpec, arrays: Mapping[str, np.ndarray],
                  env: Mapping[str, int], ntimes: int) -> dict:
    out = {k: np.array(v) for k, v in arrays.items()}
    out["O"] = out["T"][out["I"]]
    return out


def lm_embed_pattern() -> PatternSpec:
    """The LM embedding gather: zipf-skewed token ids pulling rows from
    the table — the ``gather`` op mined from ``lm.apply``'s HLO, with
    the natural-text hot-row reuse a uniform pick would miss."""
    spec = derive_spec("lm", "gather")
    stmt = Statement(
        reads=(Access("T", ("i",)), Access("I", ("i",))),
        write=Access("O", ("i",)),
        combine=lambda vals, env: vals[0],
    )
    return PatternSpec(
        "derived_lm_embed",
        (
            DataSpace("T", ("n",), "float32", _randf(19)),
            DataSpace("I", ("n",), "int32", _zipf_ids()),
            DataSpace("O", ("n",), "float32", 0.0),
        ),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=0,
        kernel=_embed_kernel,
        oracle=_embed_oracle,
        derived=spec.stamp(),
    )


def train_update_pattern() -> PatternSpec:
    """The train step's optimizer update as unit-stride streams: read
    param + grad + moment, write the updated params — the dominant
    elementwise traffic of the mined train-step HLO. jax train steps
    are functional (new param arrays, never in-place), so the
    read-3-streams / write-a-fresh-one shape is the faithful replay —
    and it keeps the nest strided-eligible."""
    spec = derive_spec("train", "stream")
    stmt = Statement(
        reads=(Access("P", ("i",)), Access("G", ("i",)),
               Access("M", ("i",))),
        write=Access("U", ("i",)),
        combine=lambda vals, env:
            vals[0] - 3e-4 * (0.9 * vals[2] + 0.1 * vals[1]),
    )
    return PatternSpec(
        "derived_train_update",
        (
            DataSpace("P", ("n",), "float32", _randf(23)),
            DataSpace("G", ("n",), "float32", _randf(29)),
            DataSpace("M", ("n",), "float32", _randf(31)),
            DataSpace("U", ("n",), "float32", 0.0),
        ),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=3,
        derived=spec.stamp(),
    )


_PATTERNS = {
    "derived_attention_kv": attention_kv_pattern,
    "derived_moe_dispatch": moe_dispatch_pattern,
    "derived_lm_embed": lm_embed_pattern,
    "derived_train_update": train_update_pattern,
}


# ---------------------------------------------------------------------------
# 4. Registration + ledger report
# ---------------------------------------------------------------------------

# independent template: single-band nests, so the auto policy keeps the
# affine replays on the strided-parametric regime (unified programs>1
# would split the outer band onto gather)
_AFFINE_CFG = DriverConfig(template="independent", programs=4, ntimes=4,
                           reps=2, validate_n=64)
_KERNEL_CFG = DriverConfig(template="unified", programs=1, ntimes=2,
                           reps=2, validate_n=64)

_DERIVED_PLAN = SweepPlan.product(
    env_axis((1 << 10, 1 << 14, 1 << 17),
             (1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20)),
)


def register_derived() -> None:
    """Register the application-derived workloads (idempotent; nothing
    compiles until a pattern factory is staged)."""
    register(Workload(
        name="derived_attention_kv",
        figure="derived",
        title="attention-derived strided KV stream (mined from HLO)",
        tags=("derived", "app"),
        pattern=lambda env: attention_kv_pattern(),
        variants=(VariantSpec("replay", _AFFINE_CFG),),
        plan=_DERIVED_PLAN,
    ))
    register(Workload(
        name="derived_moe_dispatch",
        figure="derived",
        title="MoE-derived expert dispatch gather/scatter (mined from HLO)",
        tags=("derived", "app"),
        pattern=lambda env: moe_dispatch_pattern(),
        variants=(VariantSpec("replay", _KERNEL_CFG),),
        plan=_DERIVED_PLAN,
        parametric=False,       # custom kernel: env is baked into the step
    ))
    register(Workload(
        name="derived_lm_embed",
        figure="derived",
        title="LM-derived embedding gather, zipf ids (mined from HLO)",
        tags=("derived", "app"),
        pattern=lambda env: lm_embed_pattern(),
        variants=(VariantSpec("replay", _KERNEL_CFG),),
        plan=_DERIVED_PLAN,
        parametric=False,
    ))
    register(Workload(
        name="derived_train_update",
        figure="derived",
        title="train-step-derived optimizer update streams (mined from HLO)",
        tags=("derived", "app"),
        pattern=lambda env: train_update_pattern(),
        variants=(VariantSpec("replay", _AFFINE_CFG),),
        plan=_DERIVED_PLAN,
    ))


def derived_report(names=None) -> dict:
    """Per-workload provenance block for the perf ledger: source model,
    mined source op, access class, and the feature vector. ``names``
    restricts to workloads that actually ran (avoids compiling
    applications just to report on workloads the run skipped)."""
    out: dict[str, dict] = {}
    for name, (model, access_class) in DERIVED_MODELS.items():
        if names is not None and name not in names:
            continue
        out[name] = derive_spec(model, access_class).stamp()
    return out
