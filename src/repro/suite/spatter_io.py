"""Trace-driven Spatter pattern replay (arXiv 1811.03743).

Spatter captures application gather/scatter behaviour as JSON pattern
files; AdaptMemBench's thesis is that such captured patterns should
*replay through the same drivers* as the synthetic suite. This module is
the bridge: it parses the three Spatter pattern forms —

``UNIFORM:<len>:<stride>``
    constant-stride runs (Spatter's ``-p UNIFORM:8:4``),
``MS1:<len>:<breaks>:<gaps>``
    mostly-stride-1 runs with gap jumps at break positions
    (``MS1:16:4,8,12:32``), and
explicit JSON index lists
    (``"pattern": [0, 8, 2, 8, 33]``),

into :class:`SpatterPattern` records, then lowers each onto the cheapest
viable regime: patterns whose full replay trace ``I[k] = indices[k % L]
+ delta * (k // L)`` is affine in ``k`` become ordinary strided
:class:`PatternSpec`s (riding the parametric / Pallas fast paths), while
value-dependent traces ride the ``PatternSpec.kernel`` hook with a bound
index space and a numpy index-replay oracle — the same escape hatch the
pointer chase uses. Every produced spec carries ``trace`` provenance
(``{source, pattern_hash, form}``) which the drivers stamp into each
record's ``extra["trace"]``, so a measurement stays attributable to the
JSON file (and the exact index sequence) it came from.

Malformed files fail with :class:`SpatterParseError` carrying a stable
``reason`` slug — a typed rejection, never a stack trace from deep
inside numpy.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Mapping, Sequence

import numpy as np

from repro.core import (
    Access,
    Affine,
    DataSpace,
    DriverConfig,
    PatternSpec,
    Record,
    Statement,
    domain,
)

from .axes import SweepPlan, env_axis
from .journal import stable_fingerprint
from .registry import register
from .workload import VariantSpec, Workload

__all__ = [
    "MAX_PATTERN_LEN",
    "SpatterParseError",
    "SpatterPattern",
    "parse_spatter",
    "load_spatter",
    "replay_exact",
    "trace_workload",
    "trace_report",
    "register_trace",
]

# Refuse pathological captures before allocating anything: one pattern
# entry may not exceed 2^20 indices.
MAX_PATTERN_LEN = 1 << 20

_KERNELS = ("gather", "scatter")


class SpatterParseError(ValueError):
    """A rejected Spatter JSON file.

    ``reason`` is a stable slug (``invalid_json``, ``bad_entry``,
    ``unknown_kernel``, ``bad_pattern``, ``bad_ms1``,
    ``negative_index``, ``empty_pattern``, ``oversized``) so callers and
    tests can branch on the failure class without string-matching the
    human message.
    """

    def __init__(self, reason: str, message: str, source: str = "<string>",
                 entry: int | None = None):
        where = source if entry is None else f"{source}[{entry}]"
        super().__init__(f"{where}: {message} [{reason}]")
        self.reason = reason
        self.source = source
        self.entry = entry


def _want_int(val: object, what: str, source: str, entry: int | None,
              reason: str = "bad_pattern") -> int:
    if isinstance(val, bool) or not isinstance(val, int):
        if isinstance(val, str):
            try:
                return int(val, 10)
            except ValueError:
                pass
        raise SpatterParseError(
            reason, f"{what} must be an integer, got {val!r}", source, entry)
    return int(val)


def _ints_field(text: str, what: str, source: str, entry: int | None,
                reason: str = "bad_pattern") -> list[int]:
    return [_want_int(p.strip(), what, source, entry, reason)
            for p in text.split(",") if p.strip() != ""]


@dataclasses.dataclass(frozen=True)
class SpatterPattern:
    """One parsed Spatter pattern entry, replayable through the suite."""

    source: str                  # file path or caller-supplied tag
    entry: int                   # position in the JSON file
    kernel: str                  # "gather" | "scatter"
    form: str                    # "uniform" | "ms1" | "index"
    indices: tuple[int, ...]     # one period of the index pattern
    delta: int                   # per-period base advance (Spatter -d)
    count: int = 1               # informational (Spatter -l)

    @property
    def length(self) -> int:
        return len(self.indices)

    @property
    def affine_stride(self) -> tuple[int, int] | None:
        """``(stride, offset)`` when the *full replay trace* is affine.

        The trace ``I[k] = indices[k % L] + delta * (k // L)`` collapses
        to ``offset + k * stride`` iff the within-period diffs are one
        constant ``d >= 1`` AND the period-wrap diff
        ``indices[0] + delta - indices[-1]`` equals the same ``d``.
        """
        idx = self.indices
        if len(idx) == 1:
            d = self.delta
            return (d, idx[0]) if d >= 1 else None
        diffs = {idx[j + 1] - idx[j] for j in range(len(idx) - 1)}
        if len(diffs) != 1:
            return None
        d = diffs.pop()
        if d < 1 or idx[0] + self.delta - idx[-1] != d:
            return None
        return (d, idx[0])

    @property
    def pattern_hash(self) -> str:
        """Process-stable content hash of the replayed index semantics."""
        return stable_fingerprint({
            "kernel": self.kernel, "form": self.form,
            "indices": self.indices, "delta": self.delta,
        })

    @property
    def trace_stamp(self) -> dict[str, str]:
        """The provenance dict stamped into ``extra["trace"]``."""
        return {"source": self.source, "pattern_hash": self.pattern_hash,
                "form": self.form}

    def replay_indices(self, n: int) -> np.ndarray:
        """The exact index trace of one ``n``-point sweep, wrapped into a
        target space of ``n`` elements (the value-dependent regime)."""
        k = np.arange(int(n), dtype=np.int64)
        idx = np.asarray(self.indices, dtype=np.int64)
        L = len(idx)
        return ((idx[k % L] + self.delta * (k // L)) % int(n)).astype(np.int64)

    def pattern_spec(self) -> PatternSpec:
        """Lower onto the cheapest viable regime: an ordinary strided
        spec when the trace is affine, else a bound-index kernel spec
        with a numpy replay oracle."""
        name = f"trace_{self.kernel}_{self.form}_{self.pattern_hash[:8]}"
        aff = self.affine_stride
        if aff is not None:
            return _affine_spec(self.kernel, *aff, name=name,
                                trace=self.trace_stamp)
        return _replay_spec(self, name=name, trace=self.trace_stamp)


def _affine_spec(kind: str, stride: int, offset: int, *, name: str,
                 trace: Mapping[str, str]) -> PatternSpec:
    """Strided gather/scatter with a base offset: the affine regime."""
    i = Affine.of("i")
    sub = i * stride + offset if offset else i * stride
    # S must cover offset + (n-1)*stride; n*stride + (offset-stride+1)
    # is exact and stays affine in n.
    tail = offset - stride + 1
    sshape = Affine.of("n") * stride + tail if tail else Affine.of("n") * stride
    if kind == "gather":
        stmt = Statement(
            reads=(Access("S", (sub,)),),
            write=Access("D", (i,)),
            combine=lambda vals, env: vals[0],
        )
        spaces = (
            DataSpace("D", ("n",), "float32", 0.0),
            DataSpace("S", (sshape,), "float32",
                      lambda i: (i % 23).astype(np.float32)),
        )
    else:
        stmt = Statement(
            reads=(Access("D", (i,)),),
            write=Access("S", (sub,)),
            combine=lambda vals, env: vals[0],
        )
        spaces = (
            DataSpace("D", ("n",), "float32",
                      lambda i: (i % 19).astype(np.float32)),
            DataSpace("S", (sshape,), "float32", 0.0),
        )
    return PatternSpec(name, spaces, stmt, domain(("i", 0, "n")),
                       flops_per_point=0, trace=dict(trace))


def _trace_kernel(kind: str):
    def kernel(pattern: PatternSpec, env: Mapping[str, int]):
        def step(arrays):
            arrays = dict(arrays)
            if kind == "gather":
                arrays["D"] = arrays["S"][arrays["I"]]
            else:
                arrays["S"] = arrays["S"].at[arrays["I"]].add(arrays["D"])
            return arrays
        return step
    return kernel


def _trace_oracle(kind: str):
    def oracle(pattern: PatternSpec, arrays: Mapping[str, np.ndarray],
               env: Mapping[str, int], ntimes: int) -> dict:
        out = {k: np.array(v) for k, v in arrays.items()}
        for _ in range(int(ntimes)):
            if kind == "gather":
                out["D"] = out["S"][out["I"]]
            else:
                np.add.at(out["S"], out["I"], out["D"])
        return out
    return oracle


def _replay_spec(sp: SpatterPattern, *, name: str,
                 trace: Mapping[str, str]) -> PatternSpec:
    """Value-dependent regime: the replayed index trace is bound into an
    ``I`` space at allocation time; a custom kernel performs the
    indirection (``D = S[I]`` / ``S[I] += D``) and the oracle replays it
    in numpy. The statement is the nominal 12 B/point accounting (index
    read + payload read + payload write)."""
    idx = np.asarray(sp.indices, dtype=np.int64)
    L = len(idx)
    delta = int(sp.delta)

    def init_indices(i: np.ndarray) -> np.ndarray:
        return ((idx[i % L] + delta * (i // L)) % len(i)).astype(np.int32)

    if sp.kernel == "gather":
        stmt = Statement(
            reads=(Access("S", ("i",)), Access("I", ("i",))),
            write=Access("D", ("i",)),
            combine=lambda vals, env: vals[0],
        )
        payload = (
            DataSpace("D", ("n",), "float32", 0.0),
            DataSpace("S", ("n",), "float32",
                      lambda i: (i % 23).astype(np.float32)),
        )
    else:
        stmt = Statement(
            reads=(Access("D", ("i",)), Access("I", ("i",))),
            write=Access("S", ("i",)),
            combine=lambda vals, env: vals[0],
        )
        payload = (
            DataSpace("D", ("n",), "float32",
                      lambda i: (i % 19).astype(np.float32)),
            DataSpace("S", ("n",), "float32", 0.0),
        )
    spaces = payload + (DataSpace("I", ("n",), "int32", init_indices),)
    return PatternSpec(name, spaces, stmt, domain(("i", 0, "n")),
                       flops_per_point=0,
                       kernel=_trace_kernel(sp.kernel),
                       oracle=_trace_oracle(sp.kernel),
                       trace=dict(trace))


# -- the parser --------------------------------------------------------------

def _parse_pattern_string(pat: str, source: str, entry: int
                          ) -> tuple[str, list[int]]:
    parts = pat.split(":")
    head = parts[0].strip().upper()
    if head == "UNIFORM":
        if len(parts) != 3:
            raise SpatterParseError(
                "bad_pattern", f"UNIFORM takes 2 fields, got {pat!r}",
                source, entry)
        length = _want_int(parts[1].strip(), "UNIFORM length", source, entry)
        stride = _want_int(parts[2].strip(), "UNIFORM stride", source, entry)
        if stride < 0:
            raise SpatterParseError(
                "negative_index", f"negative stride {stride}", source, entry)
        if length < 1:
            raise SpatterParseError(
                "empty_pattern", f"UNIFORM length {length} < 1", source, entry)
        if length > MAX_PATTERN_LEN:
            raise SpatterParseError(
                "oversized", f"UNIFORM length {length} exceeds "
                f"MAX_PATTERN_LEN={MAX_PATTERN_LEN}", source, entry)
        return "uniform", [j * stride for j in range(length)]
    if head == "MS1":
        if len(parts) != 4:
            raise SpatterParseError(
                "bad_ms1", f"MS1 takes 3 fields, got {pat!r}", source, entry)
        length = _want_int(parts[1].strip(), "MS1 length", source, entry,
                           "bad_ms1")
        breaks = _ints_field(parts[2], "MS1 break", source, entry, "bad_ms1")
        gaps = _ints_field(parts[3], "MS1 gap", source, entry, "bad_ms1")
        if length < 1:
            raise SpatterParseError(
                "empty_pattern", f"MS1 length {length} < 1", source, entry)
        if length > MAX_PATTERN_LEN:
            raise SpatterParseError(
                "oversized", f"MS1 length {length} exceeds "
                f"MAX_PATTERN_LEN={MAX_PATTERN_LEN}", source, entry)
        if not breaks or not gaps:
            raise SpatterParseError(
                "bad_ms1", "MS1 needs at least one break and one gap",
                source, entry)
        if len(gaps) == 1:
            gaps = gaps * len(breaks)
        if len(gaps) != len(breaks):
            raise SpatterParseError(
                "bad_ms1",
                f"{len(breaks)} breaks but {len(gaps)} gaps", source, entry)
        if breaks != sorted(set(breaks)) or breaks[0] < 1 \
                or breaks[-1] >= length:
            raise SpatterParseError(
                "bad_ms1",
                f"breaks must be strictly increasing in [1, {length - 1}], "
                f"got {breaks}", source, entry)
        gap_at = dict(zip(breaks, gaps))
        out = [0]
        for j in range(1, length):
            out.append(out[-1] + gap_at.get(j, 1))
        return "ms1", out
    raise SpatterParseError(
        "bad_pattern", f"unrecognized pattern string {pat!r} "
        "(expected UNIFORM:<len>:<stride>, MS1:<len>:<breaks>:<gaps>, "
        "or an index list)", source, entry)


def _parse_entry(obj: object, entry: int, source: str) -> SpatterPattern:
    if not isinstance(obj, Mapping):
        raise SpatterParseError(
            "bad_entry", f"entry must be an object, got {type(obj).__name__}",
            source, entry)
    kernel = str(obj.get("kernel", "gather")).strip().lower()
    if kernel not in _KERNELS:
        raise SpatterParseError(
            "unknown_kernel", f"kernel {obj.get('kernel')!r} not in "
            f"{_KERNELS}", source, entry)
    pat = obj.get("pattern")
    if pat is None:
        raise SpatterParseError(
            "bad_entry", "entry has no 'pattern' field", source, entry)
    if isinstance(pat, str):
        form, indices = _parse_pattern_string(pat, source, entry)
    elif isinstance(pat, Sequence):
        form = "index"
        indices = [_want_int(v, "pattern index", source, entry) for v in pat]
    else:
        raise SpatterParseError(
            "bad_pattern", f"pattern must be a string or list, got "
            f"{type(pat).__name__}", source, entry)
    if not indices:
        raise SpatterParseError(
            "empty_pattern", "pattern has no indices", source, entry)
    if len(indices) > MAX_PATTERN_LEN:
        raise SpatterParseError(
            "oversized", f"pattern length {len(indices)} exceeds "
            f"MAX_PATTERN_LEN={MAX_PATTERN_LEN}", source, entry)
    neg = [v for v in indices if v < 0]
    if neg:
        raise SpatterParseError(
            "negative_index", f"negative indices {neg[:4]}", source, entry)
    if "delta" in obj:
        delta = _want_int(obj["delta"], "delta", source, entry, "bad_entry")
        if delta < 0:
            raise SpatterParseError(
                "negative_index", f"negative delta {delta}", source, entry)
    elif form == "uniform":
        # the natural seamless continuation of a constant-stride run
        delta = indices[-1] - indices[0] + (indices[1] - indices[0]
                                            if len(indices) > 1 else 1)
    else:
        delta = max(indices) + 1
    count = _want_int(obj.get("count", 1), "count", source, entry, "bad_entry")
    return SpatterPattern(source=source, entry=entry, kernel=kernel,
                          form=form, indices=tuple(indices), delta=delta,
                          count=max(1, count))


def parse_spatter(text: str, source: str = "<string>"
                  ) -> tuple[SpatterPattern, ...]:
    """Parse Spatter JSON text into :class:`SpatterPattern` records.

    Accepts the standard top-level list of entries (or a single bare
    entry object). Raises :class:`SpatterParseError` with a stable
    ``reason`` slug on any malformed input.
    """
    try:
        doc = json.loads(text)
    except json.JSONDecodeError as e:
        raise SpatterParseError(
            "invalid_json", f"not valid JSON: {e}", source) from None
    if isinstance(doc, Mapping):
        doc = [doc]
    if not isinstance(doc, list):
        raise SpatterParseError(
            "bad_entry", f"top level must be a list of pattern entries, "
            f"got {type(doc).__name__}", source)
    if not doc:
        raise SpatterParseError(
            "empty_pattern", "file contains no pattern entries", source)
    return tuple(_parse_entry(obj, k, source) for k, obj in enumerate(doc))


def load_spatter(path: str | Path) -> tuple[SpatterPattern, ...]:
    """Parse a Spatter JSON pattern file from disk."""
    p = Path(path)
    try:
        text = p.read_text()
    except OSError as e:
        raise SpatterParseError(
            "bad_entry", f"cannot read pattern file: {e}", str(p)) from None
    return parse_spatter(text, source=str(p))


def replay_exact(sp: SpatterPattern, n: int = 256) -> bool:
    """Bit-exact check: allocate the spec's spaces, run its own oracle
    for one sweep, and compare the moved payload against a direct numpy
    replay of the JSON semantics. Exact equality — pure data movement
    (and ordered accumulation) must not perturb a single bit."""
    spec = sp.pattern_spec()
    env = {"n": int(n)}
    arrays = spec.allocate(env)
    if spec.oracle is not None:
        done = spec.oracle(spec, arrays, env, 1)
        trace = sp.replay_indices(n)
        if sp.kernel == "gather":
            want = np.asarray(arrays["S"])[trace]
            return bool(np.array_equal(np.asarray(done["D"]), want))
        want = np.array(arrays["S"])
        np.add.at(want, trace, np.asarray(arrays["D"]))
        return bool(np.array_equal(np.asarray(done["S"]), want))
    # affine regime: replay the strided statement directly
    from repro.core import identity, serial_oracle
    stride, offset = sp.affine_stride
    done = serial_oracle(spec, identity().lower(spec.domain, env), arrays,
                         env, ntimes=1)
    k = np.arange(int(n), dtype=np.int64)
    if sp.kernel == "gather":
        want = np.asarray(arrays["S"])[k * stride + offset]
        return bool(np.array_equal(np.asarray(done["D"]), want))
    want = np.array(arrays["S"])
    want[k * stride + offset] = np.asarray(arrays["D"])
    return bool(np.array_equal(np.asarray(done["S"]), want))


# -- the registry face -------------------------------------------------------

# every trace workload registered in this process (the builtin plus any
# --pattern-file registrations), for the smoke ledger's trace block
_REGISTERED_TRACES: dict[str, tuple[SpatterPattern, ...]] = {}

def _trace_derived(rec: Record) -> str:
    t = rec.extra.get("trace", {})
    return (f"form={t.get('form', '?')};hash={t.get('pattern_hash', '')[:8]};"
            f"{rec.gbs:.3f}GB/s")


def _trace_config(sp: SpatterPattern) -> DriverConfig:
    """Custom-kernel specs need the unified single-program template;
    affine ones take the ordinary multi-program strided config."""
    if sp.affine_stride is None:
        return DriverConfig(template="unified", programs=1, ntimes=4,
                            reps=2, validate_n=256)
    return DriverConfig(template="unified", programs=4, ntimes=8, reps=2)


def _trace_variants(pats: Sequence[SpatterPattern],
                    labels: Sequence[str] | None = None
                    ) -> tuple[VariantSpec, ...]:
    out = []
    for k, sp in enumerate(pats):
        lbl = labels[k] if labels else f"p{k}_{sp.kernel}_{sp.form}"
        out.append(VariantSpec(lbl, _trace_config(sp),
                               pattern=lambda env, sp=sp: sp.pattern_spec()))
    return tuple(out)


def trace_workload(path: str | Path, name: str | None = None) -> Workload:
    """A replay workload for a user-captured Spatter JSON file — the
    ``benchmarks.run --pattern-file`` path. One variant per pattern
    entry; each rides its regime-appropriate config and the shared
    sweep engine."""
    pats = load_spatter(path)
    wname = name or f"trace_{Path(path).stem}"
    _REGISTERED_TRACES[wname] = pats
    return Workload(
        name=wname,
        figure="trace",
        title=f"trace replay of {Path(path).name} "
              f"({len(pats)} pattern{'s' if len(pats) != 1 else ''})",
        tags=("spatter", "trace"),
        variants=_trace_variants(pats),
        plan=SweepPlan.product(
            env_axis((1 << 10, 1 << 14), (1 << 10, 1 << 14, 1 << 17))),
        derived=_trace_derived,
    )


# The committed built-in capture: an MS1 mixed-stride gather (three gap
# jumps per 16-index period — value-dependent) next to the same file's
# UNIFORM:8:4 entry (affine — rides the strided regime). Identical JSON
# is committed at benchmarks/patterns/spatter_ms1.json for the CLI path.
_BUILTIN_MS1 = """\
[
  {"kernel": "Gather", "pattern": "MS1:16:4,8,12:32", "count": 1024},
  {"kernel": "Gather", "pattern": "UNIFORM:8:4", "count": 1024}
]
"""


def trace_report(names: set[str] | None = None) -> dict:
    """Ledger block for the smoke run: per trace workload, the parsed
    provenance of every pattern entry plus a *live* bit-exact replay
    check (``replay_exact`` against the direct numpy replay of the
    JSON semantics) — the integrity gate ``scripts/ci.sh`` enforces."""
    out: dict = {}
    for wname, pats in _REGISTERED_TRACES.items():
        if names is not None and wname not in names:
            continue
        out[wname] = {
            "source": pats[0].source if pats else None,
            "patterns": [
                {"entry": sp.entry, "kernel": sp.kernel, "form": sp.form,
                 "length": sp.length, "delta": sp.delta,
                 "affine": sp.affine_stride is not None,
                 "pattern_hash": sp.pattern_hash,
                 "bitexact": replay_exact(sp, n=256)}
                for sp in pats
            ],
        }
    return out


def register_trace() -> None:
    """Register the built-in ``spatter_ms1`` trace-replay workload."""
    ms1, uniform = parse_spatter(_BUILTIN_MS1, source="builtin:spatter_ms1")
    _REGISTERED_TRACES["spatter_ms1"] = (ms1, uniform)
    register(Workload(
        name="spatter_ms1",
        figure="trace",
        title="trace-driven Spatter replay: MS1 mixed-stride vs UNIFORM",
        tags=("spatter", "trace"),
        variants=(
            VariantSpec("ms1", _trace_config(ms1),
                        pattern=lambda env, sp=ms1: sp.pattern_spec()),
            VariantSpec("uniform", _trace_config(uniform),
                        pattern=lambda env, sp=uniform: sp.pattern_spec()),
        ),
        plan=SweepPlan.product(
            env_axis((1 << 10, 1 << 14, 1 << 17),
                     (1 << 10, 1 << 14, 1 << 17, 1 << 20))),
        derived=_trace_derived,
    ))
