"""Named working-set ladders shared by every registered workload.

A :class:`Ladder` is pure data: the quick/full measurement points plus an
optional per-point transform mapping a ladder *point* (the label the CSV
reports) to the env ``n`` the driver actually runs (e.g. the Jacobi
interiors run ``n + 2`` so the interior divides the program count).
Workloads reference ladders by value, so the suite has one copy of the
canonical sizes instead of one per ``fig*`` script.

Since the multi-axis engine, a ladder is a thin compatibility wrapper: it
*is* a one-env-axis :class:`~repro.suite.axes.SweepPlan` (see
:meth:`Ladder.plan`), and every workload — ladder-declared or
plan-declared — executes through the same plan engine.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .axes import SweepPlan, env_axis

__all__ = [
    "Ladder",
    "fixed",
    "QUICK_SETS",
    "FULL_SETS",
    "QUICK_GRID",
    "FULL_GRID",
    "WORKING_SETS",
    "INTERIOR_SETS",
    "GRID2",
    "GRID3",
]

# Working-set ladder (elements per stream). On the TPU target these cross
# the VMEM boundary the way the paper's sizes cross L1/L2/L3; on this CPU
# container they cross L1/L2/LLC — the *shape* of the curves is the
# reproduction target, and records carry working_set_bytes + level so the
# table is interpretable on either substrate.
QUICK_SETS = [1 << 10, 1 << 12, 1 << 14, 1 << 17]
FULL_SETS = [1 << 10, 1 << 11, 1 << 12, 1 << 13, 1 << 14, 1 << 16,
             1 << 18, 1 << 20, 1 << 22]

QUICK_GRID = [18, 34]
FULL_GRID = [18, 34, 66, 130]


@dataclasses.dataclass(frozen=True)
class Ladder:
    """A named sequence of measurement points.

    ``points`` are what CSV labels report; ``env_n`` maps a point to the
    driver's working-set parameter (identity unless ``transform`` is
    set). ``transform`` must be a top-level function (or None) so ladder
    values stay hashable and comparable.
    """

    name: str
    quick: tuple[int, ...]
    full: tuple[int, ...]
    transform: Callable[[int], int] | None = None

    def points(self, quick: bool) -> tuple[int, ...]:
        return self.quick if quick else self.full

    def env_n(self, point: int) -> int:
        return self.transform(point) if self.transform else point

    def plan(self) -> SweepPlan:
        """This ladder as a one-env-axis sweep plan (labels stay
        ``n<point>``, envs stay ``transform(point)`` — byte-identical
        CSVs through the plan engine)."""
        return SweepPlan.product(
            env_axis(self.quick, self.full, transform=self.transform)
        )


def fixed(n: int, name: str | None = None) -> Ladder:
    """A single-point ladder (fixed-size experiments like fig07/fig10)."""
    return Ladder(name or f"fixed{n}", (n,), (n,))


def _plus_halo(n: int) -> int:
    # Jacobi interiors must divide by the program count: n = k*programs + 2
    return n + 2


WORKING_SETS = Ladder("working_sets", tuple(QUICK_SETS), tuple(FULL_SETS))
INTERIOR_SETS = Ladder("interior_sets", tuple(QUICK_SETS), tuple(FULL_SETS),
                       transform=_plus_halo)
GRID2 = Ladder("grid2", tuple(QUICK_GRID), tuple(FULL_GRID))
GRID3 = Ladder("grid3", (10, 18), (10, 18, 34, 66))
