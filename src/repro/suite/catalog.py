"""Built-in workload entries — the paper's figures as declarative data.

Each ``register(Workload(...))`` below replaces a hand-rolled
``benchmarks/fig*.py`` script: the pattern, the driver-config variants
being contrasted, the working-set ladder, and the validation policy are
*specified*; the shared runner does everything else. The Spatter-style
``spatter_uniform`` entry is the scenario-diversity proof: a whole new
gather/scatter suite in a dozen declarative lines.

Fully custom experiments (the Pallas tile sweep, the roofline refresh)
register themselves from their ``benchmarks`` modules with a ``runner``.
"""
from __future__ import annotations

from repro.core import (
    DriverConfig,
    Record,
    gather,
    gather_scatter,
    identity,
    jacobi1d,
    jacobi2d,
    jacobi3d,
    nstream,
    scatter,
    triad,
)
from repro.core.measure import NATIVE_TILE_BYTES

from .ladders import GRID2, GRID3, INTERIOR_SETS, WORKING_SETS, fixed
from .registry import register
from .workload import VariantSpec, Workload

_TILE_ELEMS = NATIVE_TILE_BYTES // 4


# -- fig05: cost of implicit barriers ---------------------------------------
# OpenMP's implicit barrier per parallel-for becomes a host sync + dispatch
# per sweep; the `nowait` analogue fuses all sweeps into one fori_loop.

register(Workload(
    name="fig05_barriers",
    figure="fig05",
    title="barrier vs fused (nowait) bandwidth per working set",
    pattern=lambda env: triad(),
    variants=(
        VariantSpec("barrier", DriverConfig(
            template="unified", programs=4, ntimes=16, reps=2,
            sync_every_rep=True)),
        VariantSpec("nowait", DriverConfig(
            template="unified", programs=4, ntimes=16, reps=2)),
    ),
    ladder=WORKING_SETS,
))


# -- fig06: unified vs independent data spaces ------------------------------
# One shared array with schedule(static, n/t) chunks vs per-program
# tile-padded rows (the paper's ~2x-in-L1 layout study).

register(Workload(
    name="fig06_dataspaces",
    figure="fig06",
    title="unified vs independent (tile-padded) data spaces for triad",
    pattern=lambda env: triad(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=16, reps=2)),
        VariantSpec("independent", DriverConfig(
            template="independent", programs=4, ntimes=16, reps=2,
            pad=_TILE_ELEMS)),
    ),
    ladder=WORKING_SETS,
))


# -- fig07: bandwidth vs concurrent read streams ----------------------------
# The paper sweeps 3..20 simultaneously-read arrays (peak at 11 streams);
# the variant list is the sweep axis, each k with its own nstream pattern.

def _fig07_variants(quick: bool) -> tuple[VariantSpec, ...]:
    ks = [1, 2, 3, 5, 7, 11, 15, 20] if quick else list(range(1, 21))
    return tuple(
        VariantSpec(
            f"streams{k}",
            DriverConfig(template="independent", programs=4, ntimes=8,
                         reps=2),
            pattern=lambda env, k=k: nstream(k),
        )
        for k in ks
    )


register(Workload(
    name="fig07_streams",
    figure="fig07",
    title="bandwidth vs number of concurrent data streams",
    variants=_fig07_variants,
    ladder=fixed(1 << 14, "streams_point"),
    validate=False,
))


# -- fig09: the interleaved-triad optimization ------------------------------
# Splitting each array into f simultaneously-accessed blocks (Listing 7)
# through the schedule engine, plus dedicated Pallas kernels as a post.

def _fig09_kernels(quick: bool) -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.core.measure import time_fn
    from repro.kernels import ops

    out = []
    n = 1 << 16
    key = jax.random.PRNGKey(0)
    b = jax.random.normal(key, (n,), jnp.float32)
    c = jax.random.normal(key, (n,), jnp.float32)
    bytes_moved = 3 * n * 4
    t = time_fn(lambda: ops.triad(b, c, block=4096), reps=3)
    out.append(f"fig09/kernel/naive,{t.seconds*1e6:.2f},"
               f"{bytes_moved/t.seconds/1e9:.3f}GB/s")
    for f in (2, 4):
        t = time_fn(lambda f=f: ops.triad_interleaved(b, c, factor=f,
                                                      block=2048), reps=3)
        out.append(f"fig09/kernel/il{f},{t.seconds*1e6:.2f},"
                   f"{bytes_moved/t.seconds/1e9:.3f}GB/s")
    return out


register(Workload(
    name="fig09_interleave",
    figure="fig09",
    title="interleaved triad: schedule engine + dedicated kernels",
    pattern=lambda env: triad(),
    variants=tuple(
        VariantSpec(
            f"engine/il{f}",
            DriverConfig(
                template="independent", programs=2, ntimes=16, reps=2,
                schedule=(identity() if f == 1
                          else identity().interleave("i", f)),
            ),
        )
        for f in (1, 2, 4)
    ),
    ladder=WORKING_SETS,
    post=_fig09_kernels,
))


# -- fig10: counter-based false-sharing diagnosis ---------------------------
# The analytic native-tile traffic model + XLA cost_analysis stand in for
# PAPI's L1-miss / exclusive-line-request counters.

def _fig10_derived(rec: Record) -> str:
    shared = rec.extra.get("shared_write_tiles", -1)
    fetches = rec.extra.get("fetches", -1)
    return f"shared_tiles={shared};fetches={fetches};gbs={rec.gbs:.3f}"


register(Workload(
    name="fig10_counters",
    figure="fig10",
    title="false-sharing counters for three Jacobi-1D layouts",
    pattern=lambda env: jacobi1d(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=4, reps=1,
            measured=True)),
        VariantSpec("indep_unpadded", DriverConfig(
            template="independent", programs=4, ntimes=4, reps=1,
            measured=True)),
        VariantSpec("indep_padded", DriverConfig(
            template="independent", programs=4, ntimes=4, reps=1,
            pad=_TILE_ELEMS, measured=True)),
    ),
    ladder=fixed((1 << 14) + 2, "counters_point"),
    validate=False,
    derived=_fig10_derived,
))


# -- fig12/14/15: the Jacobi family across layouts --------------------------

register(Workload(
    name="fig12_jacobi1d",
    figure="fig12",
    title="Jacobi 1D under unified / independent / padded layouts",
    pattern=lambda env: jacobi1d(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2,
            validate_n=66)),
        VariantSpec("independent", DriverConfig(
            template="independent", programs=4, ntimes=8, reps=2,
            validate_n=66)),
        VariantSpec("indep_padded", DriverConfig(
            template="independent", programs=4, ntimes=8, reps=2,
            pad=_TILE_ELEMS, validate_n=66)),
    ),
    ladder=INTERIOR_SETS,
))

register(Workload(
    name="fig14_jacobi2d",
    figure="fig14",
    title="Jacobi 2D (5-pt star), unified vs independent",
    pattern=lambda env: jacobi2d(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2,
            validate_n=18)),
        VariantSpec("independent", DriverConfig(
            template="independent", programs=4, ntimes=8, reps=2,
            validate_n=18)),
    ),
    ladder=GRID2,
))

register(Workload(
    name="fig15_jacobi3d",
    figure="fig15",
    title="Jacobi 3D (7-pt), unified vs independent",
    pattern=lambda env: jacobi3d(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=4, reps=2,
            validate_n=10)),
        VariantSpec("independent", DriverConfig(
            template="independent", programs=4, ntimes=4, reps=2,
            validate_n=10)),
    ),
    ladder=GRID3,
))


# -- spatter_uniform: Spatter-style gather/scatter --------------------------
# The registry's scenario-diversity payoff: a whole new pattern-as-data
# suite (Lavin et al.'s UNIFORM:stride mode) in declarative form.

register(Workload(
    name="spatter_uniform",
    figure="spatter",
    title="Spatter UNIFORM:8 gather / scatter / gather-scatter",
    variants=(
        VariantSpec("gather", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2),
            pattern=lambda env: gather(stride=8)),
        VariantSpec("scatter", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2),
            pattern=lambda env: scatter(stride=8)),
        VariantSpec("gather_scatter", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2),
            pattern=lambda env: gather_scatter(stride=8)),
    ),
    ladder=WORKING_SETS,
))
