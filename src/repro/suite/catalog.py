"""Built-in workload entries — the paper's figures as declarative data.

Each ``register(Workload(...))`` below replaces a hand-rolled
``benchmarks/fig*.py`` script: the pattern, the driver-config variants
being contrasted, the sweep plan (or legacy working-set ladder), and the
validation policy are *specified*; the shared plan engine does
everything else. Tags group the scenario families for
``benchmarks.run --tag``: ``paper-figs`` (the reproduction), ``spatter``
(gather/scatter pattern ladders), ``mess`` (bandwidth–latency load
points), ``latency`` (serial-dependence probes).

The multi-axis entries at the bottom are the plan engine's
scenario-generality proof: ``mess_load_sweep`` sweeps *DriverConfig*
axes (``programs`` × ``ntimes`` pressure), ``spatter_nonuniform``
sweeps a *pattern-factory* axis (stride) against the working-set axis,
``pointer_chase`` rides a plain env axis with a serial-dependent
custom kernel, and ``mess_calibrated`` zips working set against burst
length so a latency variant and a bandwidth variant sample the same
pressure points — sweep shapes no single-axis ladder could express.

Fully custom experiments (the Pallas tile sweep, the roofline refresh)
register themselves from their ``benchmarks`` modules with a ``runner``.
"""
from __future__ import annotations

from repro.core import (
    DriverConfig,
    Record,
    gather,
    gather_scatter,
    identity,
    jacobi1d,
    jacobi2d,
    jacobi3d,
    latency_ns,
    mix_patterns,
    nstream,
    pointer_chase,
    scatter,
    triad,
)
from repro.core.measure import NATIVE_TILE_BYTES

from .axes import SweepPlan, config_axis, device_axis, env_axis, pattern_axis
from .collectives import collective_runner
from .ladders import GRID2, GRID3, INTERIOR_SETS, WORKING_SETS, fixed
from .registry import register
from .workload import VariantSpec, Workload

_TILE_ELEMS = NATIVE_TILE_BYTES // 4


# -- fig05: cost of implicit barriers ---------------------------------------
# OpenMP's implicit barrier per parallel-for becomes a host sync + dispatch
# per sweep; the `nowait` analogue fuses all sweeps into one fori_loop.

register(Workload(
    name="fig05_barriers",
    figure="fig05",
    title="barrier vs fused (nowait) bandwidth per working set",
    tags=("paper-figs",),
    pattern=lambda env: triad(),
    variants=(
        VariantSpec("barrier", DriverConfig(
            template="unified", programs=4, ntimes=16, reps=2,
            sync_every_rep=True)),
        VariantSpec("nowait", DriverConfig(
            template="unified", programs=4, ntimes=16, reps=2)),
    ),
    ladder=WORKING_SETS,
))


# -- fig06: unified vs independent data spaces ------------------------------
# One shared array with schedule(static, n/t) chunks vs per-program
# tile-padded rows (the paper's ~2x-in-L1 layout study).

register(Workload(
    name="fig06_dataspaces",
    figure="fig06",
    title="unified vs independent (tile-padded) data spaces for triad",
    tags=("paper-figs",),
    pattern=lambda env: triad(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=16, reps=2)),
        VariantSpec("independent", DriverConfig(
            template="independent", programs=4, ntimes=16, reps=2,
            pad=_TILE_ELEMS)),
    ),
    ladder=WORKING_SETS,
))


# -- fig07: bandwidth vs concurrent read streams ----------------------------
# The paper sweeps 3..20 simultaneously-read arrays (peak at 11 streams);
# the variant list is the sweep axis, each k with its own nstream pattern.

def _fig07_variants(quick: bool) -> tuple[VariantSpec, ...]:
    ks = [1, 2, 3, 5, 7, 11, 15, 20] if quick else list(range(1, 21))
    return tuple(
        VariantSpec(
            f"streams{k}",
            DriverConfig(template="independent", programs=4, ntimes=8,
                         reps=2),
            pattern=lambda env, k=k: nstream(k),
        )
        for k in ks
    )


register(Workload(
    name="fig07_streams",
    figure="fig07",
    title="bandwidth vs number of concurrent data streams",
    tags=("paper-figs",),
    variants=_fig07_variants,
    ladder=fixed(1 << 14, "streams_point"),
    validate=False,
))


# -- fig09: the interleaved-triad optimization ------------------------------
# Splitting each array into f simultaneously-accessed blocks (Listing 7)
# through the schedule engine, plus dedicated Pallas kernels as a post.

def _fig09_kernels(quick: bool) -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.core.measure import time_fn
    from repro.kernels import ops

    out = []
    n = 1 << 16
    key = jax.random.PRNGKey(0)
    b = jax.random.normal(key, (n,), jnp.float32)
    c = jax.random.normal(key, (n,), jnp.float32)
    bytes_moved = 3 * n * 4
    t = time_fn(lambda: ops.triad(b, c, block=4096), reps=3)
    out.append(f"fig09/kernel/naive,{t.seconds*1e6:.2f},"
               f"{bytes_moved/t.seconds/1e9:.3f}GB/s")
    for f in (2, 4):
        t = time_fn(lambda f=f: ops.triad_interleaved(b, c, factor=f,
                                                      block=2048), reps=3)
        out.append(f"fig09/kernel/il{f},{t.seconds*1e6:.2f},"
                   f"{bytes_moved/t.seconds/1e9:.3f}GB/s")
    return out


register(Workload(
    name="fig09_interleave",
    figure="fig09",
    title="interleaved triad: schedule engine + dedicated kernels",
    tags=("paper-figs",),
    pattern=lambda env: triad(),
    variants=tuple(
        VariantSpec(
            f"engine/il{f}",
            DriverConfig(
                template="independent", programs=2, ntimes=16, reps=2,
                schedule=(identity() if f == 1
                          else identity().interleave("i", f)),
            ),
        )
        for f in (1, 2, 4)
    ),
    ladder=WORKING_SETS,
    post=_fig09_kernels,
))


# -- fig10: counter-based false-sharing diagnosis ---------------------------
# The analytic native-tile traffic model + XLA cost_analysis stand in for
# PAPI's L1-miss / exclusive-line-request counters.

def _fig10_derived(rec: Record) -> str:
    shared = rec.extra.get("shared_write_tiles", -1)
    fetches = rec.extra.get("fetches", -1)
    return f"shared_tiles={shared};fetches={fetches};gbs={rec.gbs:.3f}"


register(Workload(
    name="fig10_counters",
    figure="fig10",
    title="false-sharing counters for three Jacobi-1D layouts",
    tags=("paper-figs",),
    pattern=lambda env: jacobi1d(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=4, reps=1,
            measured=True)),
        VariantSpec("indep_unpadded", DriverConfig(
            template="independent", programs=4, ntimes=4, reps=1,
            measured=True)),
        VariantSpec("indep_padded", DriverConfig(
            template="independent", programs=4, ntimes=4, reps=1,
            pad=_TILE_ELEMS, measured=True)),
    ),
    ladder=fixed((1 << 14) + 2, "counters_point"),
    validate=False,
    derived=_fig10_derived,
))


# -- fig12/14/15: the Jacobi family across layouts --------------------------

register(Workload(
    name="fig12_jacobi1d",
    figure="fig12",
    title="Jacobi 1D under unified / independent / padded layouts",
    tags=("paper-figs",),
    pattern=lambda env: jacobi1d(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2,
            validate_n=66)),
        VariantSpec("independent", DriverConfig(
            template="independent", programs=4, ntimes=8, reps=2,
            validate_n=66)),
        VariantSpec("indep_padded", DriverConfig(
            template="independent", programs=4, ntimes=8, reps=2,
            pad=_TILE_ELEMS, validate_n=66)),
    ),
    ladder=INTERIOR_SETS,
))

register(Workload(
    name="fig14_jacobi2d",
    figure="fig14",
    title="Jacobi 2D (5-pt star), unified vs independent",
    tags=("paper-figs",),
    pattern=lambda env: jacobi2d(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2,
            validate_n=18)),
        VariantSpec("independent", DriverConfig(
            template="independent", programs=4, ntimes=8, reps=2,
            validate_n=18)),
    ),
    ladder=GRID2,
))

register(Workload(
    name="fig15_jacobi3d",
    figure="fig15",
    title="Jacobi 3D (7-pt), unified vs independent",
    tags=("paper-figs",),
    pattern=lambda env: jacobi3d(),
    variants=(
        VariantSpec("unified", DriverConfig(
            template="unified", programs=4, ntimes=4, reps=2,
            validate_n=10)),
        VariantSpec("independent", DriverConfig(
            template="independent", programs=4, ntimes=4, reps=2,
            validate_n=10)),
    ),
    ladder=GRID3,
))


# -- spatter_uniform: Spatter-style gather/scatter --------------------------
# The registry's scenario-diversity payoff: a whole new pattern-as-data
# suite (Lavin et al.'s UNIFORM:stride mode) in declarative form.

register(Workload(
    name="spatter_uniform",
    figure="spatter",
    title="Spatter UNIFORM:8 gather / scatter / gather-scatter",
    tags=("spatter",),
    variants=(
        VariantSpec("gather", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2),
            pattern=lambda env: gather(stride=8)),
        VariantSpec("scatter", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2),
            pattern=lambda env: scatter(stride=8)),
        VariantSpec("gather_scatter", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2),
            pattern=lambda env: gather_scatter(stride=8)),
    ),
    ladder=WORKING_SETS,
))


# -- mess_load_sweep: bandwidth–latency curve under load ---------------------
# The Mess benchmark's (arXiv 2405.10170) core plot: how achieved
# bandwidth AND per-access time move as memory pressure rises. The load
# point is a *DriverConfig* grid — ``programs`` (concurrent per-program
# streams; the independent template keeps every program count on the
# strided fast path, and total footprint scales with the generator
# count, as Mess's traffic generators do) × ``ntimes`` (burst length
# between host syncs) — at one per-program working set: two axes the old
# single-axis Ladder could not express.

def _mess_derived(rec: Record) -> str:
    # triad touches 3 streams per point: pair GB/s with time-per-access
    us = latency_ns(rec, accesses_per_point=3) / 1e3
    return f"{rec.gbs:.3f}GB/s;{us:.6f}us/access"


register(Workload(
    name="mess_load_sweep",
    figure="mess",
    title="Mess-style load points: triad under programs x ntimes pressure",
    tags=("mess",),
    pattern=lambda env: triad(),
    variants=(
        VariantSpec("triad", DriverConfig(template="independent", reps=2)),
    ),
    plan=SweepPlan.product(
        config_axis("programs", (1, 2, 4, 8), (1, 2, 4, 8, 16)),
        config_axis("ntimes", (8, 32), (8, 32, 128)),
        env_axis((1 << 16,), (1 << 20,)),
    ),
    derived=_mess_derived,
))


# -- pointer_chase: load-to-use latency per working-set level ----------------
# The serial-dependence probe (lat_mem_rd lineage): H = P[H] through a
# single-cycle random permutation — no two loads overlap, so per-step
# time is the latency of the level the working set sits in. The env axis
# is the classic ladder; the kernel is the new serial-dependent
# PatternSpec.

def _chase_derived(rec: Record) -> str:
    return f"{latency_ns(rec):.2f}ns/access;level={rec.level}"


register(Workload(
    name="pointer_chase",
    figure="latency",
    title="serial pointer-chase load-to-use latency per working-set level",
    tags=("latency", "mess"),
    pattern=lambda env: pointer_chase(),
    variants=(
        VariantSpec("chase", DriverConfig(
            template="unified", programs=1, ntimes=2, reps=2,
            validate_n=64)),
    ),
    plan=SweepPlan.product(
        env_axis((1 << 10, 1 << 14, 1 << 17),
                 (1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20)),
    ),
    parametric=False,          # custom kernel: env is baked into the step
    derived=_chase_derived,
))


# -- mess_calibrated: latency and bandwidth at matched pressure points -------
# Mess calibrates its bandwidth–latency curves by measuring both at the
# same load point. The zip-mode plan delivers the pairing declaratively:
# working set and burst length (ntimes) rise in lockstep, and each zipped
# point runs BOTH variants — the serial pointer chase (load-to-use
# ns/access) and the independent-template triad (achieved GB/s) — so
# records pairing off on identical ``extra.axis_point`` coordinates are
# the calibrated (latency, bandwidth) sample for that pressure point.
# The chase keeps its custom-kernel constraints (programs=1, specialized
# lowering); the triad rides the strided-parametric regime wherever the
# ladder shares an executable.

def _calibrated_derived(rec: Record) -> str:
    if rec.pattern == "pointer_chase":
        return f"{latency_ns(rec):.2f}ns/access;level={rec.level}"
    us = latency_ns(rec, accesses_per_point=3) / 1e3
    return f"{rec.gbs:.3f}GB/s;{us:.6f}us/access"


def _calibrated_pair_post(quick: bool) -> list[str]:
    """One *strictly matched-load* (latency, bandwidth) sample via
    ``measure.time_pair``: the zipped plan above pairs the two variants
    at the same pressure *points*, but they still run back-to-back; this
    hook times the chase and the triad in interleaved A/B calls — every
    chase rep has a triad rep as its temporal neighbour — which is the
    Mess calibration discipline proper. Min-of-reps on both sides;
    emitted as two extra CSV lines with the session CV attached."""
    import jax.numpy as jnp

    from repro.core import Driver, GLOBAL_CACHE
    from repro.core.measure import time_pair

    n, ntimes = (1 << 12, 2) if quick else (1 << 16, 8)
    chase = Driver(
        lambda env: pointer_chase(),
        DriverConfig(template="unified", programs=1, ntimes=ntimes,
                     reps=1, validate_n=None, parametric=False),
        cache=GLOBAL_CACHE)
    band = Driver(
        lambda env: triad(),
        DriverConfig(template="independent", programs=4, ntimes=ntimes,
                     reps=1, validate_n=None),
        cache=GLOBAL_CACHE)
    (cp,) = chase.prepare([n])
    (bp,) = band.prepare([n])

    def tup(p):
        arrays = p.lowered.pattern.allocate(p.lowered.env)
        return tuple(jnp.asarray(arrays[k]) for k in p.compiled.names)

    tc, tb = time_pair(cp.executable(), (tup(cp),),
                       bp.executable(), (tup(bp),), reps=5, passes=2)
    ns_access = tc.minimum / (ntimes * n) * 1e9
    pat = bp.lowered.pattern
    pts = pat.domain.point_count(bp.env)
    gbs = pat.bytes_per_point() * pts * ntimes / tb.minimum / 1e9
    return [
        f"mess/pair/latency_n{n},{tc.minimum * 1e6:.2f},"
        f"{ns_access:.2f}ns/access;cv={tc.cv:.3f}",
        f"mess/pair/bandwidth_n{n},{tb.minimum * 1e6:.2f},"
        f"{gbs:.3f}GB/s;cv={tb.cv:.3f}",
    ]


register(Workload(
    name="mess_calibrated",
    figure="mess",
    title="Mess calibration: chase latency + triad bandwidth, matched points",
    tags=("mess", "latency"),
    variants=(
        VariantSpec("latency", DriverConfig(
            template="unified", programs=1, reps=2, validate_n=64,
            parametric=False),
            pattern=lambda env: pointer_chase()),
        VariantSpec("bandwidth", DriverConfig(
            template="independent", programs=4, reps=2),
            pattern=lambda env: triad()),
    ),
    plan=SweepPlan.zip(
        env_axis((1 << 10, 1 << 14, 1 << 17),
                 (1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20)),
        config_axis("ntimes", (2, 4, 8), (2, 2, 4, 4, 8, 8)),
    ),
    derived=_calibrated_derived,
    post=_calibrated_pair_post,
))


# -- spatter_nonuniform: stride-ladder axis over gather/scatter --------------
# Spatter's (arXiv 1811.03743) headline study sweeps the *pattern*, not
# just the working set: a stride ladder over gather / scatter /
# gather-scatter index patterns. The stride is a pattern-factory axis
# (each point builds its own PatternSpec, specialized per stride) crossed
# with the working-set env axis (parametric: each stride's ladder shares
# one executable).

register(Workload(
    name="spatter_nonuniform",
    figure="spatter",
    title="Spatter stride ladder over gather / scatter / gather-scatter",
    tags=("spatter",),
    variants=(
        VariantSpec("gather", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2),
            pattern=lambda env, stride=8: gather(stride=stride)),
        VariantSpec("scatter", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2),
            pattern=lambda env, stride=8: scatter(stride=stride)),
        VariantSpec("gather_scatter", DriverConfig(
            template="unified", programs=4, ntimes=8, reps=2),
            pattern=lambda env, stride=8: gather_scatter(stride=stride)),
    ),
    plan=SweepPlan.product(
        pattern_axis("stride", (1, 4, 16, 64), (1, 2, 4, 8, 16, 32, 64, 128)),
        env_axis((1 << 10, 1 << 14), (1 << 10, 1 << 12, 1 << 14, 1 << 16)),
    ),
))


# -- mess_contended: multi-pattern mixes contending for the memory system ----
# Mess's contention methodology (arXiv 2405.10170): the pattern under
# study runs while traffic generators load the same memory system, and
# the interesting number is the *primary's* achieved bandwidth as the
# background load rises. ``mix_patterns`` composes a streaming triad
# (the primary) with a random-ish strided gather (the generator) into
# ONE executable — the components interleave inside the fused sweep
# loop, contending for the same bandwidth — and the ``ratio``
# pattern-axis scales the generator's footprint from 0 (isolated
# baseline, same machinery) upward. Records carry the per-pattern byte
# split in ``extra["mix"]``; the derived column prices the primary
# under load.

def _contended_mix(env, ratio: int = 1):
    n = int(env["n"])
    comps = [("triad", triad(), {"n": n})]
    if ratio > 0:
        comps.append(("gather", gather(stride=8),
                      {"n": max(1, (ratio * n) // 4)}))
    return mix_patterns(comps, name=f"contended_r{ratio}", primary="triad")


def _contended_derived(rec: Record) -> str:
    mix = rec.extra.get("mix")
    if not mix:
        return f"{rec.gbs:.3f}GB/s"
    comps = {c["label"]: c for c in mix["components"]}
    prim = comps[mix["primary"]]
    primary_gbs = prim["bytes"] * rec.ntimes / rec.seconds / 1e9
    return (f"primary={mix['primary']};primary_gbs={primary_gbs:.3f};"
            f"total_gbs={rec.gbs:.3f};parts={len(comps)}")


def contended_probe(records) -> dict:
    """Ledger summary of the contention study: isolated (ratio=0) vs
    most-contended primary bandwidth at matching working sets, plus the
    per-pattern byte-split integrity check CI gates on."""
    def primary_gbs(rec):
        comps = {c["label"]: c for c in rec.extra["mix"]["components"]}
        prim = comps[rec.extra["mix"]["primary"]]
        return prim["bytes"] * rec.ntimes / rec.seconds / 1e9

    mixed = [r for r in records if r.extra.get("mix")]
    split_ok = all(
        len(r.extra["mix"]["components"]) >= 2
        and all(c["bytes"] > 0 for c in r.extra["mix"]["components"])
        for r in mixed if len(r.extra["mix"]["components"]) >= 2)
    by_n: dict[int, dict[str, float]] = {}
    for r in mixed:
        slot = by_n.setdefault(r.n, {})
        parts = len(r.extra["mix"]["components"])
        if parts == 1:
            slot["isolated"] = primary_gbs(r)
        else:
            load = sum(c["bytes"] for c in r.extra["mix"]["components"])
            if load >= slot.get("_load", 0):
                slot["_load"] = load
                slot["contended"] = primary_gbs(r)
    paired = {n: s for n, s in by_n.items()
              if "isolated" in s and "contended" in s and s["isolated"] > 0}
    # headline pair = the largest working set: contention is a
    # memory-system effect, and cache-resident rungs time as noise
    worst = paired[max(paired)] if paired else None
    return {
        "records": len(mixed),
        "split_ok": bool(split_ok and any(
            len(r.extra["mix"]["components"]) >= 2 for r in mixed)),
        "isolated_gbs": round(worst["isolated"], 3) if worst else 0.0,
        "contended_gbs": round(worst["contended"], 3) if worst else 0.0,
        "ratio": (round(worst["contended"] / worst["isolated"], 4)
                  if worst else None),
    }


register(Workload(
    name="mess_contended",
    figure="mess",
    title="contended multi-pattern mix: triad under rising gather load",
    tags=("mess", "trace"),
    pattern=_contended_mix,
    variants=(
        VariantSpec("mix", DriverConfig(
            template="unified", programs=1, ntimes=4, reps=3,
            target_cv=0.2, max_reps=12, validate_n=64)),
    ),
    plan=SweepPlan.product(
        pattern_axis("ratio", (0, 2, 4), (0, 1, 2, 4)),
        # the top rung must leave cache: contention is a memory-system
        # effect, and cache-resident mixes time as pure noise
        env_axis((1 << 14, 1 << 20), (1 << 12, 1 << 16, 1 << 20)),
    ),
    parametric=False,          # mix kernel bakes component envs into the step
    derived=_contended_derived,
))


# -- device_sweep: per-device bandwidth via the device axis ------------------
# The sweep engine's device axis in declarative form: each device point
# pins its whole working-set ladder to one mesh device (DriverConfig.
# device — indices wrap modulo the visible device count, so the plan
# also runs, collapsed, on a 1-device box), and ThreadPoolBackend runs
# the per-device groups genuinely concurrently. Per-device records
# carry extra["device"] = {axis, id, platform}.

register(Workload(
    name="device_sweep",
    figure="devsweep",
    title="per-device triad bandwidth across the sweep mesh (device axis)",
    tags=("sharded",),
    pattern=lambda env: triad(),
    variants=(
        VariantSpec("triad", DriverConfig(
            template="independent", programs=2, ntimes=8, reps=2)),
    ),
    plan=SweepPlan.product(
        device_axis((0, 1), (0, 1, 2, 3)),
        env_axis((1 << 12, 1 << 14), (1 << 12, 1 << 14, 1 << 16)),
    ),
))


# -- collective_ladder: interconnect bandwidth, HLO-validated ----------------
# The device-sharded workload family proper: an all-gather / all-reduce
# size ladder shard_map'ed over the 1-D sweep mesh, each point's
# bytes-on-the-wire validated against launch/hlo_analysis ring
# accounting (the dormant mesh.py / hlo_analysis.py substrate put to
# work). Custom runner: the driver templates model per-device memory
# traffic, not cross-device collectives.

register(Workload(
    name="collective_ladder",
    figure="collective",
    title="all-gather / all-reduce wire-bandwidth ladder over the sweep mesh",
    tags=("collectives", "sharded"),
    runner=collective_runner,
))
