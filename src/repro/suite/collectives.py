"""Collective-bandwidth ladder — the device-sharded workload family.

AdaptMemBench characterizes a memory subsystem by driving it with
application-shaped traffic; on a sharded accelerator the interconnect
*is* part of that subsystem, and the traffic shapes that exercise it
are the collectives. This module measures an all-gather / all-reduce
size ladder sharded across the 1-D sweep mesh
(:func:`repro.launch.mesh.make_sweep_mesh` — on CPU CI the mesh comes
from ``--xla_force_host_platform_device_count``, the
``launch/dryrun.py`` / ``tests/test_system.py`` pattern) and validates
every point's bytes-on-the-wire two ways:

* **ring accounting** from the op and shapes alone
  (:func:`expected_wire_bytes` — all-gather moves ``(k-1)/k`` of the
  gathered result per device, all-reduce ``2(k-1)/k`` of the reduced
  buffer: reduce-scatter + all-gather);
* **HLO analysis** via
  :func:`repro.launch.hlo_analysis.analyze_collectives` over the
  compiled executable's text — the estimate the launch layer would make
  for a production program, finally exercised against a measured run.

The two must agree (CI gates at 10%); reported GB/s is aggregate wire
traffic (``k`` × per-device bytes) over the timed call.
"""
from __future__ import annotations

__all__ = [
    "COLLECTIVE_OPS",
    "collective_sizes",
    "expected_wire_bytes",
    "measure_collectives",
    "collective_runner",
]

COLLECTIVE_OPS = ("all_gather", "all_reduce")

# HLO op name per ladder op — what analyze_collectives keys its
# per-kind byte accounting on.
HLO_KIND = {"all_gather": "all-gather", "all_reduce": "all-reduce"}


def collective_sizes(quick: bool) -> tuple[int, ...]:
    """Per-device shard sizes (f32 elements) of the ladder."""
    return (1 << 10, 1 << 12) if quick else (1 << 10, 1 << 14, 1 << 16)


def expected_wire_bytes(op: str, shard_elems: int, k: int,
                        itemsize: int = 4) -> float:
    """Ring-accounting per-device wire bytes for ONE collective call
    over ``k`` devices holding ``shard_elems``-element shards.

    all_gather: every device receives the other ``k-1`` shards of the
    gathered ``k * shard_elems`` result — ``(k-1)/k`` of the result.
    all_reduce: reduce-scatter + all-gather over the ``shard_elems``
    buffer — ``2 (k-1)/k`` of it.
    """
    if op == "all_gather":
        return (k - 1) / k * (k * shard_elems * itemsize)
    if op == "all_reduce":
        return 2.0 * (k - 1) / k * (shard_elems * itemsize)
    raise ValueError(f"unknown collective op {op!r} "
                     f"(expected one of {COLLECTIVE_OPS})")


def _sharded_ops(mesh):
    """jit-wrapped shard_map bodies per op. ``check_rep=False`` is
    required: shard_map cannot statically infer that the collective
    results are replicated, and without it tracing raises."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def all_gather(x):
        return jax.lax.all_gather(x, "device", tiled=True)

    def all_reduce(x):
        return jax.lax.psum(x, "device")

    kw = dict(mesh=mesh, in_specs=P("device"), out_specs=P(None),
              check_rep=False)
    return {
        "all_gather": jax.jit(shard_map(all_gather, **kw)),
        "all_reduce": jax.jit(shard_map(all_reduce, **kw)),
    }


def measure_collectives(quick: bool = True, *, mesh=None,
                        reps: int = 3) -> list[dict]:
    """Run the ladder; one dict per (op, shard size) point.

    Keys: ``op``, ``devices``, ``shard_elems``, ``wire_bytes`` (ring
    accounting, per device), ``hlo_bytes`` (analyze_collectives, per
    device), ``agreement`` (hlo / ring), ``seconds``, ``gbs``
    (aggregate wire GB/s). Empty on a <2-device mesh — there is no wire
    to measure.
    """
    import jax.numpy as jnp

    from repro.core.measure import time_fn
    from repro.launch.hlo_analysis import analyze_collectives
    from repro.launch.mesh import make_sweep_mesh

    mesh = mesh if mesh is not None else make_sweep_mesh()
    k = int(mesh.devices.size)
    if k < 2:
        return []
    ops = _sharded_ops(mesh)
    out: list[dict] = []
    for op in COLLECTIVE_OPS:
        for s in collective_sizes(quick):
            x = jnp.linspace(0.0, 1.0, k * s, dtype=jnp.float32)
            compiled = ops[op].lower(x).compile()
            stats = analyze_collectives(compiled.as_text())
            hlo_bytes = stats.bytes_by_kind.get(HLO_KIND[op], 0.0)
            wire = expected_wire_bytes(op, s, k)
            t = time_fn(compiled, x, reps=reps, warmup=1)
            out.append({
                "op": op,
                "devices": k,
                "shard_elems": s,
                "wire_bytes": wire,
                "hlo_bytes": hlo_bytes,
                "agreement": hlo_bytes / wire if wire else float("nan"),
                "seconds": t.seconds,
                "gbs": k * wire / t.seconds / 1e9,
            })
    return out


def collective_runner(quick: bool = True) -> list[str]:
    """The registered workload entry: CSV lines per ladder point, with
    the ring-vs-HLO agreement verdict inline. A single-device box skips
    with a comment (the CI gate re-runs under a forced 8-device host
    platform)."""
    import jax

    from .runner import emit

    k = len(jax.devices())
    if k < 2:
        return emit([
            f"# collective ladder skipped: {k} device(s) visible — set "
            "--xla_force_host_platform_device_count (XLA_FLAGS) for a "
            "host mesh"
        ])
    rows = measure_collectives(quick)
    lines, bad = [], 0
    for r in rows:
        ok = abs(r["agreement"] - 1.0) <= 0.10
        bad += 0 if ok else 1
        lines.append(
            f"collective/{r['op']}/k{r['devices']}/s{r['shard_elems']},"
            f"{r['seconds'] * 1e6:.2f},{r['gbs']:.3f}GB/s,"
            f"wire={int(r['wire_bytes'])}B,hlo={int(r['hlo_bytes'])}B,"
            f"{'ok' if ok else 'MISMATCH'}"
        )
    if bad:
        lines.append(
            f"# collective ring-vs-hlo byte mismatch on {bad} point(s)")
    return emit(lines)
