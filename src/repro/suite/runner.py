"""The one executor every registered workload shares.

Per workload: expand the sweep plan (a legacy ladder is a one-axis
plan), hand it to the plan engine — which stages every (variant, point)
executable up front, shares one executable along parametric env axes,
and validates each distinct executable once against the serial oracle —
then emit the paper's ``name,us_per_call,derived`` CSV contract. The
per-workload translation activity is reported as a cache-delta comment
line.
"""
from __future__ import annotations

from repro.core import GLOBAL_CACHE, Record, TranslationCache
from repro.core.errors import ResiliencePolicy

from .engine import ExecutionBackend, RunReport, run_plan
from .journal import RunJournal
from .registry import load_builtins, workload as _lookup
from .workload import Workload

__all__ = ["csv_line", "emit", "run_workload", "run_module",
           "collect_records", "collect_report"]


def csv_line(name: str, rec: Record, derived: str | float = "") -> str:
    if derived == "":
        derived = f"{rec.gbs:.3f}GB/s"
    return f"{name},{rec.seconds * 1e6:.2f},{derived}"


def emit(lines: list[str]) -> list[str]:
    for ln in lines:
        print(ln, flush=True)
    return lines


def collect_report(
    w: Workload, quick: bool = True, *,
    cache: TranslationCache | None = None,
    parametric: "bool | str | None" = None,
    param_path: str | None = None,
    on_error: str = "demote",
    resilience: ResiliencePolicy | None = None,
    journal: "RunJournal | str | None" = None,
    backend: "ExecutionBackend | None" = None,
) -> RunReport:
    """Measure a declarative workload through the fault-isolated plan
    engine; returns the full :class:`~repro.suite.engine.RunReport`
    (rows + failures + demotions + journal replays + executor stats).
    ``backend`` picks the execution backend (None = serial)."""
    if w.runner is not None:
        raise ValueError(f"workload {w.name!r} is custom; run it via run_workload")
    cache = cache if cache is not None else GLOBAL_CACHE
    return run_plan(
        w.pattern, w.variant_list(quick), w.sweep_plan(),
        quick=quick, cache=cache, validate=w.validate,
        parametric=w.parametric if parametric is None else parametric,
        param_path=param_path, on_error=on_error, resilience=resilience,
        journal=journal, backend=backend,
    )


def collect_records(
    w: Workload, quick: bool = True, *,
    cache: TranslationCache | None = None,
    parametric: "bool | str | None" = None,
    param_path: str | None = None,
) -> list[tuple[str, Record]]:
    """Measure a declarative workload; returns ``(csv_label, record)``
    pairs. This is the runner's core loop, exposed so tests can compare
    parametric-vs-specialized executions of every registered workload.
    ``parametric`` overrides the workload-level policy (None = use it);
    ``param_path`` pins the parametric lowering regime on configs that
    leave it at "auto" (the regime-conformance tests run every workload
    under "gather" and "strided" and demand identical records).

    Strict by contract: a fault propagates with its original exception
    class (the conformance tests assert on exact classes). Callers that
    want fault isolation use :func:`collect_report`.
    """
    report = collect_report(w, quick, cache=cache, parametric=parametric,
                            param_path=param_path, on_error="raise")
    return [
        (f"{w.figure}/{row.variant}/{row.point.label}", row.record)
        for row in report.rows
    ]


def run_workload(w: Workload, quick: bool = True, *,
                 cache: TranslationCache | None = None,
                 journal: "RunJournal | str | None" = None,
                 backend: "ExecutionBackend | None" = None,
                 executor_stats: "dict | None" = None) -> list[str]:
    """Execute one workload (declarative or custom) and emit its CSV.

    Fault-isolated: a failing plan point is demoted/retried by the
    engine and, if it still fails, reported as a ``# FAILED`` comment
    while every surviving row is emitted normally; the aggregated
    :class:`~repro.core.errors.SweepFailures` (carrying the
    ``FailureRecord`` list on ``.failures``) is raised *after* emission
    so batch callers (``benchmarks/run.py``) can record the failure and
    continue to the next workload.

    ``backend`` picks the plan engine's execution backend (custom-runner
    workloads ignore it — they own their execution). When the caller
    passes an ``executor_stats`` dict, the report's per-phase executor
    accounting is copied into it (the ledger's stage/measure split).
    """
    if w.runner is not None:
        return list(w.runner(quick))
    cache = cache if cache is not None else GLOBAL_CACHE
    s0 = cache.stats()
    report = collect_report(w, quick, cache=cache, journal=journal,
                            backend=backend)
    if executor_stats is not None:
        executor_stats.update(report.executor)
    lines = [
        csv_line(f"{w.figure}/{row.variant}/{row.point.label}", row.record,
                 w.derived(row.record) if w.derived else "")
        for row in report.rows
    ]
    if w.post is not None:
        lines.extend(w.post(quick))
    s1 = cache.stats()
    print(
        f"# {w.name} cache: "
        f"{s1['compile_hits'] - s0['compile_hits']} compile hits / "
        f"{s1['compile_misses'] - s0['compile_misses']} misses",
        flush=True,
    )
    if report.replayed:
        print(f"# {w.name} journal: {report.replayed} point(s) replayed",
              flush=True)
    for d in report.demotions:
        print(f"# {w.name} demoted [{d.step}] after {d.stage}:{d.error} "
              f"({', '.join(d.labels)})", flush=True)
    for f in report.failures:
        print(f"# {w.name} FAILED {f.variant}/{f.label}: "
              f"{f.stage}:{f.error}: {f.message}", flush=True)
    emit(lines)
    report.raise_if_failed()
    return lines


def run_module(name: str, quick: bool = True) -> list[str]:
    """Registry lookup + run — the body of every thin ``fig*`` module."""
    load_builtins()
    return run_workload(_lookup(name), quick)
