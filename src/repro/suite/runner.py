"""The one executor every registered workload shares.

Per workload: build one Driver per variant, stage every (variant, point)
executable up front (XLA compiles overlap on worker threads; parametric
ladders collapse onto a single executable), validate each variant once
against the serial oracle, then measure and emit the paper's
``name,us_per_call,derived`` CSV contract. The per-workload translation
activity is reported as a cache-delta comment line.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import Driver, GLOBAL_CACHE, Record, TranslationCache, precompile

from .registry import load_builtins, workload as _lookup
from .workload import Workload

__all__ = ["csv_line", "emit", "run_workload", "run_module", "collect_records"]


def csv_line(name: str, rec: Record, derived: str | float = "") -> str:
    if derived == "":
        derived = f"{rec.gbs:.3f}GB/s"
    return f"{name},{rec.seconds * 1e6:.2f},{derived}"


def emit(lines: list[str]) -> list[str]:
    for ln in lines:
        print(ln, flush=True)
    return lines


def _drivers(w: Workload, quick: bool, cache: TranslationCache,
             parametric: "bool | str | None" = None):
    """(variant, driver) pairs with the workload's parametric policy
    applied to configs that left ``parametric`` unset (None); a variant
    that explicitly pins True/False/"auto" keeps its choice."""
    out = []
    policy = w.parametric if parametric is None else parametric
    for v in w.variant_list(quick):
        cfg = v.config
        if cfg.parametric is None:
            cfg = dataclasses.replace(cfg, parametric=policy)
        out.append((v, Driver(v.pattern or w.pattern, cfg, cache=cache)))
    return out


def collect_records(
    w: Workload, quick: bool = True, *,
    cache: TranslationCache | None = None,
    parametric: "bool | str | None" = None,
) -> list[tuple[str, Record]]:
    """Measure a declarative workload; returns ``(csv_label, record)``
    pairs. This is the runner's core loop, exposed so tests can compare
    parametric-vs-specialized executions of every registered workload.
    """
    if w.runner is not None:
        raise ValueError(f"workload {w.name!r} is custom; run it via run_workload")
    cache = cache if cache is not None else GLOBAL_CACHE
    pts = list(w.ladder.points(quick))
    ns = [w.ladder.env_n(p) for p in pts]
    drivers = _drivers(w, quick, cache, parametric)
    # stage every variant's executables before any timing starts
    precompile([
        (lambda d=d: d.prepare(ns, parallel=False)) for _, d in drivers
    ])
    out: list[tuple[str, Record]] = []
    for v, d in drivers:
        if w.validate and d.cfg.validate_n:
            d.validate()
        recs = d.run(ns)
        if w.validate and d.cfg.validate_n and any(
                r.extra.get("parametric") for r in recs):
            # the executable that produced these numbers is the shared
            # parametric one — oracle-check it too (small points only:
            # the serial oracle's guarded fallback is O(points) Python);
            # memoized per ladder, so re-runs don't re-pay it.
            d.validate_parametric(ns, max_check_n=4096)
        for p, rec in zip(pts, recs):
            out.append((f"{w.figure}/{v.label}/n{p}", rec))
    return out


def run_workload(w: Workload, quick: bool = True, *,
                 cache: TranslationCache | None = None) -> list[str]:
    """Execute one workload (declarative or custom) and emit its CSV."""
    if w.runner is not None:
        return list(w.runner(quick))
    cache = cache if cache is not None else GLOBAL_CACHE
    s0 = cache.stats()
    lines = [
        csv_line(label, rec, w.derived(rec) if w.derived else "")
        for label, rec in collect_records(w, quick, cache=cache)
    ]
    if w.post is not None:
        lines.extend(w.post(quick))
    s1 = cache.stats()
    print(
        f"# {w.name} cache: "
        f"{s1['compile_hits'] - s0['compile_hits']} compile hits / "
        f"{s1['compile_misses'] - s0['compile_misses']} misses",
        flush=True,
    )
    return emit(lines)


def run_module(name: str, quick: bool = True) -> list[str]:
    """Registry lookup + run — the body of every thin ``fig*`` module."""
    load_builtins()
    return run_workload(_lookup(name), quick)
