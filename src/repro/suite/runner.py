"""The one executor every registered workload shares.

Per workload: expand the sweep plan (a legacy ladder is a one-axis
plan), hand it to the plan engine — which stages every (variant, point)
executable up front, shares one executable along parametric env axes,
and validates each distinct executable once against the serial oracle —
then emit the paper's ``name,us_per_call,derived`` CSV contract. The
per-workload translation activity is reported as a cache-delta comment
line.
"""
from __future__ import annotations

from repro.core import GLOBAL_CACHE, Record, TranslationCache

from .engine import run_plan
from .registry import load_builtins, workload as _lookup
from .workload import Workload

__all__ = ["csv_line", "emit", "run_workload", "run_module", "collect_records"]


def csv_line(name: str, rec: Record, derived: str | float = "") -> str:
    if derived == "":
        derived = f"{rec.gbs:.3f}GB/s"
    return f"{name},{rec.seconds * 1e6:.2f},{derived}"


def emit(lines: list[str]) -> list[str]:
    for ln in lines:
        print(ln, flush=True)
    return lines


def collect_records(
    w: Workload, quick: bool = True, *,
    cache: TranslationCache | None = None,
    parametric: "bool | str | None" = None,
    param_path: str | None = None,
) -> list[tuple[str, Record]]:
    """Measure a declarative workload; returns ``(csv_label, record)``
    pairs. This is the runner's core loop, exposed so tests can compare
    parametric-vs-specialized executions of every registered workload.
    ``parametric`` overrides the workload-level policy (None = use it);
    ``param_path`` pins the parametric lowering regime on configs that
    leave it at "auto" (the regime-conformance tests run every workload
    under "gather" and "strided" and demand identical records).
    """
    if w.runner is not None:
        raise ValueError(f"workload {w.name!r} is custom; run it via run_workload")
    cache = cache if cache is not None else GLOBAL_CACHE
    rows = run_plan(
        w.pattern, w.variant_list(quick), w.sweep_plan(),
        quick=quick, cache=cache, validate=w.validate,
        parametric=w.parametric if parametric is None else parametric,
        param_path=param_path,
    )
    return [
        (f"{w.figure}/{row.variant}/{row.point.label}", row.record)
        for row in rows
    ]


def run_workload(w: Workload, quick: bool = True, *,
                 cache: TranslationCache | None = None) -> list[str]:
    """Execute one workload (declarative or custom) and emit its CSV."""
    if w.runner is not None:
        return list(w.runner(quick))
    cache = cache if cache is not None else GLOBAL_CACHE
    s0 = cache.stats()
    lines = [
        csv_line(label, rec, w.derived(rec) if w.derived else "")
        for label, rec in collect_records(w, quick, cache=cache)
    ]
    if w.post is not None:
        lines.extend(w.post(quick))
    s1 = cache.stats()
    print(
        f"# {w.name} cache: "
        f"{s1['compile_hits'] - s0['compile_hits']} compile hits / "
        f"{s1['compile_misses'] - s0['compile_misses']} misses",
        flush=True,
    )
    return emit(lines)


def run_module(name: str, quick: bool = True) -> list[str]:
    """Registry lookup + run — the body of every thin ``fig*`` module."""
    load_builtins()
    return run_workload(_lookup(name), quick)
