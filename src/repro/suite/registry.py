"""The process-wide workload registry.

Registration order is execution order (``benchmarks.run`` iterates
``names()``), so the suite stays deterministic. Re-registering a name
overwrites — module reloads and test fixtures stay idempotent.
"""
from __future__ import annotations

from .workload import Workload

__all__ = ["register", "workload", "workloads", "names", "all_tags",
           "load_builtins"]

_REGISTRY: dict[str, Workload] = {}


def register(w: Workload) -> Workload:
    """Register (or re-register) a workload; returns it for chaining."""
    _REGISTRY[w.name] = w
    return w


def workload(name: str) -> Workload:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no workload {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def workloads() -> tuple[Workload, ...]:
    return tuple(_REGISTRY.values())


def names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def all_tags() -> tuple[str, ...]:
    """Every tag used by a registered workload (sorted)."""
    out: set[str] = set()
    for w in _REGISTRY.values():
        out.update(w.tags)
    return tuple(sorted(out))


def load_builtins() -> None:
    """Import the built-in declarative entries (idempotent)."""
    from . import catalog as _builtin  # noqa: F401
    from . import derived as _derived
    from . import spatter_io as _spatter

    _derived.register_derived()
    _spatter.register_trace()
