"""Runnable training driver (CPU: reduced configs; pod: full configs).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 20 --batch 8 --seq 128 [--ckpt-dir /tmp/ckpt]

On the CPU container this trains the reduced config of any architecture
end-to-end (real data pipeline, optimizer, checkpointing, fault-tolerant
loop). On a real pod the same driver runs the full config: the mesh comes
from make_production_mesh and every step is the dry-run-validated one.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES, Shape, get_config
from repro.data.pipeline import Loader, make_batch_fn
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step, microbatches_for
from repro.models import lm
from repro.models.moe import Parallelism
from repro.optim import adamw, cosine_schedule, error_feedback
from repro.runtime.fault_tolerance import FTConfig, FaultTolerantLoop
from repro.runtime.sharding import (
    auto_parallelism, batch_specs, param_specs, shardings,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = Shape("cli", args.seq, args.batch, "train")

    if args.production_mesh:
        mesh = make_production_mesh()
        par = auto_parallelism(cfg, mesh, shape)
    else:
        mesh = make_host_mesh()
        par = None

    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    opt = adamw(cosine_schedule(args.lr, warmup=10, total=args.steps))
    if args.compress_grads:
        opt = error_feedback(opt)
    state = {"params": params, "opt": opt.init(params)}

    mb = args.microbatches or microbatches_for(cfg, shape, par)
    step_fn = make_train_step(cfg, par, opt, num_microbatches=mb)
    if par is not None:
        sds = jax.eval_shape(lambda: state)
        sspec = {"params": param_specs(sds["params"], par),
                 "opt": param_specs(sds["opt"], par)}
        sshard = shardings(sspec, mesh)
        bshard = shardings(
            batch_specs(jax.eval_shape(
                lambda: make_batch_fn(cfg, shape)(0)), par), mesh)
        step_fn = jax.jit(step_fn, in_shardings=(sshard, bshard),
                          out_shardings=(sshard, None), donate_argnums=0)
        state = jax.device_put(state, sshard)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=0)
        bshard = None

    class _Src:
        def __init__(self, fn):
            self.fn = fn

        def get(self, step):
            return self.fn(step)

    loader = Loader(_Src(make_batch_fn(cfg, shape, args.seed)), bshard)

    if args.ckpt_dir:
        loop = FaultTolerantLoop(
            step_fn, state,
            FTConfig(args.ckpt_dir, ckpt_every=args.ckpt_every),
        )
        start = loop.try_resume()
        out = loop.run(loader, args.steps, start_step=start)
        losses = [float(m["loss"]) for m in out["metrics"]]
    else:
        losses = []
        t0 = time.time()
        for step, batch in loader:
            if step >= args.steps:
                break
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if step % 5 == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"({dt/ max(1, len(losses)):.2f}s/step)", flush=True)
    loader.close()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"delta {losses[0]-losses[-1]:+.4f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
