"""Production mesh construction.

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
axis crosses DCN; data/model are intra-pod ICI.

``make_production_mesh`` is a function (never module-level state) so that
importing this module touches no jax device machinery — only the dry-run
entrypoint sets the 512-device host-platform flag.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_sweep_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_sweep_mesh(num_devices: int | None = None):
    """1-D mesh over the visible devices, axis ``"device"`` — the shape
    the sweep engine's device axis and the collective-bandwidth ladder
    shard across. On CPU CI the device count comes from
    ``--xla_force_host_platform_device_count`` (the ``launch/dryrun.py``
    / ``tests/test_system.py`` pattern); ``num_devices`` restricts to a
    leading subset (it must not exceed what is visible)."""
    avail = len(jax.devices())
    k = avail if num_devices is None else int(num_devices)
    if not 1 <= k <= avail:
        raise ValueError(
            f"make_sweep_mesh: asked for {k} devices, {avail} visible")
    return jax.make_mesh((k,), ("device",), devices=jax.devices()[:k])
