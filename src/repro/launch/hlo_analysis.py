"""Post-SPMD HLO analysis: collective-byte accounting + roofline terms.

``cost_analysis()`` reports FLOPs/bytes with while-loop (scan) bodies
counted ONCE, and it does not expose collective traffic at all. This
module parses ``compiled.as_text()`` to

1. find every collective op (all-gather / all-reduce / reduce-scatter /
   all-to-all / collective-permute) with its result shape and replica
   group size,
2. estimate each while loop's trip count (from the constant compared
   against the induction variable in the loop condition computation),
3. multiply per-computation counts by the loop-nesting trip product,

yielding whole-step per-device collective bytes. Byte cost per op follows
ring-algorithm accounting:

    all-reduce       2 (k-1)/k x result bytes
    all-gather         (k-1)/k x result bytes
    reduce-scatter     (k-1)   x result bytes   (operand = k x result)
    all-to-all         (k-1)/k x result bytes
    collective-permute           result bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

__all__ = ["CollectiveStats", "analyze_collectives", "parse_computations",
           "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)"
)
_CALLS_RE = re.compile(
    r"(?:calls=|condition=|body=|to_apply=)%?([\w.\-]+)")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_txt):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_computations(hlo: str) -> dict[str, str]:
    """Split HLO text into named computations (entry included)."""
    comps: dict[str, str] = {}
    cur_name, buf, depth = None, [], 0
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur_name is None:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)[^{]*\{", stripped)
            if m and stripped.endswith("{"):
                cur_name = m.group(1)
                buf = []
                depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            comps[cur_name] = "\n".join(buf)
            cur_name = None
        else:
            buf.append(line)
    return comps


def _trip_count(cond_body: str) -> int:
    """Heuristic: largest integer constant in the loop condition."""
    consts = [int(c) for c in _CONST_CMP_RE.findall(cond_body)]
    return max(consts) if consts else 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def analyze_collectives(hlo: str) -> CollectiveStats:
    comps = parse_computations(hlo)

    # while condition/body pairs and trip counts
    trip: dict[str, int] = {}
    for name, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, loop_body = m.group(1), m.group(2)
            t = _trip_count(comps.get(cond, ""))
            trip[loop_body] = max(trip.get(loop_body, 1), t)
            trip[cond] = max(trip.get(cond, 1), t)

    # call multiplicity: entry has multiplier 1; called computations inherit
    entry = None
    for name in comps:
        if "entry" in name.lower() or name.startswith("main"):
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))

    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, seen: tuple):
        if name not in comps or name in seen:
            return
        mult[name] += m
        body = comps[name]
        for cm in _CALLS_RE.finditer(body):
            callee = cm.group(1)
            if callee == name:
                continue
            visit(callee, m * trip.get(callee, 1), seen + (name,))

    if entry:
        visit(entry, 1.0, ())

    by_kind: dict[str, float] = defaultdict(float)
    count: dict[str, int] = defaultdict(int)
    for name, body in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for cm in _COLL_RE.finditer(body):
            shape_txt, kind = cm.group(1), cm.group(2)
            nbytes = _shape_bytes(shape_txt)
            line_end = body.find("\n", cm.end())
            line = body[cm.start():line_end if line_end > 0 else None]
            k = _group_size(line)
            if kind == "all-reduce":
                eff = 2.0 * (k - 1) / k * nbytes
            elif kind == "all-gather":
                eff = (k - 1) / k * nbytes
            elif kind == "reduce-scatter":
                eff = (k - 1) * nbytes
            elif kind == "all-to-all":
                eff = (k - 1) / k * nbytes
            else:  # collective-permute
                eff = float(nbytes)
            by_kind[kind] += m * eff
            count[kind] += int(m)
    return CollectiveStats(dict(by_kind), dict(count))


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 2
