"""Post-SPMD HLO analysis: collective bytes, per-op traffic, roofline terms.

``cost_analysis()`` reports FLOPs/bytes with while-loop (scan) bodies
counted ONCE, and it does not expose collective traffic or per-op access
shapes at all. This module parses ``compiled.as_text()`` to

1. find every collective op (all-gather / all-reduce / reduce-scatter /
   all-to-all / collective-permute) with its result shape and replica
   group size,
2. estimate each while loop's trip count (from the constant compared
   against the induction variable in the loop condition computation),
3. multiply per-computation counts by the loop-nesting trip product,

yielding whole-step per-device collective bytes. Byte cost per op follows
ring-algorithm accounting:

    all-reduce       2 (k-1)/k x result bytes
    all-gather         (k-1)/k x result bytes
    reduce-scatter     (k-1)   x result bytes   (operand = k x result)
    all-to-all         (k-1)/k x result bytes
    collective-permute           result bytes

Async collective pairs (``-start``/``-done``) are counted once, at the
``-start`` op; a ``-start``'s tuple result shape ``(operand, result,
contexts...)`` contributes only the result element. Dtypes missing from
``DTYPE_BYTES`` are never silently counted as zero bytes — they surface
as a structured ``unknown_dtypes`` marker on the result.

``analyze_memory_ops`` applies the same trip-weighted walk to *every*
op, yielding per-opcode result-byte traffic — the raw material the
application-derived workload pipeline (``repro.suite.derived``)
classifies into access shapes.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

__all__ = ["CollectiveStats", "OpTraffic", "ShapeBytes",
           "analyze_collectives", "analyze_memory_ops",
           "parse_computations", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
# any named op: `%x = <shape> opcode(`; shape is a tuple or dtype[dims]{...}
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"([a-z][a-z0-9\-]*)\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)"
)
_CALLS_RE = re.compile(
    r"(?:calls=|condition=|body=|to_apply=)%?([\w.\-]+)")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")
_HEADER_RE = re.compile(r"(?:ENTRY\s+)?%?([\w.\-]+)[^{]*\{")


@dataclasses.dataclass(frozen=True)
class ShapeBytes:
    """Byte count of an HLO shape string + the dtypes it could not
    account (never silently counted as zero)."""

    nbytes: int
    unknown: tuple[str, ...] = ()


def _shape_bytes(shape_txt: str) -> ShapeBytes:
    total = 0
    unknown: list[str] = []
    for dt, dims in _SHAPE_RE.findall(shape_txt):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        if dt not in DTYPE_BYTES:
            if dt not in unknown:
                unknown.append(dt)
            continue
        total += n * DTYPE_BYTES[dt]
    return ShapeBytes(total, tuple(unknown))


def _tuple_elems(shape_txt: str) -> list[str]:
    """Split a tuple shape ``(a, b, ...)`` into its top-level element
    shape strings (dims commas don't split). Non-tuples return [self]."""
    txt = shape_txt.strip()
    if not txt.startswith("("):
        return [txt]
    inner = txt[1:txt.rfind(")")] if ")" in txt else txt[1:]
    elems, depth, cur = [], 0, []
    for ch in inner:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            elems.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        elems.append("".join(cur).strip())
    return [e for e in elems if e]


def _result_bytes(shape_txt: str, *, is_start: bool) -> ShapeBytes:
    """Bytes of an op's *result*. Async ``-start`` ops carry tuple
    results ``(operand, result, contexts...)`` — count only the result
    element, so the ``-start``/``-done`` pair is accounted exactly
    once and context scratch (e.g. ``u32[]`` ids) never inflates it."""
    if is_start:
        elems = _tuple_elems(shape_txt)
        if len(elems) >= 2:
            return _shape_bytes(elems[1])
        if elems:
            return _shape_bytes(elems[0])
    return _shape_bytes(shape_txt)


def parse_computations(hlo: str) -> dict[str, str]:
    """Split HLO text into named computations (entry included).

    Splitting is brace-depth driven: a header is any line matching the
    computation-name shape whose net brace count opens a scope — newer
    jaxlib emits headers with trailing attributes after the ``{``
    (``execution_thread=...``), so "line ends with ``{``" is not a
    usable signal. Layout/group braces (``f32[8]{0}``,
    ``replica_groups={{0,1}}``) balance within a line, keeping the
    net count correct.
    """
    comps: dict[str, str] = {}
    cur_name, buf, depth = None, [], 0
    for line in hlo.splitlines():
        stripped = line.strip()
        delta = stripped.count("{") - stripped.count("}")
        if cur_name is None:
            m = _HEADER_RE.match(stripped)
            if m and delta > 0:
                cur_name = m.group(1)
                buf = []
                depth = delta
            continue
        depth += delta
        if depth <= 0:
            comps[cur_name] = "\n".join(buf)
            cur_name = None
        else:
            buf.append(line)
    return comps


def _trip_count(cond_body: str) -> int:
    """Heuristic: largest integer constant in the loop condition."""
    consts = [int(c) for c in _CONST_CMP_RE.findall(cond_body)]
    return max(consts) if consts else 1


def _computation_multiplicity(comps: dict[str, str]) -> dict[str, float]:
    """Trip-weighted execution multiplicity per computation: the entry
    runs once; called computations inherit the caller's multiplicity
    times their while-loop trip count."""
    trip: dict[str, int] = {}
    for name, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, loop_body = m.group(1), m.group(2)
            t = _trip_count(comps.get(cond, ""))
            trip[loop_body] = max(trip.get(loop_body, 1), t)
            trip[cond] = max(trip.get(cond, 1), t)

    entry = None
    for name in comps:
        if "entry" in name.lower() or name.startswith("main"):
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))

    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, seen: tuple):
        if name not in comps or name in seen:
            return
        mult[name] += m
        body = comps[name]
        for cm in _CALLS_RE.finditer(body):
            callee = cm.group(1)
            if callee == name:
                continue
            visit(callee, m * trip.get(callee, 1), seen + (name,))

    if entry:
        visit(entry, 1.0, ())
    return mult


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]
    unknown_dtypes: tuple[str, ...] = ()

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def analyze_collectives(hlo: str) -> CollectiveStats:
    comps = parse_computations(hlo)
    mult = _computation_multiplicity(comps)

    by_kind: dict[str, float] = defaultdict(float)
    count: dict[str, int] = defaultdict(int)
    unknown: list[str] = []
    for name, body in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for cm in _COLL_RE.finditer(body):
            shape_txt, kind = cm.group(1), cm.group(2)
            sb = _result_bytes(shape_txt, is_start=bool(cm.group(3)))
            for dt in sb.unknown:
                if dt not in unknown:
                    unknown.append(dt)
            nbytes = sb.nbytes
            line_end = body.find("\n", cm.end())
            line = body[cm.start():line_end if line_end > 0 else None]
            k = _group_size(line)
            if kind == "all-reduce":
                eff = 2.0 * (k - 1) / k * nbytes
            elif kind == "all-gather":
                eff = (k - 1) / k * nbytes
            elif kind == "reduce-scatter":
                eff = (k - 1) * nbytes
            elif kind == "all-to-all":
                eff = (k - 1) / k * nbytes
            else:  # collective-permute
                eff = float(nbytes)
            by_kind[kind] += m * eff
            count[kind] += int(m)
    return CollectiveStats(dict(by_kind), dict(count), tuple(unknown))


@dataclasses.dataclass(frozen=True)
class OpTraffic:
    """Trip-weighted result traffic of one HLO opcode across the module."""

    op: str
    count: float            # occurrences weighted by loop trip products
    result_bytes: float     # result bytes weighted the same way
    example_shape: str = ""
    unknown_dtypes: tuple[str, ...] = ()


# opcodes that are bookkeeping, not memory access shapes
_SKIP_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "custom-call", "call", "while", "conditional",
})


def analyze_memory_ops(hlo: str) -> dict[str, OpTraffic]:
    """Per-opcode, trip-weighted result-byte traffic for the module.

    The same computation-multiplicity walk ``analyze_collectives`` uses,
    applied to every op: a gather inside a scan body with trip count 10
    contributes 10x its result bytes. Async ``-start`` collectives count
    their result tuple element only (pairs count once). The returned map
    is the raw material for classifying a program's dominant access
    shapes (``repro.suite.derived``).
    """
    comps = parse_computations(hlo)
    mult = _computation_multiplicity(comps)

    count: dict[str, float] = defaultdict(float)
    nbytes: dict[str, float] = defaultdict(float)
    example: dict[str, str] = {}
    unknown: dict[str, list] = defaultdict(list)
    for name, body in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for om in _OP_RE.finditer(body):
            shape_txt, op = om.group(1), om.group(2)
            if op in _SKIP_OPS:
                continue
            is_start = op.endswith("-start")
            base = op[:-6] if is_start else op
            if op.endswith("-done") or op.endswith("-update"):
                continue  # the -start leg carries the accounting
            sb = _result_bytes(shape_txt, is_start=is_start)
            count[base] += m
            nbytes[base] += m * sb.nbytes
            example.setdefault(base, shape_txt)
            for dt in sb.unknown:
                if dt not in unknown[base]:
                    unknown[base].append(dt)
    return {
        op: OpTraffic(op, count[op], nbytes[op], example.get(op, ""),
                      tuple(unknown.get(op, ())))
        for op in count
    }


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 2
