import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16,16) or (2,16,16), the
architecture config, the sharding policy, and AOT-compiles the real step
function against ShapeDtypeStruct inputs — no arrays are allocated. The
compiled artifact yields:

  * memory_analysis()  — per-device argument/output/temp bytes (fits-HBM
    proof against the 16 GiB v5e budget),
  * cost_analysis()    — XLA FLOPs / bytes (scan bodies counted once —
    see hlo_analysis for the trip-corrected whole-step view),
  * as_text()          — post-SPMD HLO, parsed for per-device collective
    bytes (trip-count corrected).

Results are dumped as JSON under experiments/dryrun/ for the roofline
stage. Usage:

    python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--jobs-file f.txt]
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES, ArchConfig, Shape, get_config, list_archs
from repro.launch.hlo_analysis import analyze_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    microbatches_for,
)
from repro.models import lm
from repro.optim import adafactor, adamw
from repro.runtime.sharding import (
    auto_parallelism,
    batch_specs,
    cache_specs,
    param_count,
    param_specs,
    shardings,
)

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"
HBM_BYTES = 16 * 2 ** 30  # v5e


def skip_reason(cfg: ArchConfig, shape: Shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 512k decode is quadratic-cost; "
                "skipped per assignment (noted in DESIGN.md)")
    return None


def build_cell(arch_id: str, shape_name: str, multi_pod: bool):
    """Returns (jitted, example_args, meta) ready to lower."""
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    par = auto_parallelism(cfg, mesh, shape)
    sds_params = jax.eval_shape(
        lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    pspecs = param_specs(sds_params, par)
    pshard = shardings(pspecs, mesh)
    batch = input_specs(cfg, shape)
    bshard = shardings(batch_specs(batch, par), mesh)

    if shape.kind == "train":
        n_params = param_count(cfg)
        big = n_params > 60e9
        # bf16 moments whenever state is ZeRO-tight: always under the
        # TP-off policy (the policy's fit estimate assumes 8 B/param) and
        # for >60B models; smaller f32-moment configs keep headroom anyway
        bf16_moments = big or par.tp_axis is None
        if n_params > 300e9:
            # the 1T config: factored second moment + bf16 first moment is
            # what fits the 16 GiB budget (see EXPERIMENTS.md memory table)
            opt = adafactor(moment_dtype=jnp.bfloat16)
        else:
            opt = adamw(moment_dtype=jnp.bfloat16 if bf16_moments
                        else jnp.float32)
        sds_opt = jax.eval_shape(opt.init, sds_params)
        ospecs = param_specs(sds_opt, par)   # name-based rules match m/v
        oshard = shardings(ospecs, mesh)
        mb = microbatches_for(cfg, shape, par)
        step = make_train_step(
            cfg, par, opt, num_microbatches=mb,
            accum_dtype=jnp.bfloat16 if big else jnp.float32,
            grad_shardings=pshard,
        )
        state_shape = {"params": sds_params, "opt": sds_opt}
        state_shard = {"params": pshard, "opt": oshard}
        jitted = jax.jit(
            step,
            in_shardings=(state_shard, bshard),
            out_shardings=(state_shard, None),
            donate_argnums=(0,),
        )
        return jitted, (state_shape, batch), {
            "microbatches": mb, "par": par, "mesh": mesh, "cfg": cfg,
        }

    # serving shapes
    B = shape.global_batch
    max_len = shape.seq_len + (1 if shape.kind == "decode" else 0)
    sds_cache = jax.eval_shape(lambda: lm.init_cache(cfg, B, max_len))
    cspecs = cache_specs(sds_cache, par, cfg, B)
    cshard = shardings(cspecs, mesh)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, par)
    else:
        step = make_serve_step(cfg, par)
    jitted = jax.jit(
        step,
        in_shardings=(pshard, cshard, bshard),
        out_shardings=(None, cshard),
        donate_argnums=(1,),
    )
    return jitted, (sds_params, sds_cache, batch), {
        "par": par, "mesh": mesh, "cfg": cfg,
    }


def run_cell(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "params": param_count(cfg),
    }
    reason = skip_reason(cfg, shape)
    if reason:
        rec["skipped"] = reason
        return rec
    t0 = time.time()
    jitted, args, meta = build_cell(arch_id, shape_name, multi_pod)
    with meta["mesh"]:
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
            live = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                    + ma.output_size_in_bytes - ma.alias_size_in_bytes)
            rec["memory"]["live_bytes"] = int(live)
            rec["memory"]["fits_16g"] = bool(live < HBM_BYTES)
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        rec["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(
                sum(v for k, v in ca.items() if k.startswith("bytes accessed"))
            ),
        }
        hlo = compiled.as_text()
        stats = analyze_collectives(hlo)
        rec["collectives"] = {
            "bytes_by_kind": stats.bytes_by_kind,
            "count_by_kind": stats.count_by_kind,
            "total_bytes": stats.total_bytes,
        }
        rec["hlo_chars"] = len(hlo)
    if "microbatches" in meta:
        rec["microbatches"] = meta["microbatches"]
    par = meta["par"]
    rec["policy"] = {
        "fsdp_axes": list(par.fsdp_axes),
        "ep_axes": list(par.ep_axes),
        "tp_axis": par.tp_axis,
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = out_dir / f"{tag}.json"
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape, mp)
                    status = ("SKIP" if "skipped" in rec else "OK")
                except Exception as e:  # noqa: BLE001 — report, keep sweeping
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    status = "FAIL"
                    failures += 1
                rec["wall_s"] = round(time.time() - t0, 2)
                path.write_text(json.dumps(rec, indent=2))
                extra = ""
                if status == "OK" and "memory" in rec:
                    gb = rec["memory"]["live_bytes"] / 2 ** 30
                    extra = (f" live={gb:.2f}GiB coll="
                             f"{rec['collectives']['total_bytes']/1e9:.2f}GB")
                print(f"[{status}] {tag} ({rec['wall_s']}s){extra}",
                      flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("dry-run sweep complete")


if __name__ == "__main__":
    main()
