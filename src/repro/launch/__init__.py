"""Launch layer: mesh construction, step factories, dry-run, drivers."""
