"""Runnable serving driver: batched prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Implements the full serving path the decode dry-run shapes lower:
allocate cache -> prefill the prompt batch -> iterated one-token greedy
decode. Reports per-phase wall time and tokens/s (CPU numbers on this
container; the step functions are identical on a pod).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    B, P, G = args.batch, args.prompt_len, args.gen
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)

    prefill = jax.jit(make_prefill_step(cfg, None), donate_argnums=(1,))
    decode = jax.jit(make_serve_step(cfg, None), donate_argnums=(1,))

    cache = lm.init_cache(cfg, B, P + G)
    batch: dict = {}
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jax.random.normal(
            key, (B, P, cfg.d_model), jnp.bfloat16)
        batch["cond"] = jax.random.normal(key, (B, 64, cfg.d_model),
                                          jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    t0 = time.time()
    tok, cache = prefill(params, cache, batch)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0
    print(f"prefill: {B}x{P} tokens in {t_prefill:.3f}s "
          f"({B*P/t_prefill:.0f} tok/s)")

    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(G - 1):
        if cfg.frontend == "audio":
            step_in = {"frame_embeds": jnp.take(params["emb"], tok[:, -1:],
                                                axis=0),
                       "cond": batch["cond"]}
        else:
            step_in = {"tokens": tok}
        tok, cache = decode(params, cache, step_in)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    print(f"decode: {G-1} steps x {B} seqs in {t_dec:.3f}s "
          f"({B*(G-1)/max(t_dec,1e-9):.0f} tok/s)")
    print(f"sample generated ids (seq 0): {gen[0][:16].tolist()}")


if __name__ == "__main__":
    main()
