"""Step factories: train_step / prefill_step / serve_step per (arch, shape).

``train_step`` supports gradient accumulation (scan over microbatches) —
required to fit the 1T/123B configs in the 16 GiB v5e budget — with the
DP gradient all-reduce deferred to the accumulated gradient (one reduction
per step; XLA schedules it as async all-reduce-start/done overlapping the
optimizer). ``serve_step`` is one-token greedy decode against a KV cache.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig, Shape
from repro.models import lm
from repro.models.moe import Parallelism
from repro.optim import Optimizer

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_serve_step",
    "input_specs",
    "microbatches_for",
]

I32 = jnp.int32
BF16 = jnp.bfloat16


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: Shape) -> dict[str, jax.ShapeDtypeStruct]:
    """Batch inputs for one step of the given kind."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch: dict[str, Any] = {"labels": sds((B, S), I32)}
        if cfg.frontend == "audio":
            batch["frame_embeds"] = sds((B, S, cfg.d_model), BF16)
            batch["cond"] = sds((B, 64, cfg.d_model), BF16)
        elif cfg.frontend == "vision":
            vt = cfg.vision_tokens
            batch["tokens"] = sds((B, S - vt), I32)
            batch["vision_embeds"] = sds((B, vt, cfg.d_model), BF16)
        else:
            batch["tokens"] = sds((B, S), I32)
        return batch
    if shape.kind == "prefill":
        if cfg.frontend == "audio":
            return {"frame_embeds": sds((B, S, cfg.d_model), BF16),
                    "cond": sds((B, 64, cfg.d_model), BF16)}
        if cfg.frontend == "vision":
            vt = cfg.vision_tokens
            return {"tokens": sds((B, S - vt), I32),
                    "vision_embeds": sds((B, vt, cfg.d_model), BF16)}
        return {"tokens": sds((B, S), I32)}
    # decode: one new token against a cache of length S
    if cfg.frontend == "audio":
        return {"frame_embeds": sds((B, 1, cfg.d_model), BF16),
                "cond": sds((B, 64, cfg.d_model), BF16)}
    return {"tokens": sds((B, 1), I32)}


def microbatches_for(cfg: ArchConfig, shape: Shape,
                     par: Parallelism | None = None) -> int:
    """Gradient-accumulation factor targeting ~4 GiB of layer-boundary
    remat residuals per device: tokens_dev x d_model x 2B x L / mb <= 4e9.
    Clamped so every DP shard keeps >= 1 sample per microbatch."""
    if shape.kind != "train":
        return 1
    dp = 1
    if par is not None:
        from repro.runtime.sharding import batch_axes_for
        for a in batch_axes_for(par, shape.global_batch):
            dp *= par.mesh.shape[a]
    tokens_dev = shape.tokens / dp
    resid = tokens_dev * cfg.d_model * 2 * cfg.n_layers
    need = resid / 2.5e9
    mb = 1
    max_mb = max(1, shape.global_batch // dp)
    while mb < need and mb * 2 <= max_mb and shape.global_batch % (mb * 2) == 0:
        mb *= 2
    return mb


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, par: Parallelism | None, opt: Optimizer,
                    *, num_microbatches: int = 1, remat: bool = True,
                    accum_dtype=jnp.float32,
                    grad_shardings=None) -> Callable:
    """``grad_shardings`` (params-shaped NamedSharding tree) pins the
    gradient accumulator to the *param* sharding inside the microbatch
    scan — ZeRO-2 semantics: each microbatch's DP reduction lowers to a
    reduce-scatter onto the shard instead of a full all-reduce of a
    replicated carry (2x fewer bytes, params-sized instead of
    replicated-sized carry memory)."""

    def pin(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def loss(params, batch):
        return lm.loss_fn(params, cfg, batch, par=par, remat=remat)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        if num_microbatches == 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
            grads = pin(grads)
        else:
            k = num_microbatches

            def split(x):
                return x.reshape((k, x.shape[0] // k) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def accum(carry, mb):
                tot, g = carry
                li, gi = jax.value_and_grad(loss)(params, mb)
                gi = pin(gi)  # shard the raw microbatch grad immediately:
                # without this, GSPMD materializes it replicated before the
                # add (params-sized x dp_replication of temp)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g, gi
                )
                return (tot + li, pin(g)), None

            g0 = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            ))
            (l, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), g0), micro
            )
            l = l / k
            grads = jax.tree.map(lambda g: (g / k).astype(jnp.float32), grads)
        new_params, opt_state = opt.update(grads, state["opt"], params)
        inner = opt_state.get("inner", opt_state)  # compression wrapper
        metrics = {"loss": l, "step": inner.get("step", 0)}
        return {"params": new_params, "opt": opt_state}, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ArchConfig, par: Parallelism | None) -> Callable:
    def prefill_step(params: dict, cache: dict, batch: dict
                     ) -> tuple[jnp.ndarray, dict]:
        hidden, new_cache, _ = lm.apply(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("frame_embeds"),
            prefix_embeds=batch.get("vision_embeds"),
            cond=batch.get("cond"),
            cache=cache, par=par, remat=False,
        )
        # next-token ids for the last position only (greedy)
        last = hidden[:, -1:]
        logits = last @ lm.unembed_table(params, cfg).T
        return jnp.argmax(logits, axis=-1).astype(I32), new_cache

    return prefill_step


def make_serve_step(cfg: ArchConfig, par: Parallelism | None) -> Callable:
    def serve_step(params: dict, cache: dict, batch: dict
                   ) -> tuple[jnp.ndarray, dict]:
        hidden, new_cache, _ = lm.apply(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("frame_embeds"),
            cond=batch.get("cond"),
            cache=cache, par=par, remat=False,
        )
        logits = hidden @ lm.unembed_table(params, cfg).T
        return jnp.argmax(logits, axis=-1).astype(I32), new_cache

    return serve_step
