"""Blocked Pallas kernels for the Jacobi stencil family (paper §III-B).

TPU adaptation of the paper's tiling study. The CPU version tiles to keep
working sets in L1/L2; the TPU version tiles so that (a) the output block
plus its halo'd input window fits VMEM, and (b) the trailing two dims are
native-tile aligned. Halos are handled the TPU-idiomatic way: the *output*
is blocked with a non-overlapping BlockSpec while the *input* stays
unblocked (whole-array ref = HBM-resident operand) and the kernel slices
the halo'd window explicitly — the manual-DMA pattern Mosaic compiles to
HBM->VMEM copies. Overlapping input windows cannot be expressed as a
blocked BlockSpec (blocks are disjoint by construction), which is exactly
why the paper's "blocking in all three dimensions" transliterates poorly
to TPU; see jacobi3d_streaming for the adaptation that works.

Kernels:
    jacobi1d_blocked     1D, grid over interior blocks.
    jacobi2d_blocked     5-pt/9-pt 2D, 2D grid of (bi, bj) output tiles.
    jacobi3d_blocked     7-pt 3D, 3D grid (the paper's xyz tiling).
    jacobi3d_streaming   7-pt 3D, 2D grid over (j,k) tiles; i is *streamed*
                         inside the kernel with a rolling 3-plane window —
                         the paper's "partial blocking" (Rivera-Tseng)
                         adapted to the TPU memory hierarchy.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "jacobi1d_blocked",
    "jacobi2d_blocked",
    "jacobi3d_blocked",
    "jacobi3d_streaming",
]

_THIRD = np.float32(1.0 / 3.0)
_FIFTH = np.float32(1.0 / 5.0)
_SEVENTH = np.float32(1.0 / 7.0)


def _div(a: int, b: int, what: str) -> int:
    if a % b != 0:
        raise ValueError(f"{what}: {b} must divide {a}")
    return a // b


def jacobi1d_blocked(b: jnp.ndarray, *, block: int = 1024,
                     interpret: bool = True) -> jnp.ndarray:
    """A[i] = (B[i-1]+B[i]+B[i+1])/3 on 1 <= i < n-1; A keeps B's borders.

    Interior (n-2) must be divisible by ``block``. Output is blocked;
    input is an unblocked ref sliced with a halo of 1.
    """
    n = b.shape[0]
    interior = n - 2
    block = min(block, interior)
    nb = _div(interior, block, "jacobi1d interior")

    def kernel(b_ref, out_ref):
        i = pl.program_id(0)
        start = i * block + 1
        w = b_ref[pl.ds(start - 1, block + 2)]
        out_ref[...] = ((w[:-2] + w[1:-1] + w[2:]) * _THIRD).astype(out_ref.dtype)

    interior_out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(b.shape, lambda i: (0,))],  # whole array
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((interior,), b.dtype),
        interpret=interpret,
    )(b)
    return b.at[1:-1].set(interior_out)


def jacobi2d_blocked(b: jnp.ndarray, *, block: tuple[int, int] = (128, 128),
                     points: int = 5, interpret: bool = True) -> jnp.ndarray:
    """5-pt star or 9-pt box Jacobi 2D with a 2D grid of output tiles."""
    n0, n1 = b.shape
    bi = min(block[0], n0 - 2)
    bj = min(block[1], n1 - 2)
    gi = _div(n0 - 2, bi, "jacobi2d dim0")
    gj = _div(n1 - 2, bj, "jacobi2d dim1")

    def kernel(b_ref, out_ref):
        i = pl.program_id(0) * bi + 1
        j = pl.program_id(1) * bj + 1
        w = b_ref[pl.ds(i - 1, bi + 2), pl.ds(j - 1, bj + 2)]
        c = w[1:-1, 1:-1]
        if points == 5:
            acc = (w[:-2, 1:-1] + w[2:, 1:-1] + w[1:-1, :-2] + w[1:-1, 2:] + c)
            res = acc * _FIFTH
        else:  # 9-pt box
            acc = c
            for di in (0, 1, 2):
                for dj in (0, 1, 2):
                    if di == 1 and dj == 1:
                        continue
                    acc = acc + w[di:di + bi, dj:dj + bj]
            res = acc * np.float32(1.0 / 9.0)
        out_ref[...] = res.astype(out_ref.dtype)

    interior = pl.pallas_call(
        kernel,
        grid=(gi, gj),
        in_specs=[pl.BlockSpec(b.shape, lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n0 - 2, n1 - 2), b.dtype),
        interpret=interpret,
    )(b)
    return b.at[1:-1, 1:-1].set(interior)


def jacobi3d_blocked(b: jnp.ndarray, *, block: tuple[int, int, int] = (8, 8, 128),
                     interpret: bool = True) -> jnp.ndarray:
    """7-pt Jacobi 3D, xyz tiling (paper Listing 9): 3D grid of tiles.

    Every tile re-fetches a (bi+2, bj+2, bk+2) halo'd window — the halo
    re-read overhead is (1+2/b)^3 - 1; with the paper's 16^3 tiles that is
    ~42% extra traffic, which is why xyz tiling loses. The roofline
    benchmark quantifies this; jacobi3d_streaming removes it.
    """
    n0, n1, n2 = b.shape
    bi, bj, bk = (min(bb, nn - 2) for bb, nn in zip(block, b.shape))
    gi = _div(n0 - 2, bi, "jacobi3d dim0")
    gj = _div(n1 - 2, bj, "jacobi3d dim1")
    gk = _div(n2 - 2, bk, "jacobi3d dim2")

    def kernel(b_ref, out_ref):
        i = pl.program_id(0) * bi + 1
        j = pl.program_id(1) * bj + 1
        k = pl.program_id(2) * bk + 1
        w = b_ref[pl.ds(i - 1, bi + 2), pl.ds(j - 1, bj + 2), pl.ds(k - 1, bk + 2)]
        c = w[1:-1, 1:-1, 1:-1]
        acc = (
            w[:-2, 1:-1, 1:-1] + w[2:, 1:-1, 1:-1]
            + w[1:-1, :-2, 1:-1] + w[1:-1, 2:, 1:-1]
            + w[1:-1, 1:-1, :-2] + w[1:-1, 1:-1, 2:]
            + c
        )
        out_ref[...] = (acc * _SEVENTH).astype(out_ref.dtype)

    interior = pl.pallas_call(
        kernel,
        grid=(gi, gj, gk),
        in_specs=[pl.BlockSpec(b.shape, lambda i, j, k: (0, 0, 0))],
        out_specs=pl.BlockSpec((bi, bj, bk), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((n0 - 2, n1 - 2, n2 - 2), b.dtype),
        interpret=interpret,
    )(b)
    return b.at[1:-1, 1:-1, 1:-1].set(interior)


def jacobi3d_streaming(b: jnp.ndarray, *, block: tuple[int, int] = (8, 128),
                       interpret: bool = True) -> jnp.ndarray:
    """7-pt Jacobi 3D, partial (j,k) blocking with the i dim *streamed*.

    The TPU-native version of Rivera-Tseng partial blocking: a 2D grid of
    (bj, bk) column tiles; inside the kernel a fori_loop walks i planes
    keeping a rolling window of three (bj+2, bk+2) planes in registers /
    VMEM. Per-tile HBM traffic is (bj+2)(bk+2)/(bj*bk) of minimal — halo
    re-reads happen only in the two blocked dims, and each plane is read
    once, so the streamed dim is traffic-optimal.
    """
    n0, n1, n2 = b.shape
    bj = min(block[0], n1 - 2)
    bk = min(block[1], n2 - 2)
    gj = _div(n1 - 2, bj, "jacobi3d dim1")
    gk = _div(n2 - 2, bk, "jacobi3d dim2")

    def kernel(b_ref, out_ref):
        j = pl.program_id(0) * bj + 1
        k = pl.program_id(1) * bk + 1

        def plane(i):
            return b_ref[pl.ds(i, 1), pl.ds(j - 1, bj + 2), pl.ds(k - 1, bk + 2)][0]

        def body(i, carry):
            prev, cur = carry  # planes i-1 and i (full halo'd slabs)
            nxt = plane(i + 1)
            c = cur[1:-1, 1:-1]
            acc = (
                prev[1:-1, 1:-1] + nxt[1:-1, 1:-1]
                + cur[:-2, 1:-1] + cur[2:, 1:-1]
                + cur[1:-1, :-2] + cur[1:-1, 2:]
                + c
            )
            out_ref[pl.ds(i - 1, 1), :, :] = (acc * _SEVENTH).astype(
                out_ref.dtype
            )[None]
            return (cur, nxt)

        jax.lax.fori_loop(1, n0 - 1, body, (plane(0), plane(1)))

    interior = pl.pallas_call(
        kernel,
        grid=(gj, gk),
        in_specs=[pl.BlockSpec(b.shape, lambda j, k: (0, 0, 0))],
        out_specs=pl.BlockSpec((n0 - 2, bj, bk), lambda j, k: (0, j, k)),
        out_shape=jax.ShapeDtypeStruct((n0 - 2, n1 - 2, n2 - 2), b.dtype),
        interpret=interpret,
    )(b)
    return b.at[1:-1, 1:-1, 1:-1].set(interior)
