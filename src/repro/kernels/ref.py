"""Pure-jnp oracles for every kernel in this package.

Each function computes the same mathematical result as its Pallas
counterpart with plain vectorized jax.numpy — no grids, no blocks. Tests
sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "triad_ref",
    "nstream_ref",
    "jacobi1d_ref",
    "jacobi2d_ref",
    "jacobi2d9_ref",
    "jacobi3d_ref",
]


def triad_ref(b: jnp.ndarray, c: jnp.ndarray, scalar: float = 3.0) -> jnp.ndarray:
    return b + scalar * c


def nstream_ref(streams, scalar: float = 3.0) -> jnp.ndarray:
    """A = scalar*S0 + S1 + ... + Sk-1 (matches core.pattern.nstream)."""
    acc = streams[0] * scalar
    for s in streams[1:]:
        acc = acc + s
    return acc


def jacobi1d_ref(b: jnp.ndarray) -> jnp.ndarray:
    third = np.float32(1.0 / 3.0)
    interior = (b[:-2] + b[1:-1] + b[2:]) * third
    return b.at[1:-1].set(interior.astype(b.dtype))


def jacobi2d_ref(b: jnp.ndarray) -> jnp.ndarray:
    fifth = np.float32(1.0 / 5.0)
    interior = (
        b[:-2, 1:-1] + b[2:, 1:-1] + b[1:-1, :-2] + b[1:-1, 2:] + b[1:-1, 1:-1]
    ) * fifth
    return b.at[1:-1, 1:-1].set(interior.astype(b.dtype))


def jacobi2d9_ref(b: jnp.ndarray) -> jnp.ndarray:
    ninth = np.float32(1.0 / 9.0)
    acc = None
    for di in (0, 1, 2):
        for dj in (0, 1, 2):
            sl = b[di:b.shape[0] - 2 + di, dj:b.shape[1] - 2 + dj]
            acc = sl if acc is None else acc + sl
    return b.at[1:-1, 1:-1].set((acc * ninth).astype(b.dtype))


def jacobi3d_ref(b: jnp.ndarray) -> jnp.ndarray:
    seventh = np.float32(1.0 / 7.0)
    interior = (
        b[:-2, 1:-1, 1:-1] + b[2:, 1:-1, 1:-1]
        + b[1:-1, :-2, 1:-1] + b[1:-1, 2:, 1:-1]
        + b[1:-1, 1:-1, :-2] + b[1:-1, 1:-1, 2:]
        + b[1:-1, 1:-1, 1:-1]
    ) * seventh
    return b.at[1:-1, 1:-1, 1:-1].set(interior.astype(b.dtype))
