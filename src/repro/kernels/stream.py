"""Blocked Pallas kernels for the STREAM/triad pattern family.

These are the BlockSpec-tiled showcase versions of the patterns the
generic ``repro.core.codegen`` backend lowers in manual-DMA style. Block
shapes default to multiples of the v5e native tile (8x128 f32 = 1024
elements) so the MXU/VPU sees hardware-aligned operands; ``interpret=True``
executes the same kernels on CPU for validation.

Kernels:

``stream``          A = f(B, C, ...) elementwise over 1D arrays, blocked
                    into ``block``-element VMEM tiles (copy/scale/sum/triad
                    and the k-read-stream generalization of paper Fig. 7).

``interleaved``     the paper's triad interleaving (Listing 7) as a layout
                    transformation: arrays are viewed as (factor, n/factor)
                    and blocks span all ``factor`` rows, so each grid step
                    streams ``factor`` segments of every operand
                    simultaneously — 2*factor+1 concurrent DMA streams for
                    triad, the TPU analogue of "use more prefetch streams".
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["stream", "interleaved", "NATIVE_BLOCK"]

NATIVE_BLOCK = 8 * 128  # one f32 native tile, flattened


def _check(n: int, block: int) -> None:
    if n % block != 0:
        raise ValueError(f"block {block} must divide n {n}")
    if block % NATIVE_BLOCK != 0:
        # allowed (interpret mode), but the TPU target wants tile multiples
        pass


def stream(
    combine: Callable[..., jnp.ndarray],
    *streams: jnp.ndarray,
    block: int = 4 * NATIVE_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    """A[i] = combine(streams...[i]) with 1D BlockSpec tiling.

    ``combine`` receives one ``(block,)`` array per input stream.
    """
    n = streams[0].shape[0]
    for s in streams:
        if s.shape != (n,):
            raise ValueError("all streams must be 1D of equal length")
    block = min(block, n)
    _check(n, block)
    grid = (n // block,)

    def kernel(*refs):
        *ins, out = refs
        out[...] = combine(*[r[...] for r in ins]).astype(out.dtype)

    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * len(streams),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), streams[0].dtype),
        interpret=interpret,
    )(*streams)


def interleaved(
    combine: Callable[..., jnp.ndarray],
    *streams: jnp.ndarray,
    factor: int = 2,
    block: int = NATIVE_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Interleaved-by-``factor`` stream: each grid step touches ``factor``
    disjoint segments of every operand at once (paper Listing 7).

    Input 1D arrays of length n are *viewed* (no copy — XLA reshape of a
    contiguous array is a bitcast) as (factor, n//factor); a (factor, block)
    BlockSpec then walks all segments in lockstep.
    """
    n = streams[0].shape[0]
    if n % factor != 0:
        raise ValueError(f"factor {factor} must divide n {n}")
    seg = n // factor
    block = min(block, seg)
    if seg % block != 0:
        raise ValueError(f"block {block} must divide segment {seg}")
    grid = (seg // block,)

    def kernel(*refs):
        *ins, out = refs
        out[...] = combine(*[r[...] for r in ins]).astype(out.dtype)

    spec = pl.BlockSpec((factor, block), lambda i: (0, i))
    out2d = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * len(streams),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((factor, seg), streams[0].dtype),
        interpret=interpret,
    )(*[s.reshape(factor, seg) for s in streams])
    return out2d.reshape(n)
