"""Pallas TPU kernels for the memory-pattern hot spots.

stream.py   — STREAM/triad family + the paper's interleaving, BlockSpec-tiled
stencil.py  — Jacobi 1D/2D/3D, blocked + streaming (partial-block) variants
ops.py      — jit'd public wrappers (what benchmarks and models call)
ref.py      — pure-jnp oracles for allclose validation

All kernels are written for the TPU target (pl.pallas_call + BlockSpec,
native-tile-aligned blocks) and validated with interpret=True on CPU.
"""
from . import ops, ref  # noqa: F401
