"""Jit'd public wrappers over the Pallas kernels.

These are what the benchmarks, drivers, and model code call. Each wrapper
validates shapes, dispatches dtype, and jits with static block/factor
arguments so re-invocations with the same geometry hit the compile cache.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import stream as _stream
from . import stencil as _stencil

__all__ = [
    "triad",
    "nstream",
    "triad_interleaved",
    "jacobi1d",
    "jacobi2d",
    "jacobi3d",
    "jacobi3d_streaming",
]


@partial(jax.jit, static_argnames=("scalar", "block", "interpret"))
def triad(b: jnp.ndarray, c: jnp.ndarray, *, scalar: float = 3.0,
          block: int = 4096, interpret: bool = True) -> jnp.ndarray:
    return _stream.stream(
        lambda bb, cc: bb + scalar * cc, b, c, block=block, interpret=interpret
    )


@partial(jax.jit, static_argnames=("scalar", "block", "interpret"))
def nstream(streams: tuple[jnp.ndarray, ...], *, scalar: float = 3.0,
            block: int = 4096, interpret: bool = True) -> jnp.ndarray:
    """A = scalar*S0 + S1 + ... (k concurrent read streams, paper Fig. 7)."""
    def combine(*vals):
        acc = vals[0] * scalar
        for v in vals[1:]:
            acc = acc + v
        return acc

    return _stream.stream(combine, *streams, block=block, interpret=interpret)


@partial(jax.jit, static_argnames=("scalar", "factor", "block", "interpret"))
def triad_interleaved(b: jnp.ndarray, c: jnp.ndarray, *, scalar: float = 3.0,
                      factor: int = 2, block: int = 1024,
                      interpret: bool = True) -> jnp.ndarray:
    return _stream.interleaved(
        lambda bb, cc: bb + scalar * cc, b, c,
        factor=factor, block=block, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("block", "interpret"))
def jacobi1d(b: jnp.ndarray, *, block: int = 1024,
             interpret: bool = True) -> jnp.ndarray:
    return _stencil.jacobi1d_blocked(b, block=block, interpret=interpret)


@partial(jax.jit, static_argnames=("block", "points", "interpret"))
def jacobi2d(b: jnp.ndarray, *, block: tuple[int, int] = (128, 128),
             points: int = 5, interpret: bool = True) -> jnp.ndarray:
    return _stencil.jacobi2d_blocked(
        b, block=block, points=points, interpret=interpret
    )


@partial(jax.jit, static_argnames=("block", "interpret"))
def jacobi3d(b: jnp.ndarray, *, block: tuple[int, int, int] = (8, 8, 128),
             interpret: bool = True) -> jnp.ndarray:
    return _stencil.jacobi3d_blocked(b, block=block, interpret=interpret)


@partial(jax.jit, static_argnames=("block", "interpret"))
def jacobi3d_streaming(b: jnp.ndarray, *, block: tuple[int, int] = (8, 128),
                       interpret: bool = True) -> jnp.ndarray:
    return _stencil.jacobi3d_streaming(b, block=block, interpret=interpret)
