"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

One chunked linear-recurrence core serves both families::

    S_t = exp(log_a_t) * S_{t-1} + exp(log_b_t) * k_t v_t^T     (per head)
    y_t = q_t . S_t

* Mamba2: q=C, k=B (shared across heads), v=x, log_a=A*dt, log_b=log(dt).
* mLSTM:  q,k,v projections; log_a=logsigmoid(f), log_b=i (exp input
  gate); the normalizer n_t is carried as an extra value column (v
  augmented with ones), so y = (num . q) / max(den . q, 1).

The chunked form (intra-chunk quadratic + inter-chunk state scan) is the
TPU-native formulation: matmul-heavy, O(S) memory, parallel over chunks —
the paper's "adapt the access pattern to the memory hierarchy" applied to
recurrences. Decode is the O(1) state update.

sLSTM has genuine recurrent weight matrices (h_{t-1} feeds the gates), so
it cannot be chunk-parallelized; it runs as a lax.scan over time — slow
but faithful, and only 1-in-8 xLSTM blocks use it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .layers import DEFAULT_DTYPE, init_linear

__all__ = [
    "chunked_linear_attention",
    "linear_attention_step",
    "mamba2_init", "mamba2_apply", "mamba2_step",
    "mlstm_init", "mlstm_apply", "mlstm_step",
    "slstm_init", "slstm_apply",
]


# ---------------------------------------------------------------------------
# Shared chunked linear recurrence
# ---------------------------------------------------------------------------


def chunked_linear_attention(
    q: jnp.ndarray,       # (B,S,H,N)
    k: jnp.ndarray,       # (B,S,H,N)
    v: jnp.ndarray,       # (B,S,H,D)
    log_a: jnp.ndarray,   # (B,S,H)
    log_b: jnp.ndarray,   # (B,S,H)
    *, chunk: int = 256,
    initial_state: jnp.ndarray | None = None,  # (B,H,N,D)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,D), final_state (B,H,N,D)). f32 internal math."""
    B, S, H, N = q.shape
    D = v.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    f32 = jnp.float32
    qc = q.astype(f32).reshape(B, nc, chunk, H, N)
    kc = k.astype(f32).reshape(B, nc, chunk, H, N)
    vc = v.astype(f32).reshape(B, nc, chunk, H, D)
    la = log_a.astype(f32).reshape(B, nc, chunk, H)
    lb = log_b.astype(f32).reshape(B, nc, chunk, H)

    ca = jnp.cumsum(la, axis=2)                   # inclusive cumsum
    total = ca[:, :, -1]                          # (B,nc,H)

    # intra-chunk: scores[i,j] = q_i.k_j * exp(ca_i - ca_j + lb_j), i>=j
    gain = ca[:, :, :, None, :] - ca[:, :, None, :, :] + lb[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    gain = jnp.where(causal[None, None, :, :, None], gain, -jnp.inf)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", qc, kc) * jnp.exp(gain)
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", scores, vc)

    # per-chunk boundary states: S_c = sum_j exp(total - ca_j + lb_j) k_j v_j^T
    w = jnp.exp(total[:, :, None, :] - ca + lb)   # (B,nc,Q,H)
    S_c = jnp.einsum("bcjh,bcjhn,bcjhd->bchnd", w, kc, vc)

    # inter-chunk scan
    s0 = (jnp.zeros((B, H, N, D), f32) if initial_state is None
          else initial_state.astype(f32))
    decay = jnp.exp(total)                        # (B,nc,H)

    def body(s_prev, inp):
        s_chunk, dec = inp                        # (B,H,N,D), (B,H)
        s_new = dec[:, :, None, None] * s_prev + s_chunk
        return s_new, s_prev

    _, s_prevs = jax.lax.scan(
        body, s0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(decay, 1, 0)),
    )
    final = body(s_prevs[-1],
                 (S_c[:, -1], decay[:, -1]))[0]
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)         # (B,nc,H,N,D)

    y_inter = jnp.einsum(
        "bcihn,bchnd->bcihd", qc * jnp.exp(ca)[..., None], s_prevs
    )
    y = (y_intra + y_inter).reshape(B, S, H, D)
    return y.astype(q.dtype), final


def linear_attention_step(
    state: jnp.ndarray,   # (B,H,N,D)
    q: jnp.ndarray,       # (B,H,N)
    k: jnp.ndarray,
    v: jnp.ndarray,       # (B,H,D)
    log_a: jnp.ndarray,   # (B,H)
    log_b: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step. Returns (y (B,H,D), new_state)."""
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    b = jnp.exp(log_b.astype(f32))[..., None, None]
    outer = k.astype(f32)[..., :, None] * v.astype(f32)[..., None, :]
    new = a * state.astype(f32) + b * outer
    y = jnp.einsum("bhn,bhnd->bhd", q.astype(f32), new)
    return y.astype(q.dtype), new


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------


def mamba2_init(key, d: int, ssm, *, dtype=DEFAULT_DTYPE) -> dict:
    d_in = ssm.expand * d
    H = d_in // ssm.head_dim
    N = ssm.d_state
    ks = jax.random.split(key, 4)
    # in_proj emits [z | x | B | C | dt]
    d_proj = 2 * d_in + 2 * N + H
    conv_ch = d_in + 2 * N
    return {
        "w_in": init_linear(ks[0], d, d_proj, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (ssm.conv_width, conv_ch),
                                     jnp.float32) * 0.2).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "w_out": init_linear(ks[2], d_in, d, dtype=dtype),
    }


def _split_mamba(p, x, ssm, d_in, H, N):
    proj = x @ p["w_in"]
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1
    )
    return z, xs, Bm, Cm, dt


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv over (B,S,C) with taps (W,C).

    state (B, W-1, C) holds the trailing inputs from the previous call;
    returns (y, new_state).
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    y = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(y), up[:, -(W - 1):]


def mamba2_apply(p: dict, x: jnp.ndarray, ssm, *,
                 cache: dict | None = None) -> tuple[jnp.ndarray, dict | None]:
    """x: (B,S,d). cache={'state': (B,H,N,hd), 'conv': (B,W-1,C)} for decode."""
    B, S, d = x.shape
    d_in = ssm.expand * d
    H = d_in // ssm.head_dim
    N = ssm.d_state
    z, xs, Bm, Cm, dt = _split_mamba(p, x, ssm, d_in, H, N)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, p["conv_w"], None if cache is None else cache["conv"]
    )
    xs, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])
    log_a = dtf * A                                 # (B,S,H)
    log_b = jnp.log(dtf + 1e-9)

    xh = xs.reshape(B, S, H, ssm.head_dim)
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    kk = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))

    init = None if cache is None else cache["state"]
    if S == 1 and cache is not None:
        y1, new_state = linear_attention_step(
            init, q[:, 0], kk[:, 0], xh[:, 0], log_a[:, 0], log_b[:, 0]
        )
        y = y1[:, None]
    else:
        y, new_state = chunked_linear_attention(
            q, kk, xh, log_a, log_b, chunk=ssm.chunk, initial_state=init
        )
    y = (y.astype(jnp.float32)
         + xh.astype(jnp.float32) * p["D"][None, None, :, None]).astype(x.dtype)
    y = y.reshape(B, S, d_in) * jax.nn.silu(z)
    out = y @ p["w_out"]
    new_cache = None
    if cache is not None:
        new_cache = {"state": new_state, "conv": conv_state}
    return out, new_cache


def mamba2_step(p, x1, ssm, cache):
    """Convenience: single-token decode. x1: (B,1,d)."""
    return mamba2_apply(p, x1, ssm, cache=cache)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------


def mlstm_init(key, d: int, n_heads: int, head_dim: int,
               *, dtype=DEFAULT_DTYPE) -> dict:
    ks = jax.random.split(key, 6)
    hh = n_heads * head_dim
    return {
        "w_q": init_linear(ks[0], d, hh, dtype=dtype),
        "w_k": init_linear(ks[1], d, hh, dtype=dtype),
        "w_v": init_linear(ks[2], d, hh, dtype=dtype),
        "w_if": init_linear(ks[3], d, 2 * n_heads, dtype=dtype),  # i,f gates
        "w_o": init_linear(ks[4], hh, d, dtype=dtype),
        "w_og": init_linear(ks[5], d, hh, dtype=dtype),           # output gate
    }


def mlstm_apply(p: dict, x: jnp.ndarray, *, n_heads: int, head_dim: int,
                chunk: int = 256,
                cache: dict | None = None) -> tuple[jnp.ndarray, dict | None]:
    B, S, d = x.shape
    H, Dh = n_heads, head_dim
    q = (x @ p["w_q"]).reshape(B, S, H, Dh) / float(np.sqrt(Dh))
    k = (x @ p["w_k"]).reshape(B, S, H, Dh) / float(np.sqrt(Dh))
    v = (x @ p["w_v"]).reshape(B, S, H, Dh)
    gates = (x @ p["w_if"]).astype(jnp.float32).reshape(B, S, H, 2)
    log_f = jax.nn.log_sigmoid(gates[..., 1])
    log_i = -jax.nn.softplus(-gates[..., 0]) - 2.0  # bounded exp input gate

    # carry the normalizer as an extra value column
    v_aug = jnp.concatenate(
        [v, jnp.ones((B, S, H, 1), v.dtype)], axis=-1
    )
    init = None if cache is None else cache["state"]
    if S == 1 and cache is not None:
        y1, new_state = linear_attention_step(
            init, q[:, 0], k[:, 0], v_aug[:, 0], log_f[:, 0], log_i[:, 0]
        )
        y = y1[:, None]
    else:
        y, new_state = chunked_linear_attention(
            q, k, v_aug, log_f, log_i, chunk=chunk, initial_state=init
        )
    num, den = y[..., :Dh], y[..., Dh:]
    yn = num / jnp.maximum(jnp.abs(den), 1.0)
    yn = yn.reshape(B, S, H * Dh) * jax.nn.silu(x @ p["w_og"])
    out = yn @ p["w_o"]
    new_cache = None if cache is None else {"state": new_state}
    return out, new_cache


def mlstm_step(p, x1, *, n_heads, head_dim, cache):
    return mlstm_apply(p, x1, n_heads=n_heads, head_dim=head_dim, cache=cache)


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory recurrent block; sequential over time)
# ---------------------------------------------------------------------------


def slstm_init(key, d: int, n_heads: int, *, dtype=DEFAULT_DTYPE) -> dict:
    dh = d // n_heads
    ks = jax.random.split(key, 3)
    return {
        "w_x": init_linear(ks[0], d, 4 * d, dtype=dtype),
        # block-diagonal recurrent weights, one (dh, 4dh) block per head
        "r_h": (jax.random.normal(ks[1], (n_heads, dh, 4 * dh), jnp.float32)
                / np.sqrt(dh)).astype(dtype),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "w_o": init_linear(ks[2], d, d, dtype=dtype),
    }


def slstm_apply(p: dict, x: jnp.ndarray, *, n_heads: int,
                cache: dict | None = None) -> tuple[jnp.ndarray, dict | None]:
    """Sequential scan over time; state = (h, c, n) each (B, d)."""
    B, S, d = x.shape
    H = n_heads
    dh = d // H
    wx = (x @ p["w_x"]).astype(jnp.float32) + p["bias"]     # (B,S,4d)

    def step(carry, wx_t):
        h, c, n = carry                                     # (B,d) f32
        hh = h.reshape(B, H, dh)
        rec = jnp.einsum("bhd,hdk->bhk", hh,
                         p["r_h"].astype(jnp.float32)).reshape(B, 4 * d)
        zifo = wx_t + rec
        z, i, f, o = jnp.split(zifo, 4, axis=-1)
        z = jnp.tanh(z)
        i = jnp.exp(jnp.minimum(i, 10.0))
        f = jax.nn.sigmoid(f)
        o = jax.nn.sigmoid(o)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (h, c, n), h

    if cache is None:
        h0 = jnp.zeros((B, d), jnp.float32)
        carry = (h0, h0, h0)
    else:
        carry = cache["hcn"]
    carry, hs = jax.lax.scan(step, carry, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype) @ p["w_o"]
    new_cache = None if cache is None else {"hcn": carry}
    return y, new_cache
