from . import attention, layers, lm, moe, ssm  # noqa: F401
from .moe import Parallelism

__all__ = ["attention", "layers", "lm", "moe", "ssm", "Parallelism"]
