"""Decoder-LM assembly for all 10 assigned architectures.

A single ``init``/``apply`` pair covers the zoo; family differences are
config-driven:

* dense / audio / vlm — uniform GQA+MLP blocks, scanned over layers.
  gemma3's 5:1 local:global pattern is a per-layer (window, rope_theta)
  array scanned alongside the params. musicgen adds per-layer
  cross-attention to the (stub) conditioning sequence. phi-3-vision
  consumes stub patch embeddings concatenated before the text tokens.
* moe — ``first_k_dense`` dense blocks (unrolled) + scanned MLA+MoE blocks.
* ssm (xlstm) — groups of (slstm_every-1) mLSTM + 1 sLSTM, scanned over
  groups.
* hybrid (zamba2) — groups of ``hybrid_attn_every`` Mamba2 blocks + one
  *shared* (weight-tied) attention block, scanned over groups; trailing
  mamba blocks unrolled.

Caches are pytrees with leading layer axes, scanned in lockstep with the
params during decode. ``apply`` is mode-agnostic: ``cache=None`` is
train/score, a fresh cache is prefill, a filled cache is decode.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ArchConfig
from .attention import gqa_apply, gqa_init, mla_apply, mla_init
from .layers import (
    DEFAULT_DTYPE,
    cross_entropy_loss,
    init_embed,
    mlp_apply,
    mlp_init,
    rms_norm,
)
from .moe import Parallelism, moe_apply, moe_init
from .ssm import (
    mamba2_apply,
    mamba2_init,
    mlstm_apply,
    mlstm_init,
    slstm_apply,
    slstm_init,
)

__all__ = ["init_params", "apply", "init_cache", "Parallelism", "loss_fn"]

AUX_COEF = 1e-3


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _stack_init(n: int, fn, key):
    """vmap an init over a leading layer axis."""
    return jax.vmap(fn)(jax.random.split(key, n))


def _constrain(x, par: Parallelism | None, spec: P):
    if par is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(par.mesh, spec))


def _pin_layer(lp, par: Parallelism | None):
    """Constrain one layer's param slice to its partition spec inside the
    layer scan. The constraint's transpose pins the per-layer *gradient*
    slices too, which keeps the scan-transpose's stacked grads sharded
    (without it GSPMD materializes them DP-replicated: params-sized x
    dp_size temporaries — the dominant train-memory term at 123B+)."""
    if par is None:
        return lp
    from repro.runtime.sharding import param_specs  # lazy: no cycle

    specs = param_specs(lp, par)
    return jax.tree.map(
        lambda a, s: jax.lax.with_sharding_constraint(
            a, NamedSharding(par.mesh, s)),
        lp, specs,
    )


def _norm_gamma(d):
    return jnp.zeros((d,), jnp.float32)


def _gemma_layer_meta(cfg: ArchConfig):
    """Per-layer (window, theta) arrays for the local/global pattern."""
    wins, thetas = [], []
    for l in range(cfg.n_layers):
        is_global = cfg.global_every and ((l + 1) % cfg.global_every == 0)
        wins.append(0 if is_global else cfg.window)
        thetas.append(cfg.rope_theta if is_global else 1e4)
    return (jnp.array(wins, jnp.int32), jnp.array(thetas, jnp.float32))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig, *, dtype=DEFAULT_DTYPE) -> dict:
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict[str, Any] = {"emb": init_embed(keys[0], cfg.vocab_size, d, dtype=dtype),
                         "ln_f": _norm_gamma(d)}
    if not cfg.tie_embeddings:
        p["unemb"] = init_embed(keys[1], cfg.vocab_size, d, dtype=dtype)

    fam = cfg.family

    if fam in ("dense", "audio", "vlm"):
        def block(k):
            ks = jax.random.split(k, 4)
            blk = {
                "ln1": _norm_gamma(d), "ln2": _norm_gamma(d),
                "attn": gqa_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, dtype=dtype),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.act, dtype=dtype),
            }
            if fam == "audio":
                blk["ln_x"] = _norm_gamma(d)
                blk["xattn"] = gqa_init(ks[2], d, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.head_dim, dtype=dtype)
            return blk

        p["layers"] = _stack_init(cfg.n_layers, block, keys[2])

    elif fam == "moe":
        def dense_block(k):
            ks = jax.random.split(k, 2)
            return {
                "ln1": _norm_gamma(d), "ln2": _norm_gamma(d),
                "attn": mla_init(ks[0], d, cfg.n_heads, cfg.mla, dtype=dtype),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.act, dtype=dtype),
            }

        def moe_block(k):
            ks = jax.random.split(k, 2)
            return {
                "ln1": _norm_gamma(d), "ln2": _norm_gamma(d),
                "attn": mla_init(ks[0], d, cfg.n_heads, cfg.mla, dtype=dtype),
                "moe": moe_init(ks[1], d, cfg.moe, dtype=dtype),
            }

        nd = cfg.moe.first_k_dense
        p["dense_layers"] = [
            dense_block(k) for k in jax.random.split(keys[2], nd)
        ]
        p["layers"] = _stack_init(cfg.n_layers - nd, moe_block, keys[3])

    elif fam == "ssm":  # xlstm
        ssm = cfg.ssm
        per = ssm.slstm_every or cfg.n_layers + 1
        n_groups = max(1, cfg.n_layers // per)
        n_m = per - 1 if ssm.slstm_every else cfg.n_layers

        def group(k):
            ks = jax.random.split(k, n_m + 1)
            g = {
                "mlstm": jax.vmap(
                    lambda kk: {
                        "ln": _norm_gamma(d),
                        "blk": mlstm_init(kk, d, cfg.n_heads,
                                          ssm.head_dim, dtype=dtype),
                    }
                )(jnp.stack(ks[:n_m])),
            }
            if ssm.slstm_every:
                g["slstm"] = {"ln": _norm_gamma(d),
                              "blk": slstm_init(ks[-1], d, cfg.n_heads,
                                                dtype=dtype)}
            return g

        p["groups"] = _stack_init(n_groups, group, keys[2])

    elif fam == "hybrid":  # zamba2
        ssm = cfg.ssm
        per = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // per
        n_tail = cfg.n_layers - n_groups * per

        def mamba_block(k):
            return {"ln": _norm_gamma(d),
                    "blk": mamba2_init(k, d, ssm, dtype=dtype)}

        def group(k):
            ks = jax.random.split(k, per)
            return {"mamba": jax.vmap(mamba_block)(jnp.stack(ks))}

        p["groups"] = _stack_init(n_groups, group, keys[2])
        if n_tail:
            p["tail"] = _stack_init(n_tail, mamba_block, keys[3])
        ks = jax.random.split(keys[4], 2)
        p["shared_attn"] = {
            "ln1": _norm_gamma(d), "ln2": _norm_gamma(d),
            "attn": gqa_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim, dtype=dtype),
            "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.act, dtype=dtype),
        }
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               *, dtype=DEFAULT_DTYPE) -> dict:
    """Allocate decode caches (leading layer axes match the param stacks).

    Local/sliding-window attention layers (gemma3's 5-in-6, zamba2's
    shared block) get *ring* caches bounded at the window size — the
    memory-pattern optimization from EXPERIMENTS.md §Perf: a 32k-context
    gemma3 decode cache shrinks ~25x vs uniform full-length stacks.
    """
    from .attention import RING_EMPTY_POS

    d, fam = cfg.d_model, cfg.family
    z = jnp.zeros
    kvhd = (cfg.n_kv_heads, cfg.head_dim)
    if fam in ("dense", "audio", "vlm"):
        L = cfg.n_layers
        if cfg.global_every and cfg.window:
            # grouped layout: (per-1) local ring layers + 1 global per group
            per = cfg.global_every
            G = L // per
            n_tail = L - G * per
            W = min(max_len, cfg.window + 1)
            c = {
                "local_k": z((G, per - 1, batch, W) + kvhd, dtype),
                "local_v": z((G, per - 1, batch, W) + kvhd, dtype),
                "local_pos": jnp.full((G, per - 1, W), RING_EMPTY_POS,
                                      jnp.int32),
                "k": z((G, batch, max_len) + kvhd, dtype),
                "v": z((G, batch, max_len) + kvhd, dtype),
                "len": jnp.zeros((), jnp.int32),
            }
            if n_tail:
                c["tail_k"] = z((n_tail, batch, W) + kvhd, dtype)
                c["tail_v"] = z((n_tail, batch, W) + kvhd, dtype)
                c["tail_pos"] = jnp.full((n_tail, W), RING_EMPTY_POS,
                                         jnp.int32)
            return c
        return {
            "k": z((L, batch, max_len) + kvhd, dtype),
            "v": z((L, batch, max_len) + kvhd, dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    if fam == "moe":
        L = cfg.n_layers
        mla = cfg.mla
        return {
            "ckv": z((L, batch, max_len, mla.kv_lora_rank), dtype),
            "krope": z((L, batch, max_len, mla.qk_rope_head_dim), dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    if fam == "ssm":
        ssm = cfg.ssm
        per = ssm.slstm_every or cfg.n_layers + 1
        n_groups = max(1, cfg.n_layers // per)
        n_m = per - 1 if ssm.slstm_every else cfg.n_layers
        H, Dh = cfg.n_heads, ssm.head_dim
        c = {
            "mlstm": z((n_groups, n_m, batch, H, Dh, Dh + 1), jnp.float32),
        }
        if ssm.slstm_every:
            c["slstm"] = tuple(
                z((n_groups, batch, d), jnp.float32) for _ in range(3)
            )
        c["len"] = jnp.zeros((), jnp.int32)
        return c
    if fam == "hybrid":
        ssm = cfg.ssm
        per = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // per
        n_tail = cfg.n_layers - n_groups * per
        d_in = ssm.expand * d
        H = d_in // ssm.head_dim
        conv_ch = d_in + 2 * ssm.d_state
        attn_len = min(max_len, cfg.window) if cfg.window else max_len

        def mamba_cache(lead):
            return {
                "state": z(lead + (batch, H, ssm.d_state, ssm.head_dim),
                           jnp.float32),
                "conv": z(lead + (batch, ssm.conv_width - 1, conv_ch), dtype),
            }

        W = min(max_len, cfg.window + 1) if cfg.window else max_len
        c = {
            "groups": mamba_cache((n_groups, per)),
            "attn_k": z((n_groups, batch, W, cfg.n_kv_heads,
                         cfg.head_dim), dtype),
            "attn_v": z((n_groups, batch, W, cfg.n_kv_heads,
                         cfg.head_dim), dtype),
            "attn_pos": jnp.full((n_groups, W), RING_EMPTY_POS, jnp.int32),
            "len": jnp.zeros((), jnp.int32),
        }
        if n_tail:
            c["tail"] = mamba_cache((n_tail,))
        return c
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def apply(
    params: dict,
    cfg: ArchConfig,
    *,
    tokens: jnp.ndarray | None = None,       # (B, S) int32
    embeds: jnp.ndarray | None = None,       # (B, S, d) — frontends
    prefix_embeds: jnp.ndarray | None = None,  # vlm patch embeddings
    cond: jnp.ndarray | None = None,         # audio conditioning (B, Tc, d)
    positions: jnp.ndarray | None = None,
    cache: dict | None = None,
    par: Parallelism | None = None,
    remat: bool = True,
) -> tuple[jnp.ndarray, dict | None, jnp.ndarray]:
    """Run the backbone. Returns (hidden (B,S,d), new_cache, aux_loss)."""
    d = cfg.d_model
    if embeds is None:
        if par is not None and par.vocab_axis in par.batch_axes:
            # keep token ids off the vocab axis so the vocab-sharded table
            # is gathered per-shard, not replicated (see loss_fn note)
            ba = tuple(a for a in par.batch_axes if a != par.vocab_axis)
            tokens = _constrain(tokens, par, P(ba if ba else None, None))
        embeds = jnp.take(params["emb"], tokens, axis=0)
        if cfg.family == "dense" and cfg.tie_embeddings:
            embeds = embeds * jnp.asarray(np.sqrt(d), embeds.dtype)
    if prefix_embeds is not None:
        embeds = jnp.concatenate([prefix_embeds.astype(embeds.dtype), embeds],
                                 axis=1)
    B, S, _ = embeds.shape
    if positions is None:
        start = cache["len"] if cache is not None else 0
        positions = start + jnp.arange(S)[None, :].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (B, S))

    bspec = P((par.act_axes or None) if par else None, None, None)
    x = _constrain(embeds, par, bspec)
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family
    new_cache = None

    def maybe_remat(fn):
        return jax.checkpoint(fn) if (remat and cache is None) else fn

    if fam in ("dense", "audio", "vlm"):
        wins, thetas = (
            _gemma_layer_meta(cfg) if cfg.global_every
            else (jnp.zeros((cfg.n_layers,), jnp.int32) + cfg.window,
                  jnp.full((cfg.n_layers,), cfg.rope_theta, jnp.float32))
        )

        def block(x, lp, lc, win, theta, *, ring=False):
            h, a_cache = gqa_apply(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.rmsnorm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, positions=positions,
                rope_theta=theta, window=win,
                cache=None if lc is None else {**lc, "len": cache["len"]},
                ring=ring,
            )
            x = _constrain(x + h, par, bspec)
            if fam == "audio":
                # cross-attention to the conditioning sequence (stub T5 enc)
                xh, _ = gqa_apply(
                    lp["xattn"], rms_norm(x, lp["ln_x"], cfg.rmsnorm_eps),
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    head_dim=cfg.head_dim, positions=positions,
                    causal=False, kv_seq=cond,
                )
                x = _constrain(x + xh, par, bspec)
            m = mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"], cfg.rmsnorm_eps),
                          cfg.act)
            x = _constrain(x + m, par, bspec)
            return x, a_cache

        block = maybe_remat(block)
        if cache is not None and cfg.global_every and cfg.window:
            # ---- serve path, gemma3 grouped local/global caches --------
            per = cfg.global_every
            G = cfg.n_layers // per
            n_tail = cfg.n_layers - G * per
            main_p = jax.tree.map(
                lambda a: a[:G * per].reshape((G, per) + a.shape[1:]),
                params["layers"])
            tail_p = (jax.tree.map(lambda a: a[G * per:], params["layers"])
                      if n_tail else None)

            def group_body(carry, inp):
                x, gk, gv = carry
                gp, lk, lv, lpos, g = inp
                new_lk, new_lv, new_lpos = [], [], []
                for i in range(per - 1):  # local ring layers
                    lp = jax.tree.map(lambda a: a[i], gp)
                    lc = {"k": lk[i], "v": lv[i], "pos": lpos[i]}
                    x, nc = block(x, lp, lc, cfg.window, 1e4, ring=True)
                    new_lk.append(nc["k"])
                    new_lv.append(nc["v"])
                    new_lpos.append(nc["pos"])
                # global layer (last in group) — full-length carried cache
                lp = jax.tree.map(lambda a: a[per - 1], gp)
                ck = jax.lax.dynamic_index_in_dim(gk, g, 0, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(gv, g, 0, keepdims=False)
                x, nc = block(x, lp, {"k": ck, "v": cv}, 0, cfg.rope_theta)
                gk = jax.lax.dynamic_update_index_in_dim(gk, nc["k"], g, 0)
                gv = jax.lax.dynamic_update_index_in_dim(gv, nc["v"], g, 0)
                ys = (jnp.stack(new_lk), jnp.stack(new_lv),
                      jnp.stack(new_lpos))
                return (x, gk, gv), ys

            (x, gk, gv), (nlk, nlv, nlpos) = jax.lax.scan(
                group_body, (x, cache["k"], cache["v"]),
                (main_p, cache["local_k"], cache["local_v"],
                 cache["local_pos"], jnp.arange(G)),
            )
            new_cache = dict(cache)
            new_cache.update({"k": gk, "v": gv, "local_k": nlk,
                              "local_v": nlv, "local_pos": nlpos,
                              "len": cache["len"] + S})
            if n_tail:
                tks, tvs, tps = [], [], []
                for t in range(n_tail):
                    lp = jax.tree.map(lambda a: a[t], tail_p)
                    lc = {"k": cache["tail_k"][t], "v": cache["tail_v"][t],
                          "pos": cache["tail_pos"][t]}
                    x, nc = block(x, lp, lc, cfg.window, 1e4, ring=True)
                    tks.append(nc["k"])
                    tvs.append(nc["v"])
                    tps.append(nc["pos"])
                new_cache["tail_k"] = jnp.stack(tks)
                new_cache["tail_v"] = jnp.stack(tvs)
                new_cache["tail_pos"] = jnp.stack(tps)
        elif cache is not None:
            # ---- serve path, uniform layers: carry the stacked cache so
            # the while loop updates it in place (no xs/ys double buffer)
            def scan_body(carry, inp):
                x, ck, cv = carry
                lp, win, theta, l = inp
                lc = {
                    "k": jax.lax.dynamic_index_in_dim(ck, l, 0, False),
                    "v": jax.lax.dynamic_index_in_dim(cv, l, 0, False),
                }
                x, nc = block(x, lp, lc, win, theta)
                ck = jax.lax.dynamic_update_index_in_dim(ck, nc["k"], l, 0)
                cv = jax.lax.dynamic_update_index_in_dim(cv, nc["v"], l, 0)
                return (x, ck, cv), None

            (x, ck, cv), _ = jax.lax.scan(
                scan_body, (x, cache["k"], cache["v"]),
                (params["layers"], wins, thetas, jnp.arange(cfg.n_layers)),
            )
            new_cache = dict(cache)
            new_cache.update({"k": ck, "v": cv, "len": cache["len"] + S})
        else:
            # ---- train/score path: plain scan over rematted layers ------
            def scan_body(x, inp):
                lp, win, theta = inp
                x, _ = block(x, lp, None, win, theta)
                return x, None

            x, _ = jax.lax.scan(scan_body, x,
                                (params["layers"], wins, thetas))

    elif fam == "moe":
        nd = cfg.moe.first_k_dense

        def mla_block(x, lp, lc, moe_layer: bool):
            h, a_cache = mla_apply(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.rmsnorm_eps),
                n_heads=cfg.n_heads, mla=cfg.mla, positions=positions,
                rope_theta=cfg.rope_theta,
                cache=None if lc is None else
                {"ckv": lc["ckv"], "krope": lc["krope"], "len": cache["len"]},
            )
            x = _constrain(x + h, par, bspec)
            xn = rms_norm(x, lp["ln2"], cfg.rmsnorm_eps)
            if moe_layer:
                m, aux = moe_apply(lp["moe"], xn, cfg.moe, par=par,
                                   act=cfg.act)
            else:
                m, aux = mlp_apply(lp["mlp"], xn, cfg.act), 0.0
            x = _constrain(x + m, par, bspec)
            new_lc = (None if a_cache is None else
                      {"ckv": a_cache["ckv"], "krope": a_cache["krope"]})
            return x, new_lc, aux

        mla_block_r = maybe_remat(partial(mla_block, moe_layer=True))
        ckv_buf = cache["ckv"] if cache is not None else None
        krope_buf = cache["krope"] if cache is not None else None
        for l in range(nd):
            lc = (None if cache is None else
                  {"ckv": ckv_buf[l], "krope": krope_buf[l]})
            x, new_lc, aux = mla_block(x, params["dense_layers"][l], lc,
                                       moe_layer=False)
            if cache is not None:
                ckv_buf = ckv_buf.at[l].set(new_lc["ckv"])
                krope_buf = krope_buf.at[l].set(new_lc["krope"])

        if cache is not None:
            # carry the stacked cache buffers: in-place while-loop updates
            def scan_body(carry, inp):
                x, aux_t, cb, kb = carry
                lp, l = inp
                lc = {
                    "ckv": jax.lax.dynamic_index_in_dim(cb, l, 0, False),
                    "krope": jax.lax.dynamic_index_in_dim(kb, l, 0, False),
                }
                x, new_lc, aux = mla_block_r(x, lp, lc)
                cb = jax.lax.dynamic_update_index_in_dim(
                    cb, new_lc["ckv"], l, 0)
                kb = jax.lax.dynamic_update_index_in_dim(
                    kb, new_lc["krope"], l, 0)
                return (x, aux_t + aux, cb, kb), None

            (x, aux_total, ckv_buf, krope_buf), _ = jax.lax.scan(
                scan_body, (x, aux_total, ckv_buf, krope_buf),
                (params["layers"], nd + jnp.arange(cfg.n_layers - nd)),
            )
            new_cache = dict(cache)
            new_cache.update({"ckv": ckv_buf, "krope": krope_buf,
                              "len": cache["len"] + S})
        else:
            def scan_body(carry, lp):
                x, aux_t = carry
                x, _, aux = mla_block_r(x, lp, None)
                return (x, aux_t + aux), None

            (x, aux_total), _ = jax.lax.scan(
                scan_body, (x, aux_total), params["layers"]
            )

    elif fam == "ssm":
        ssm = cfg.ssm
        n_m = (ssm.slstm_every - 1) if ssm.slstm_every else cfg.n_layers

        def group_body(x, gp, gc):
            new_m, new_s = [], None
            for i in range(n_m):
                lp = jax.tree.map(lambda a: a[i], gp["mlstm"])
                lc = (None if gc is None else {"state": gc["mlstm"][i]})
                h, nc = mlstm_apply(
                    lp["blk"], rms_norm(x, lp["ln"], cfg.rmsnorm_eps),
                    n_heads=cfg.n_heads, head_dim=ssm.head_dim,
                    chunk=ssm.chunk, cache=lc,
                )
                x = _constrain(x + h, par, bspec)
                if nc is not None:
                    new_m.append(nc["state"])
            if ssm.slstm_every:
                sp = gp["slstm"]
                lc = (None if gc is None else {"hcn": gc["slstm"]})
                h, nc = slstm_apply(
                    sp["blk"], rms_norm(x, sp["ln"], cfg.rmsnorm_eps),
                    n_heads=cfg.n_heads, cache=lc,
                )
                x = _constrain(x + h, par, bspec)
                if nc is not None:
                    new_s = nc["hcn"]
            ngc = None
            if gc is not None:
                ngc = {"mlstm": jnp.stack(new_m)}
                if new_s is not None:
                    ngc["slstm"] = new_s
            return x, ngc

        group_body = maybe_remat(group_body)
        gcs = None
        if cache is not None:
            gcs = {"mlstm": cache["mlstm"]}
            if ssm.slstm_every:
                gcs["slstm"] = cache["slstm"]

        def scan_body(x, inp):
            gp, gc = inp
            return group_body(x, gp, gc)

        x, new_gcs = jax.lax.scan(scan_body, x, (params["groups"], gcs))
        if cache is not None:
            new_cache = dict(cache)
            new_cache["mlstm"] = new_gcs["mlstm"]
            if ssm.slstm_every:
                new_cache["slstm"] = new_gcs["slstm"]
            new_cache["len"] = cache["len"] + S

    elif fam == "hybrid":
        ssm = cfg.ssm
        per = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // per
        n_tail = cfg.n_layers - n_groups * per
        sa = params["shared_attn"]

        def mamba_one(x, lp, lc):
            h, nc = mamba2_apply(
                lp["blk"], rms_norm(x, lp["ln"], cfg.rmsnorm_eps), ssm,
                cache=lc,
            )
            return _constrain(x + h, par, bspec), nc

        def group_body(x, gp, gc):
            new_mc = []
            for i in range(per):
                lp = jax.tree.map(lambda a: a[i], gp["mamba"])
                lc = (None if gc is None else
                      jax.tree.map(lambda a: a[i], gc["mamba"]))
                x, nc = mamba_one(x, lp, lc)
                if nc is not None:
                    new_mc.append(nc)
            # shared attention block (weight-tied across groups); the KV
            # cache is a window-bounded ring (cfg.window)
            a_lc = None
            if gc is not None:
                a_lc = {"k": gc["attn_k"], "v": gc["attn_v"],
                        "pos": gc["attn_pos"], "len": cache["len"]}
            h, a_cache = gqa_apply(
                sa["attn"], rms_norm(x, sa["ln1"], cfg.rmsnorm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, positions=positions,
                rope_theta=cfg.rope_theta, window=cfg.window,
                cache=a_lc, ring=gc is not None,
            )
            x = _constrain(x + h, par, bspec)
            m = mlp_apply(sa["mlp"], rms_norm(x, sa["ln2"], cfg.rmsnorm_eps),
                          cfg.act)
            x = _constrain(x + m, par, bspec)
            ngc = None
            if gc is not None:
                ngc = {
                    "mamba": jax.tree.map(
                        lambda *a: jnp.stack(a), *new_mc
                    ),
                    "attn_k": a_cache["k"], "attn_v": a_cache["v"],
                    "attn_pos": a_cache["pos"],
                }
            return x, ngc

        group_body = maybe_remat(group_body)
        gcs = None
        if cache is not None:
            gcs = {"mamba": cache["groups"], "attn_k": cache["attn_k"],
                   "attn_v": cache["attn_v"], "attn_pos": cache["attn_pos"]}

        def scan_body(x, inp):
            gp, gc = inp
            return group_body(x, gp, gc)

        x, new_gcs = jax.lax.scan(scan_body, x, (params["groups"], gcs))
        new_tail = []
        if n_tail:
            for i in range(n_tail):
                lp = jax.tree.map(lambda a: a[i], params["tail"])
                lc = (None if cache is None else
                      jax.tree.map(lambda a: a[i], cache["tail"]))
                x, nc = mamba_one(x, lp, lc)
                if nc is not None:
                    new_tail.append(nc)
        if cache is not None:
            new_cache = dict(cache)
            new_cache["groups"] = new_gcs["mamba"]
            new_cache["attn_k"] = new_gcs["attn_k"]
            new_cache["attn_v"] = new_gcs["attn_v"]
            new_cache["attn_pos"] = new_gcs["attn_pos"]
            if n_tail:
                new_cache["tail"] = jax.tree.map(
                    lambda *a: jnp.stack(a), *new_tail
                )
            new_cache["len"] = cache["len"] + S
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["ln_f"], cfg.rmsnorm_eps)
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def unembed_table(params: dict, cfg: ArchConfig) -> jnp.ndarray:
    return params["emb"] if cfg.tie_embeddings else params["unemb"]


def loss_fn(params: dict, cfg: ArchConfig, batch: dict,
            par: Parallelism | None = None, remat: bool = True) -> jnp.ndarray:
    """Causal-LM loss over a batch dict (see launch.dryrun.input_specs)."""
    hidden, _, aux = apply(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("frame_embeds"),
        prefix_embeds=batch.get("vision_embeds"),
        cond=batch.get("cond"),
        par=par, remat=remat,
    )
    labels = batch["labels"]
    if par is not None and par.vocab_axis in par.batch_axes:
        # vocab-parallel loss (Megatron-style): tokens must not be sharded
        # over the vocab axis, or every device gathers the whole embedding
        # table (and its f32 gradient) — reshard batch off that axis here.
        ba = tuple(a for a in par.batch_axes if a != par.vocab_axis)
        hidden = _constrain(hidden, par, P(ba if ba else None, None, None))
        labels = _constrain(labels, par, P(ba if ba else None, None))
    loss = cross_entropy_loss(hidden, unembed_table(params, cfg), labels)
    return loss + AUX_COEF * aux
