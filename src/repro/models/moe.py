"""Mixture-of-Experts layer with expert parallelism (DeepSeek/Kimi family).

Routing: token-choice softmax top-k (DeepSeek-V2 style), renormalized over
the selected experts, with per-expert capacity ``C = T*k/E * cf`` and
deterministic weight-ranked capacity dropping.

Parallelism: experts are sharded over the EP axes (``model``, plus ``pod``
when the multi-pod mesh is up and the expert count divides); within each
device a ``lax.scan`` walks the local experts, each picking its top-C
assigned tokens (static shapes, no sort/a2a — the token set is replicated
over the EP axes because activations are only batch-sharded, so expert
output partial-sums reduce with one ``psum`` per layer). Optional FSDP
shards the expert d_model dim over ``data`` and all-gathers per layer —
ZeRO-3 semantics, required for the 1T-param config to fit HBM.

The same local kernel runs without shard_map for single-device smoke
tests (``par=None``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

try:  # top-level export landed after 0.4.x
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep -> check_vma
# independently of the export move; probe the actual signature
import inspect as _inspect

_SHARD_MAP_CHECK_KW = (
    "check_vma"
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else "check_rep"
)

from .layers import DEFAULT_DTYPE, init_linear

__all__ = ["moe_init", "moe_apply", "Parallelism"]


@dataclasses.dataclass(frozen=True)
class Parallelism:
    """Mesh + axis assignment threaded through model apply."""

    mesh: object                     # jax.sharding.Mesh
    dp_axes: tuple[str, ...] = ("data",)   # batch axes
    tp_axis: str = "model"
    ep_axes: tuple[str, ...] = ("model",)  # expert-parallel axes
    fsdp_axes: tuple[str, ...] = ()        # param-shard axes (ZeRO-3)
    pod_axis: str | None = None
    head_dim: int = 0                # head-aware K/V projection sharding
    vocab_axis: str | None = "model"  # embeddings shard here even with TP off
    # activations-only batch axes override. Big-model DECODE replicates
    # the (tiny) activations over data so FSDP-sharded weights compute
    # partial products + psum instead of being all-gathered per layer —
    # the dense-path twin of the MoE weight-stationary rule.
    act_batch_axes: tuple[str, ...] | None = None

    @property
    def batch_axes(self) -> tuple[str, ...]:
        if self.pod_axis and self.pod_axis not in self.ep_axes:
            return (self.pod_axis,) + self.dp_axes
        return ((self.pod_axis,) if self.pod_axis else ()) + self.dp_axes

    @property
    def act_axes(self) -> tuple[str, ...]:
        if self.act_batch_axes is not None:
            return self.act_batch_axes
        return self.batch_axes


def moe_init(key, d: int, moe, *, dtype=DEFAULT_DTYPE) -> dict:
    ks = jax.random.split(key, 5)
    E, fe = moe.n_routed, moe.d_ff_expert
    std = 1.0 / np.sqrt(d)

    def experts(k, d_in, d_out):
        return (jax.random.normal(k, (E, d_in, d_out), jnp.float32)
                * (1.0 / np.sqrt(d_in))).astype(dtype)

    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * std
                   ).astype(jnp.float32),  # router kept f32 for stable top-k
        "w_gate_e": experts(ks[1], d, fe),
        "w_in_e": experts(ks[2], d, fe),
        "w_out_e": (jax.random.normal(ks[3], (E, fe, d), jnp.float32)
                    * (1.0 / np.sqrt(fe))).astype(dtype),
    }
    if moe.n_shared:
        fs = moe.n_shared * fe
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": init_linear(kss[0], d, fs, dtype=dtype),
            "w_in": init_linear(kss[1], d, fs, dtype=dtype),
            "w_out": init_linear(kss[2], fs, d, dtype=dtype),
        }
    return p


def _local_moe(x2d, gates, w_gate, w_in, w_out, *, top_k: int, capacity: int,
               e_offset: jnp.ndarray | int,
               fsdp: tuple[str, ...] = ()):
    """Process this shard's experts for all (replicated) tokens.

    x2d: (T, d); gates: (T, E_global) f32 probabilities. w_gate/w_in are
    (E_local, d_local, fe) and w_out is (E_local, fe, d_local) where
    d_local = d / prod(fsdp) — the weight-stationary layout: instead of
    ZeRO-3 all-gathering O(GB) expert weights per layer, each fsdp peer
    computes partial products on its d-slice and psums the (C, fe) hidden
    activations — orders of magnitude fewer bytes for decode, and ~equal
    for prefill, with no weight-sized temporaries. Returns the partial
    output (T, d_local) — caller psums over EP axes and all-gathers the
    d_local dim over fsdp.
    """
    T, d = x2d.shape
    E_local = w_gate.shape[0]
    d_local = w_gate.shape[1]

    if fsdp:
        # this peer's d-slice of the (replicated-d) token matrix
        idx = 0
        for a in fsdp:
            idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
        x_l = jax.lax.dynamic_slice_in_dim(x2d, idx * d_local, d_local, 1)
    else:
        x_l = x2d

    # top-k over the *global* expert axis (identical on every EP peer)
    topv, topi = jax.lax.top_k(gates, top_k)              # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    def one_expert(acc, inp):
        w_g, w_i, w_o, e_local = inp
        e_id = e_offset + e_local
        # weight of this expert for each token (0 if not selected)
        sel = (topi == e_id)
        w_tok = jnp.where(sel, topv, 0.0).sum(-1)         # (T,)
        cw, ci = jax.lax.top_k(w_tok, capacity)           # deterministic drop
        xc = jnp.take(x_l, ci, axis=0)                    # (C, d_local)
        gate_h = xc @ w_g
        in_h = xc @ w_i
        if fsdp:  # complete the contraction over d before the nonlinearity
            gate_h = jax.lax.psum(gate_h, fsdp)
            in_h = jax.lax.psum(in_h, fsdp)
        h = jax.nn.silu(gate_h) * in_h
        out = (h @ w_o).astype(jnp.float32) * cw[:, None]  # (C, d_local)
        acc = acc.at[ci].add(jnp.where((cw > 0)[:, None], out, 0.0))
        return acc, None

    acc0 = jnp.zeros((T, d_local), jnp.float32)
    acc, _ = jax.lax.scan(
        one_expert, acc0,
        (w_gate, w_in, w_out, jnp.arange(E_local)),
    )
    return acc


def moe_apply(p: dict, x: jnp.ndarray, moe, *, par: Parallelism | None,
              act: str = "silu") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss). x: (B, S, d)."""
    B, S, d = x.shape
    E, k = moe.n_routed, moe.top_k
    x2d = x.reshape(B * S, d)
    gates = jax.nn.softmax((x2d.astype(jnp.float32) @ p["router"]), axis=-1)

    # Switch-style load-balance aux loss (fraction * probability per expert)
    topv, topi = jax.lax.top_k(gates, k)
    load = jnp.mean(
        jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1), axis=0
    )
    imp = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(load * imp) / k

    if par is None:
        capacity = min(B * S, max(1, int(B * S * k / E * moe.capacity_factor)))
        out = _local_moe(x2d, gates, p["w_gate_e"], p["w_in_e"], p["w_out_e"],
                         top_k=k, capacity=capacity, e_offset=0)
    else:
        ep = par.ep_axes
        ep_size = int(np.prod([par.mesh.shape[a] for a in ep]))
        if E % ep_size != 0:
            raise ValueError(f"{E} experts not divisible by EP={ep_size}")
        # tokens are replicated over EP axes (batch only shards dp axes);
        # keep only batch axes that divide the token count (B=1 decode
        # degrades to fully-replicated tokens)
        batch_spec: tuple[str, ...] = ()
        size = 1
        for a in par.act_axes:
            if a in ep:
                continue
            nxt = size * par.mesh.shape[a]
            if (B * S) % nxt == 0:
                batch_spec += (a,)
                size = nxt
        t_local = B * S // size
        capacity = min(t_local, max(1, int(t_local * k / E * moe.capacity_factor)))
        fsdp = tuple(a for a in par.fsdp_axes if a not in ep)

        xs = P(batch_spec if batch_spec else None, None)
        ws = P(ep, fsdp if fsdp else None, None)
        wos = P(ep, None, fsdp if fsdp else None)

        def shard_fn(x2d_l, gates_l, w_g, w_i, w_o):
            e_local = w_g.shape[0]
            e_off = _ep_offset(ep, e_local)
            out = _local_moe(x2d_l, gates_l, w_g, w_i, w_o,
                             top_k=k, capacity=capacity, e_offset=e_off,
                             fsdp=fsdp)
            for a in ep:
                out = jax.lax.psum(out, a)   # (T, d_local) partial-sum
            if fsdp:
                out = _allgather(out, fsdp, axis=1)  # (T, d)
            return out

        out = _shard_map(
            shard_fn, mesh=par.mesh,
            in_specs=(xs, xs, ws, ws, wos),
            out_specs=xs,
            **{_SHARD_MAP_CHECK_KW: False},
        )(x2d, gates, p["w_gate_e"], p["w_in_e"], p["w_out_e"])

    y = out.astype(x.dtype).reshape(B, S, d)

    if "shared" in p:
        sp = p["shared"]
        h = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_in"])
        y = y + h @ sp["w_out"]
    return y, aux


def _axis_size(a: str) -> int:
    if hasattr(jax.lax, "axis_size"):  # jax >= 0.5
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)  # 0.4.x: count participants


def _ep_offset(ep_axes: tuple[str, ...], e_local: int):
    idx = 0
    for a in ep_axes:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx * e_local


def _allgather(w, axes: tuple[str, ...], *, axis: int):
    for a in reversed(axes):
        w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
    return w
