"""Shared model building blocks: norms, RoPE, MLPs, embeddings, loss.

Conventions used across the model zoo:

* params are nested dicts of jnp arrays; weights live in bf16 (the v5e
  compute dtype), math that needs range runs in f32 and casts back;
* every constructor comes in (init, apply) pairs; layer stacks are built
  by vmapping init over a leading layer axis and scanning apply;
* logical sharding is attached *by name* via runtime.sharding rules — no
  sharding code in the layers themselves.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "rope",
    "init_linear",
    "init_embed",
    "mlp_init",
    "mlp_apply",
    "cross_entropy_loss",
]

Dtype = jnp.dtype
DEFAULT_DTYPE = jnp.bfloat16


def init_linear(key, d_in: int, d_out: int, *, scale: float | None = None,
                dtype=DEFAULT_DTYPE) -> jnp.ndarray:
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_embed(key, vocab: int, d: int, *, dtype=DEFAULT_DTYPE) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, *, theta: float = 1e4) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == cos.ndim + 1:  # head axis present: (..., S, H, D)
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, f: int, act: str, *, dtype=DEFAULT_DTYPE) -> dict:
    """act: "silu" (SwiGLU) | "geglu" (gated GELU, gemma) | "gelu" (plain)."""
    ks = jax.random.split(key, 3)
    p = {"w_out": init_linear(ks[2], f, d, dtype=dtype)}
    if act in ("silu", "geglu"):  # gated: gate + up projections
        p["w_gate"] = init_linear(ks[0], d, f, dtype=dtype)
        p["w_in"] = init_linear(ks[1], d, f, dtype=dtype)
    else:  # plain GELU MLP
        p["w_in"] = init_linear(ks[1], d, f, dtype=dtype)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_in"])
    else:
        h = jax.nn.gelu(x @ p["w_in"])
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# Loss (chunked over sequence so (B,S,V) logits never materialize)
# ---------------------------------------------------------------------------


def cross_entropy_loss(
    x: jnp.ndarray,            # (B, S, d) final hidden states
    emb: jnp.ndarray,          # (V, d) unembedding (tied or separate)
    labels: jnp.ndarray,       # (B, S) int32; -1 = masked
    *, chunks: int = 8,
) -> jnp.ndarray:
    """Mean masked token cross entropy, computed in S/chunks slabs."""
    B, S, d = x.shape
    chunks = min(chunks, S)
    while S % chunks:
        chunks -= 1
    C = S // chunks
    xc = x.reshape(B, chunks, C, d).swapaxes(0, 1)          # (chunks,B,C,d)
    lc = labels.reshape(B, chunks, C).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt = carry
        xs, ls = inp
        logits = (xs @ emb.T).astype(jnp.float32)           # (B,C,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(ls, 0)[..., None], axis=-1
        )[..., 0]
        mask = (ls >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - ll) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xc, lc)
    )
    return tot / jnp.maximum(cnt, 1.0)
