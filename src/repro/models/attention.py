"""Attention variants: GQA (full / sliding-window / cross), MLA.

Compute paths:

* ``chunked_attention`` — pure-JAX online-softmax (flash-style) attention:
  a scan over KV chunks carrying (m, l, acc). Bounded memory at any
  sequence length, so the 32k prefill and 512k decode shapes compile with
  flat VMEM/HBM footprints. This is the dry-run/default path; GSPMD
  shards it over batch/heads (and sequence for long decode).
* ``repro.kernels`` hosts the Pallas blocked kernels for the perf study;
  the model picks per config (``attn_impl``).

MLA (DeepSeek/Kimi) implements both the decompressed (train/prefill) and
the absorbed (decode) forms; the KV cache stores only the compressed
``c_kv`` + shared rope key — the technique's whole point (cache is
(B, S, kv_lora + rope) instead of (B, S, 2*H*hd)).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .layers import DEFAULT_DTYPE, init_linear, rope

__all__ = [
    "gqa_init",
    "gqa_apply",
    "mla_init",
    "mla_apply",
    "chunked_attention",
]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core: chunked online-softmax attention
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jnp.ndarray,              # (B, Sq, H, D)
    k: jnp.ndarray,              # (B, Sk, Hkv, D)
    v: jnp.ndarray,              # (B, Sk, Hkv, Dv)
    *,
    causal: bool,
    q_offset: jnp.ndarray | int = 0,   # absolute position of q[0]
    window: int | jnp.ndarray = 0,     # 0 = unbounded; may be traced (gemma3)
    kv_chunk: int = 1024,
    q_chunk: int = 4096,
    scale: float | None = None,
    kv_positions: jnp.ndarray | None = None,  # (Sk,) — ring caches
) -> jnp.ndarray:
    """Flash-style attention: scan over query blocks of an inner scan over
    KV chunks. Both loops bound the live set — the (m, l, acc) running
    state is (B, q_chunk, H) shaped regardless of sequence length, which
    is what lets prefill_32k / long_500k compile with flat footprints.
    ``kv_positions`` overrides the implied arange positions for ring
    (sliding-window) caches whose slots are not in position order; unused
    slots carry a huge positive position so the causal mask drops them.
    Returns (B, Sq, H, Dv)."""
    B, Sq, H, D = q.shape
    if Sq > q_chunk:
        qc = q_chunk
        while Sq % qc:
            qc -= 1
        nq = Sq // qc
        qb = jnp.moveaxis(q.reshape(B, nq, qc, H, D), 1, 0)

        def q_body(_, inp):
            qj, j = inp
            out = _chunked_attention_inner(
                qj, k, v, causal=causal, q_offset=q_offset + j * qc,
                window=window, kv_chunk=kv_chunk, scale=scale,
                kv_positions=kv_positions)
            return None, out

        _, outs = jax.lax.scan(q_body, None, (qb, jnp.arange(nq)))
        return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, v.shape[-1])
    return _chunked_attention_inner(
        q, k, v, causal=causal, q_offset=q_offset, window=window,
        kv_chunk=kv_chunk, scale=scale, kv_positions=kv_positions)


def _chunked_attention_inner(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool, q_offset, window, kv_chunk: int, scale: float | None,
    kv_positions: jnp.ndarray | None = None,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    g = H // Hkv                                   # queries per KV head
    scale = (1.0 / np.sqrt(D)) if scale is None else scale

    kv_chunk = min(kv_chunk, Sk)
    while Sk % kv_chunk:
        kv_chunk -= 1
    n_chunks = Sk // kv_chunk

    # operands stay in their input dtype (bf16 on TPU); all reductions
    # accumulate in f32 via preferred_element_type — the flash recipe.
    # Heads stay FLAT: a (Hkv, g) reshape of a head-sharded query is not
    # representable in GSPMD (SPMD "involuntary full rematerialization"
    # per chunk); instead each KV chunk is broadcast to the query heads —
    # a local repeat of a VMEM-sized tile, free of collectives.
    qs = q * jnp.asarray(scale, q.dtype)           # (B,Sq,H,D)
    kc = jnp.moveaxis(k.reshape(B, n_chunks, kv_chunk, Hkv, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, kv_chunk, Hkv, Dv), 1, 0)
    if kv_positions is not None:
        pc = jnp.moveaxis(kv_positions.reshape(1, n_chunks, kv_chunk), 1, 0)
    else:
        pc = jnp.zeros((n_chunks, 1, 1), jnp.int32)  # unused placeholder

    q_pos = jnp.arange(Sq) + q_offset              # absolute q positions

    def body(carry, inp):
        m, l, acc = carry                          # (B,Sq,H), same, (..,Dv)
        kj, vj, pj, j = inp
        if g > 1:
            kj = jnp.repeat(kj, g, axis=2)         # (B,C,H,D) local tile
            vj = jnp.repeat(vj, g, axis=2)
        # scores: (B, Sq, H, C), f32 accumulation
        s = jnp.einsum("bqhd,bchd->bqhc", qs, kj,
                       preferred_element_type=jnp.float32)
        if kv_positions is not None:
            kpos = pj[0]
        else:
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((Sq, kv_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= kpos[None, :]
        static_win = isinstance(window, (int, np.integer))
        if static_win and window > 0:
            mask &= (q_pos[:, None] - kpos[None, :]) < window
        elif not static_win:  # traced per-layer window; 0 means global
            dist_ok = (q_pos[:, None] - kpos[None, :]) < window
            mask &= jnp.where(window > 0, dist_ok, True)
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhc,bchd->bqhd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, pc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Ring (sliding-window) KV caches
# ---------------------------------------------------------------------------

RING_EMPTY_POS = np.int32(2 ** 30)  # huge position -> causally masked


def ring_update(cache_k, cache_v, pos_buf, k_new, v_new, start):
    """Write new tokens into a (B, W, Hkv, D) ring cache.

    Slot p%W holds position p; ``pos_buf`` (W,) tracks which absolute
    position each slot currently holds (RING_EMPTY_POS when empty). Only
    the last W of the incoming tokens are kept — earlier ones can never
    be attended again under a window of W.
    """
    B, Sq = k_new.shape[:2]
    W = cache_k.shape[1]
    if Sq >= W:
        k_new, v_new = k_new[:, -W:], v_new[:, -W:]
        newpos = start + Sq - W + jnp.arange(W)
    else:
        newpos = start + jnp.arange(Sq)
    slots = newpos % W
    ck = cache_k.at[:, slots].set(k_new.astype(cache_k.dtype))
    cv = cache_v.at[:, slots].set(v_new.astype(cache_v.dtype))
    pb = pos_buf.at[slots].set(newpos.astype(pos_buf.dtype))
    return ck, cv, pb


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def gqa_init(key, d: int, n_heads: int, n_kv: int, head_dim: int,
             *, dtype=DEFAULT_DTYPE) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "w_q": init_linear(ks[0], d, n_heads * head_dim, dtype=dtype),
        "w_k": init_linear(ks[1], d, n_kv * head_dim, dtype=dtype),
        "w_v": init_linear(ks[2], d, n_kv * head_dim, dtype=dtype),
        "w_o": init_linear(ks[3], n_heads * head_dim, d, dtype=dtype),
    }


def gqa_apply(
    p: dict, x: jnp.ndarray, *,
    n_heads: int, n_kv: int, head_dim: int,
    positions: jnp.ndarray,          # (B, Sq) absolute positions
    rope_theta: float = 1e4,
    causal: bool = True,
    window: int = 0,
    cache: dict | None = None,       # {"k": (B,Smax,Hkv,D), "v": ..., "len": int}
    kv_seq: jnp.ndarray | None = None,  # cross-attention source (B,Skv,d)
    kv_chunk: int = 1024,
    ring: bool = False,              # cache is a (B,W,...) ring + "pos" buffer
) -> tuple[jnp.ndarray, dict | None]:
    B, Sq, d = x.shape
    q = (x @ p["w_q"]).reshape(B, Sq, n_heads, head_dim)
    src = x if kv_seq is None else kv_seq
    k = (src @ p["w_k"]).reshape(B, src.shape[1], n_kv, head_dim)
    v = (src @ p["w_v"]).reshape(B, src.shape[1], n_kv, head_dim)

    if kv_seq is None:  # self-attention: rotary on q and new k
        q = rope(q, positions, theta=rope_theta)
        k = rope(k, positions, theta=rope_theta)

    new_cache = None
    kv_positions = None
    if cache is not None and ring:
        start = cache["len"]
        ck, cv, pb = ring_update(cache["k"], cache["v"], cache["pos"],
                                 k, v, start)
        new_cache = {"k": ck, "v": cv, "pos": pb}
        if Sq == 1:  # decode: attend the ring with tracked positions
            k, v, kv_positions = ck, cv, pb
        # prefill (Sq>1) from an empty ring: attend the in-flight k/v —
        # the windowed causal mask makes this exact (see ring_update doc)
    elif cache is not None:
        # linear cache: append the Sq new entries at cache["len"]
        start = cache["len"]
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0)
        )
        k, v = ck, cv
        new_cache = {"k": ck, "v": cv, "len": start + Sq}

    out = chunked_attention(
        q, k, v,
        causal=causal and kv_seq is None,
        q_offset=(positions[0, 0] if cache is not None else 0),
        window=window,
        kv_chunk=kv_chunk,
        kv_positions=kv_positions,
    )
    return out.reshape(B, Sq, n_heads * head_dim) @ p["w_o"], new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(key, d: int, n_heads: int, mla, *, dtype=DEFAULT_DTYPE) -> dict:
    ks = jax.random.split(key, 8)
    dq = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    p = {
        # kv compression + decompression
        "w_dkv": init_linear(ks[0], d, mla.kv_lora_rank + mla.qk_rope_head_dim,
                             dtype=dtype),
        "w_uk": init_linear(ks[1], mla.kv_lora_rank,
                            n_heads * mla.qk_nope_head_dim, dtype=dtype),
        "w_uv": init_linear(ks[2], mla.kv_lora_rank,
                            n_heads * mla.v_head_dim, dtype=dtype),
        "w_o": init_linear(ks[3], n_heads * mla.v_head_dim, d, dtype=dtype),
    }
    if mla.q_lora_rank:
        p["w_dq"] = init_linear(ks[4], d, mla.q_lora_rank, dtype=dtype)
        p["w_uq"] = init_linear(ks[5], mla.q_lora_rank, n_heads * dq, dtype=dtype)
    else:
        p["w_q"] = init_linear(ks[6], d, n_heads * dq, dtype=dtype)
    return p


def mla_apply(
    p: dict, x: jnp.ndarray, *, n_heads: int, mla,
    positions: jnp.ndarray, rope_theta: float = 1e4,
    cache: dict | None = None,       # {"ckv": (B,Smax,c), "krope": (B,Smax,r), "len"}
    kv_chunk: int = 1024,
    absorbed_decode: bool = True,
) -> tuple[jnp.ndarray, dict | None]:
    """MLA attention. Cache stores compressed c_kv + shared rope key only."""
    B, Sq, d = x.shape
    H = n_heads
    dn, dr, dv, c = (mla.qk_nope_head_dim, mla.qk_rope_head_dim,
                     mla.v_head_dim, mla.kv_lora_rank)

    # --- queries
    if mla.q_lora_rank:
        q = (x @ p["w_dq"]) @ p["w_uq"]
    else:
        q = x @ p["w_q"]
    q = q.reshape(B, Sq, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, theta=rope_theta)

    # --- compressed kv for the new tokens
    dkv = x @ p["w_dkv"]                          # (B,Sq,c+dr)
    ckv_new, krope_new = dkv[..., :c], dkv[..., c:]
    krope_new = rope(krope_new[..., None, :], positions,
                     theta=rope_theta)[..., 0, :]

    if cache is not None:
        start = cache["len"]
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, start, 0))
        krope = jax.lax.dynamic_update_slice(
            cache["krope"], krope_new.astype(cache["krope"].dtype),
            (0, start, 0))
        new_cache = {"ckv": ckv, "krope": krope, "len": start + Sq}
        if absorbed_decode:
            out = _mla_absorbed(p, q_nope, q_rope, ckv, krope, H=H, mla=mla,
                                q_offset=start, kv_chunk=kv_chunk)
            return out.reshape(B, Sq, H * dv) @ p["w_o"], new_cache
        ckv_all, krope_all, q_off = ckv, krope, start
    else:
        new_cache = None
        ckv_all, krope_all, q_off = ckv_new, krope_new, 0

    # --- decompressed (train / prefill) path
    Sk = ckv_all.shape[1]
    k_nope = (ckv_all @ p["w_uk"]).reshape(B, Sk, H, dn)
    vfull = (ckv_all @ p["w_uv"]).reshape(B, Sk, H, dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_all[:, :, None, :], (B, Sk, H, dr))],
        axis=-1,
    )
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(
        qq, k, vfull, causal=True, q_offset=q_off, kv_chunk=kv_chunk,
        scale=1.0 / np.sqrt(dn + dr),
    )
    return out.reshape(B, Sq, H * dv) @ p["w_o"], new_cache


def _mla_absorbed(p, q_nope, q_rope, ckv, krope, *, H, mla, q_offset, kv_chunk):
    """Absorbed decode: score against the compressed cache directly.

    q_c = q_nope @ W_uk (per head) -> (B,Sq,H,c); scores = q_c . ckv +
    q_rope . krope (two einsums — never concatenated, so a c-sharded cache
    stays sharded); out_c = attn @ ckv -> decompress via W_uv once.
    """
    B, Sq, _, dn = q_nope.shape
    c, dr, dv = mla.kv_lora_rank, mla.qk_rope_head_dim, mla.v_head_dim
    Sk = ckv.shape[1]
    scale = np.float32(1.0 / np.sqrt(dn + dr))
    w_uk = p["w_uk"].reshape(c, H, dn)
    q_c = jnp.einsum("bqhd,chd->bqhc", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32)) * scale
    q_r = q_rope.astype(jnp.float32) * scale

    kv_chunk = min(kv_chunk, Sk)
    while Sk % kv_chunk:
        kv_chunk -= 1
    n_chunks = Sk // kv_chunk
    cc = jnp.moveaxis(ckv.reshape(B, n_chunks, kv_chunk, c), 1, 0)
    rc = jnp.moveaxis(krope.reshape(B, n_chunks, kv_chunk, dr), 1, 0)
    q_pos = jnp.arange(Sq) + q_offset

    q_cb = q_c.astype(ckv.dtype)
    q_rb = q_r.astype(krope.dtype)

    def body(carry, inp):
        m, l, acc = carry
        cj, rj, j = inp                          # (B,C,c), (B,C,dr)
        s = (jnp.einsum("bqhc,bkc->bqhk", q_cb, cj,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bqhr,bkr->bqhk", q_rb, rj,
                          preferred_element_type=jnp.float32))
        kpos = j * kv_chunk + jnp.arange(kv_chunk)
        mask = q_pos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        pch = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pch.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhk,bkc->bqhc", pch.astype(cj.dtype), cj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, c), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (cc, rc, jnp.arange(n_chunks)))
    out_c = acc / jnp.maximum(l, 1e-30)[..., None]
    w_uv = p["w_uv"].reshape(c, H, dv)
    return jnp.einsum("bqhc,chd->bqhd", out_c,
                      w_uv.astype(jnp.float32)).astype(ckv.dtype)
