from .checkpoint import Checkpointer, latest_step, restore, save
