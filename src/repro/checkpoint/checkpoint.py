"""Sharded checkpointing with elastic restore.

Layout on disk::

    <dir>/step_000123/
        manifest.json       tree structure, shapes, dtypes, step, metadata
        <leaf-id>.npy       one file per pytree leaf

Properties required at fleet scale, all implemented here:

* **atomic commit** — written to ``step_X.tmp`` then renamed, so a killed
  writer never leaves a half checkpoint that restore would pick up;
* **async save** — a background thread serializes device arrays after
  they are snapshotted to host, so the train loop stalls only for the
  device->host copy;
* **elastic restore** — ``restore`` takes target shardings; arrays are
  ``device_put`` against the *new* mesh, so a job restarted on a
  different topology (e.g. 512 -> 256 chips after a pod loss) resumes
  with re-laid-out state — the resharding path the fault-tolerance
  runtime exercises;
* integrity: manifest carries per-leaf shape/dtype; mismatches fail
  loudly before any state is touched.
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "Checkpointer"]

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_id(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return _SAFE.sub("_", ".".join(parts)) or "leaf"


def save(directory: str | pathlib.Path, step: int, tree: Any,
         metadata: dict | None = None) -> pathlib.Path:
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "metadata": metadata or {}, "leaves": []}
    seen: dict[str, int] = {}
    for path, leaf in leaves:
        lid = _leaf_id(path)
        if lid in seen:
            seen[lid] += 1
            lid = f"{lid}.{seen[lid]}"
        else:
            seen[lid] = 0
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype in ("bfloat16", "float8_e4m3fn",
                                                      "float8_e5m2"):
            # numpy can't serialize ml_dtypes natively: store raw bits
            stored = arr.view(np.uint16 if arr.dtype.itemsize == 2
                              else np.uint8)
        else:
            stored = arr
        np.save(tmp / f"{lid}.npy", stored)
        manifest["leaves"].append(
            {"id": lid, "shape": list(arr.shape), "dtype": logical_dtype}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(directory: str | pathlib.Path) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.iterdir()
        if p.is_dir() and p.name.startswith("step_")
        and not p.name.endswith(".tmp") and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(directory: str | pathlib.Path, step: int, target: Any,
            shardings: Any | None = None) -> Any:
    """Load ``step`` into the structure of ``target`` (a shape tree or
    example tree). ``shardings``, if given, must mirror ``target``; each
    loaded array is placed with its (possibly new-mesh) sharding."""
    src = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((src / "manifest.json").read_text())
    entries = manifest["leaves"]
    tpaths = jax.tree_util.tree_flatten_with_path(target)[0]
    if len(entries) != len(tpaths):
        raise ValueError(
            f"checkpoint has {len(entries)} leaves, target {len(tpaths)}"
        )
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: hasattr(s, "mesh"))
        if shardings is not None else [None] * len(entries))
    out = []
    for (path, tleaf), entry, shard in zip(tpaths, entries, shard_leaves):
        arr = np.load(src / f"{entry['id']}.npy")
        if str(arr.dtype) != entry["dtype"]:
            import ml_dtypes  # ships with jax

            arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"])))
        want_shape = tuple(getattr(tleaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {entry['id']}: checkpoint {arr.shape} vs target "
                f"{want_shape}"
            )
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    tdef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(tdef, out)


class Checkpointer:
    """Async double-buffered checkpointer with retention."""

    def __init__(self, directory: str | pathlib.Path, *, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, step: int, tree: Any,
                   metadata: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, metadata)
                self._gc()
            except Exception as e:  # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            p for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_")
            and not p.name.endswith(".tmp")
        )
        for p in steps[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)
