from .pipeline import Loader, MemmapSource, SyntheticSource, make_batch_fn
