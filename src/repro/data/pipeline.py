"""Data pipeline: deterministic sharded token streams with prefetch.

Two sources behind one iterator interface:

* ``SyntheticSource`` — deterministic per (step, shard) pseudo-random
  tokens; reproducible across restarts (the stream is a pure function of
  the step index, so checkpoint-resume replays identically — a
  fault-tolerance requirement, not a convenience).
* ``MemmapSource`` — a flat token file (np.memmap) chunked into
  (batch, seq) windows, shard-strided so each data shard reads a disjoint
  stream.

``Loader`` shards each batch over the mesh (device_put against the batch
sharding) and prefetches one batch ahead on a worker thread — the
host-side analogue of the paper's "overlap the next working set's
initialization with the current measurement".
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import jax
import numpy as np

__all__ = ["SyntheticSource", "MemmapSource", "Loader", "make_batch_fn"]


@dataclasses.dataclass
class SyntheticSource:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0

    def get(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(
            0, self.vocab_size, (self.batch, self.seq_len + 1), dtype=np.int32
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class MemmapSource:
    path: str
    vocab_size: int
    batch: int
    seq_len: int

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self._windows = (len(self._data) - 1) // self.seq_len

    def get(self, step: int) -> dict[str, np.ndarray]:
        idx = (step * self.batch + np.arange(self.batch)) % self._windows
        starts = idx * self.seq_len
        toks = np.stack(
            [self._data[s:s + self.seq_len + 1] for s in starts]
        ).astype(np.int32)
        toks %= self.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Loader:
    """Prefetching, shard-placing iterator over a source."""

    def __init__(self, source, batch_shardings: Any | None = None,
                 start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.shardings = batch_shardings
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch: dict[str, np.ndarray]):
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {
            k: jax.device_put(v, self.shardings[k]) for k, v in batch.items()
        }

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            try:
                batch = self.source.get(step)
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, self._place(batch)

    def close(self):
        self._stop.set()


def make_batch_fn(cfg, shape, seed: int = 0):
    """Batch factory covering the frontend-stub archs too (smoke/examples)."""
    def get(step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((seed, step))
        B, S = shape.global_batch, shape.seq_len
        batch: dict[str, np.ndarray] = {}
        labels = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
        if cfg.frontend == "audio":
            batch["frame_embeds"] = rng.standard_normal(
                (B, S, cfg.d_model), dtype=np.float32)
            batch["cond"] = rng.standard_normal(
                (B, 64, cfg.d_model), dtype=np.float32)
        elif cfg.frontend == "vision":
            vt = cfg.vision_tokens
            batch["tokens"] = rng.integers(
                0, cfg.vocab_size, (B, S - vt), dtype=np.int32)
            batch["vision_embeds"] = rng.standard_normal(
                (B, vt, cfg.d_model), dtype=np.float32)
            labels[:, :vt] = -1
        else:
            batch["tokens"] = rng.integers(
                0, cfg.vocab_size, (B, S), dtype=np.int32)
        batch["labels"] = labels
        return batch

    return get
