"""Fault-tolerant training runtime: retries, watchdog, elastic resize.

Single-controller reproduction of the fleet behaviors; the policies are
real, the failure *sources* are injectable so tests exercise them
deterministically:

* **step retry with backoff** — transient executor failures re-run the
  step from the last good state (params are only committed after a step
  completes, so a mid-step failure is side-effect-free — functional
  updates are what make this sound);
* **watchdog / straggler mitigation** — a step exceeding
  ``straggler_factor`` x the trailing-median step time is recorded and,
  past ``max_slow_steps``, triggers the elastic path (on a real fleet:
  re-slice without the slow host; here: resize event);
* **elastic resize** — on a (simulated) device loss the loop rebuilds a
  smaller mesh, re-shards the last checkpoint onto it (see
  checkpoint.restore) and continues; batch is re-sharded by the new
  data-axis size;
* **checkpoint cadence** — async saves every ``ckpt_every`` steps +
  always before a resize.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax

from repro.checkpoint.checkpoint import Checkpointer, latest_step, restore

__all__ = ["FTConfig", "FaultTolerantLoop", "TransientError"]


class TransientError(RuntimeError):
    """Raised by injected failure hooks; real-world analogue: a failed
    collective / preempted worker surfacing as an executor error."""


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_retries: int = 3
    retry_backoff_s: float = 0.05
    straggler_factor: float = 3.0
    max_slow_steps: int = 5
    keep: int = 3


class FaultTolerantLoop:
    """Wraps ``step_fn(state, batch) -> (state, metrics)``.

    ``failure_hook(step) -> None | "transient" | "resize"`` lets tests
    inject faults. ``resize_hook(state) -> state`` performs the elastic
    re-shard (built by the caller who owns mesh construction).
    """

    def __init__(self, step_fn: Callable, state: Any, cfg: FTConfig, *,
                 failure_hook: Callable[[int], str | None] | None = None,
                 resize_hook: Callable[[Any], Any] | None = None,
                 state_shape: Any | None = None):
        self.step_fn = step_fn
        self.state = state
        self.cfg = cfg
        self.failure_hook = failure_hook or (lambda _: None)
        self.resize_hook = resize_hook
        self.ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.step_times: list[float] = []
        self.events: list[tuple[int, str]] = []
        self._state_shape = state_shape

    # -- recovery ------------------------------------------------------------

    def try_resume(self, shardings: Any | None = None) -> int:
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            return 0
        self.state = restore(self.cfg.ckpt_dir, last,
                             self._state_shape or self.state, shardings)
        self.events.append((last, "resumed"))
        return last

    # -- main loop -----------------------------------------------------------

    def run(self, batches, n_steps: int, start_step: int = 0) -> dict:
        metrics_hist = []
        slow = 0
        step = start_step
        it = iter(batches)
        while step < n_steps:
            _, batch = next(it)
            fault = self.failure_hook(step)
            if fault == "resize" and self.resize_hook is not None:
                self.ckpt.wait()
                self.ckpt.save_async(step, self.state, {"reason": "resize"})
                self.ckpt.wait()
                self.state = self.resize_hook(self.state)
                self.events.append((step, "resized"))

            t0 = time.perf_counter()
            for attempt in range(self.cfg.max_retries + 1):
                try:
                    if fault == "transient" and attempt == 0:
                        raise TransientError(f"injected at step {step}")
                    new_state, metrics = self.step_fn(self.state, batch)
                    jax.block_until_ready(
                        jax.tree.leaves(metrics)[0]
                        if jax.tree.leaves(metrics) else new_state
                    )
                    break
                except (TransientError, jax.errors.JaxRuntimeError) as e:
                    self.events.append((step, f"retry{attempt}:{type(e).__name__}"))
                    if attempt == self.cfg.max_retries:
                        raise
                    time.sleep(self.cfg.retry_backoff_s * (2 ** attempt))
            dt = time.perf_counter() - t0

            # straggler watchdog
            if len(self.step_times) >= 5:
                med = statistics.median(self.step_times[-20:])
                if dt > self.cfg.straggler_factor * med:
                    slow += 1
                    self.events.append((step, f"straggler({dt:.3f}s)"))
                    if slow >= self.cfg.max_slow_steps and self.resize_hook:
                        self.state = self.resize_hook(self.state)
                        self.events.append((step, "resized:stragglers"))
                        slow = 0
            self.step_times.append(dt)

            self.state = new_state
            metrics_hist.append(metrics)
            step += 1
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(step, self.state)
        self.ckpt.wait()
        return {"metrics": metrics_hist, "events": self.events,
                "final_step": step}
