"""Parameter / cache / batch partition rules.

Name-based rules map every leaf of the model pytrees to a PartitionSpec,
Megatron/MaxText-style:

* column-parallel projections (w_q, w_k, w_v, w_gate, w_in, w_u*, ...) —
  ``P(fsdp, tp)``: input dim sharded by the ZeRO-3/FSDP axes (GSPMD
  all-gathers per layer inside the scan), output dim tensor-parallel;
* row-parallel projections (w_o, w_out) — ``P(tp, fsdp)`` (psum on exit);
* expert tensors (E, d, f) — expert dim over the EP axes, d over FSDP;
* embeddings — vocab-parallel ``P(tp, None)``;
* everything small (norms, gates, routers, SSM scalars) — replicated.

Leading layer/group stack axes are auto-padded with ``None``. Any axis
whose size does not divide the corresponding dim is *dropped* (replicated)
— this is what lets one rule table serve 10 architectures with head
counts from 4 to 96: e.g. xLSTM's (d, 2*H=8) gate projection silently
degrades to replicated on a 16-way TP axis instead of erroring.

FSDP policy is size-based (``auto_parallelism``): params ≤ TP budget stay
DP-replicated; mid archs shard over ``data``; the 1T config additionally
shards over ``pod`` (documented DCN cost; the alternative is not fitting).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ArchConfig, Shape
from repro.models.moe import Parallelism

__all__ = [
    "auto_parallelism",
    "param_specs",
    "cache_specs",
    "batch_specs",
    "shardings",
    "param_count",
]


# rule table: (leaf-name match) -> spec template over the *trailing* dims.
# tokens: "tp" -> par.tp_axis, "fsdp" -> par.fsdp_axes, "ep" -> par.ep_axes.
_COL = ("w_q", "w_k", "w_v", "w_gate", "w_in", "w_uq", "w_uk", "w_uv",
        "w_og", "w_if", "w_dq")
# NOTE: sLSTM's w_x is deliberately absent (replicated): it feeds a
# 4096-step time scan, and an FSDP-sharded w_x makes XLA re-gather it
# inside the scan — 4096 gathers/layer (measured: ~840 GB/step on xlstm).
_ROW = ("w_o", "w_out")
_RULES: list[tuple[tuple[str, ...], tuple[Any, ...]]] = [
    (("emb", "unemb"), ("vocab", None)),
    (("w_gate_e", "w_in_e"), ("ep", "fsdp", None)),
    (("w_out_e",), ("ep", None, "fsdp")),
    (_ROW, ("tp", "fsdp")),
    # w_dkv's output packs [c_kv | k_rope]: TP-slicing it would split the
    # concat boundary and force gathers at every use; it is tiny — replicate
    # the out dim and shard only the input dim.
    (("w_dkv",), ("fsdp", None)),
    (_COL, ("fsdp", "tp")),
    (("conv_w",), (None, "tp")),
    (("r_h",), (None, None, None)),
]


def param_count(cfg: ArchConfig) -> int:
    """Total parameter count (from shapes, no allocation)."""
    import repro.models.lm as lm

    shapes = jax.eval_shape(
        lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))


def auto_parallelism(cfg: ArchConfig, mesh: Mesh, shape: Shape | None = None
                     ) -> Parallelism:
    """Pick TP/FSDP/EP axes from model size, mesh topology, and step kind.

    Train policy (roofline-driven, see EXPERIMENTS.md §Perf P1): tensor
    parallelism costs ~per-layer activation psums of tokens_dev x d bytes
    — for models whose optimizer state fits under ZeRO, that traffic
    dwarfs the gradient reduction it replaces. So:

      params <= ~60B  ->  TP OFF: the model axis joins data parallelism;
                          state is ZeRO-sharded over data (and over model
                          too when data alone is not enough);
      params  >  60B  ->  TP=16 + ZeRO over data (+ EP/pod for the 1T MoE).

    Serve keeps TP=16: decode latency wants the model axis on weights,
    and per-token activations are tiny so TP psums are cheap.
    """
    multi_pod = "pod" in mesh.axis_names
    n_params = param_count(cfg)
    n_bytes = 2 * n_params  # bf16
    kind = shape.kind if shape is not None else "train"
    d_ax, m_ax = mesh.shape["data"], mesh.shape["model"]

    total_dev = d_ax * m_ax * (2 if multi_pod else 1)
    # measured exceptions (EXPERIMENTS.md §Perf I9/I11): these two blow the
    # HBM budget under TP-off (starcoder2: f32 boundary copies XLA hoists
    # into the while state at d_ff=24576; zamba2: SSD intra-chunk Q^2xH f32
    # buffers with all heads local) — they keep TP=16, which fits.
    _TP_OFF_DENY = ("starcoder2-15b", "zamba2-1.2b")
    if (kind == "train" and n_params <= 60e9
            and cfg.name not in _TP_OFF_DENY
            and shape is not None and shape.global_batch % total_dev == 0):
        pass  # TP-off candidate; may still fall through to _tp_parallelism
    else:
        return _tp_parallelism(cfg, mesh, shape)
    if True:
        # bf16 moments policy: p + m + v + grad ~ 8 bytes/param
        state = 8.0 * n_params
        tokens_dev = (shape.tokens / (d_ax * m_ax * (2 if multi_pod else 1))
                      if shape else 0)
        act = tokens_dev * cfg.d_model * 2 * cfg.n_layers
        if state / d_ax + act <= 11e9:
            fsdp: tuple[str, ...] = ("data",)
        elif not cfg.moe and state / (d_ax * m_ax) + 5 * act <= 12e9:
            # ZeRO-3 over both axes (measured headroom factor on act: the
            # while-state f32 boundary copies XLA hoists cost ~2-3x)
            fsdp = ("data", "model")
        else:
            return _tp_parallelism(cfg, mesh, shape)
        ep = ("model",) if cfg.moe else ()
        return Parallelism(
            mesh=mesh,
            dp_axes=("data", "model"),
            tp_axis=None,
            ep_axes=ep,
            fsdp_axes=fsdp,
            pod_axis="pod" if multi_pod else None,
            head_dim=cfg.head_dim,
        )

    raise AssertionError("unreachable")


def _tp_parallelism(cfg: ArchConfig, mesh: Mesh, shape: Shape | None
                    ) -> Parallelism:
    multi_pod = "pod" in mesh.axis_names
    n_params = param_count(cfg)
    n_bytes = 2 * n_params
    kind = shape.kind if shape is not None else "train"
    d_ax, m_ax = mesh.shape["data"], mesh.shape["model"]
    tp = m_ax
    state_mult = 3 if kind == "train" else 1
    per_dev_tp_only = n_bytes * state_mult / tp
    fsdp = ()
    if per_dev_tp_only > 4e9:               # >4GB/device with TP alone
        fsdp = ("data",)
        if multi_pod and per_dev_tp_only / d_ax > 8e9:
            fsdp = ("data", "pod")          # the 1T config
    ep: tuple[str, ...] = ("model",) if cfg.moe else ("model",)
    if cfg.moe and multi_pod and cfg.moe.n_routed % (tp * 2) == 0 and (
        n_bytes / (tp * d_ax) > 4e9
    ):
        ep = ("model", "pod")
    # an axis can appear in at most one factor of a spec: EP wins over FSDP
    fsdp = tuple(a for a in fsdp if a not in ep)
    # big-model decode: replicate the tiny per-token activations over the
    # FSDP axes so weights stay resident (partial products + psum) instead
    # of being all-gathered layer by layer (see EXPERIMENTS.md §Perf I13)
    # measured (EXPERIMENTS.md §Perf I13): replicating decode activations
    # did NOT beat GSPMD's own choice (mistral coll 126->183 GB) — refuted;
    # keep activations batch-sharded.
    act_override = None
    return Parallelism(
        mesh=mesh,
        dp_axes=("data",),
        tp_axis="model",
        ep_axes=ep,
        fsdp_axes=fsdp,
        pod_axis="pod" if multi_pod else None,
        head_dim=cfg.head_dim,
        act_batch_axes=act_override,
    )


# ---------------------------------------------------------------------------
# spec builders
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _resolve(template, par: Parallelism):
    out = []
    for t in template:
        if t == "tp":
            out.append(par.tp_axis)
        elif t == "vocab":
            out.append(par.vocab_axis)
        elif t == "fsdp":
            out.append(par.fsdp_axes if par.fsdp_axes else None)
        elif t == "ep":
            out.append(par.ep_axes)
        else:
            out.append(t)
    return out


def _fit(spec_tail, shape, mesh: Mesh):
    """Pad leading dims with None; drop axes that don't divide."""
    spec = [None] * (len(shape) - len(spec_tail)) + list(spec_tail)
    fitted = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            fitted.append(None)
        elif dim % _axis_size(mesh, ax) == 0:
            fitted.append(ax)
        else:
            fitted.append(None)  # graceful degradation -> replicate
    return P(*fitted)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "idx"):
            continue
    return ""


def param_specs(params_shape: Any, par: Parallelism) -> Any:
    """Spec tree matching a params (shape-)tree."""
    mesh = par.mesh

    def template_for(name: str):
        for names, template in _RULES:
            if name in names:
                return template
        return None

    def one(path, leaf):
        name = _leaf_name(path)
        if name in ("row", "col"):
            # factored second moment: derive from the parent param's rule
            parents = [str(e.key) for e in path if hasattr(e, "key")]
            parent = parents[-2] if len(parents) >= 2 else ""
            template = template_for(parent)
            if template is None:
                return P()
            template = (template[:-1] if name == "row"
                        else template[:-2] + template[-1:])
            return _fit(_resolve(template, par), leaf.shape, mesh)
        template = template_for(name)
        if template is not None:
            spec = _fit(_resolve(template, par), leaf.shape, mesh)
            if name in ("w_k", "w_v") and par.head_dim:
                # head-aware: a TP shard must hold whole KV heads, else
                # every attention chunk re-gathers half-heads over TP
                tp_size = _axis_size(mesh, par.tp_axis)
                if (leaf.shape[-1] // tp_size) % par.head_dim != 0:
                    spec = P(*spec[:-1], None)
            return spec
        return P()  # replicate

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_axes_for(par: Parallelism, batch: int) -> tuple[str, ...]:
    """Largest prefix of batch axes that divides ``batch``."""
    axes: tuple[str, ...] = ()
    size = 1
    for a in par.batch_axes:
        if batch % (size * par.mesh.shape[a]) == 0:
            axes = axes + (a,)
            size *= par.mesh.shape[a]
    return axes


def batch_specs(batch_shape: Any, par: Parallelism) -> Any:
    """Inputs: shard dim0 (batch) over the batch axes that divide."""
    mesh = par.mesh

    def one(leaf):
        ba = batch_axes_for(par, leaf.shape[0])
        spec = [ba if ba else None] + [None] * (len(leaf.shape) - 1)
        return P(*spec)

    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape: Any, par: Parallelism, cfg: ArchConfig,
                batch: int) -> Any:
    """Decode caches: batch over dp axes; heads (or the compressed dim)
    over TP when divisible; for unshardable batch (long-context B=1) the
    sequence axis takes the dp axes instead (context parallelism)."""
    mesh = par.mesh
    ba = batch_axes_for(par, batch)

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if leaf.ndim == 0:
            return P()
        spec: list = [None] * leaf.ndim
        if name in ("k", "v", "attn_k", "attn_v",
                    "local_k", "local_v", "tail_k", "tail_v"):
            # (L?, B, S, Hkv, hd)
            b_ax = leaf.ndim - 4
            spec[b_ax] = ba if ba else None
            tp_size = _axis_size(mesh, par.tp_axis)
            if shape[b_ax + 2] % tp_size == 0:
                spec[b_ax + 2] = par.tp_axis       # head-parallel
            elif shape[b_ax + 3] % tp_size == 0:
                spec[b_ax + 3] = par.tp_axis       # head-DIM parallel (kv<tp)
            if not ba and shape[b_ax + 1] % _axis_size(mesh, ("data",)) == 0:
                spec[b_ax + 1] = "data"   # context parallel over S
            return P(*spec)
        if name in ("ckv", "krope"):
            # (L, B, S, c)
            spec[1] = ba if ba else None
            if not ba and shape[2] % mesh.shape["data"] == 0:
                spec[2] = "data"
            if name == "ckv" and shape[3] % _axis_size(mesh, par.tp_axis) == 0:
                spec[3] = par.tp_axis
            return P(*spec)
        if name == "state":
            # (..., B, H, N, D) — shard H over tp if divisible, B over dp
            b_ax = leaf.ndim - 4
            spec[b_ax] = ba if ba else None
            if shape[b_ax + 1] % _axis_size(mesh, par.tp_axis) == 0:
                spec[b_ax + 1] = par.tp_axis
            return P(*spec)
        if name == "conv":
            # (..., B, W-1, C)
            b_ax = leaf.ndim - 3
            spec[b_ax] = ba if ba else None
            if shape[b_ax + 2] % _axis_size(mesh, par.tp_axis) == 0:
                spec[b_ax + 2] = par.tp_axis
            return P(*spec)
        if name == "mlstm":
            # (G, n_m, B, H, Dh, Dh+1)
            spec[2] = ba if ba else None
            if shape[4] % _axis_size(mesh, par.tp_axis) == 0:
                spec[4] = par.tp_axis
            return P(*spec)
        if name == "slstm" or name == "hcn":
            # tuple leaves (G, B, d)
            spec[-2] = ba if ba else None
            return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
