"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-scale quantization applied to the gradients at the DP
reduction boundary, with an error-feedback accumulator so the quantization
bias does not accumulate across steps (Seide et al. / EF-SGD). On a real
pod the compressed tensor is what crosses the ICI/DCN links (4x fewer
bytes on the all-reduce); in this single-controller reproduction the
transform wraps the optimizer so semantics and tests are identical.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .adamw import Optimizer

__all__ = ["quantize_int8", "dequantize_int8", "error_feedback"]


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def error_feedback(opt: Optimizer, *, enabled: bool = True) -> Optimizer:
    """Wrap an optimizer: grads are int8-quantized with error feedback."""
    if not enabled:
        return opt

    def init(params):
        return {
            "inner": opt.init(params),
            "err": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ),
        }

    def update(grads, state, params):
        def comp(g, e):
            corrected = g.astype(jnp.float32) + e
            q, s = quantize_int8(corrected)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), corrected - deq

        out = jax.tree.map(comp, grads, state["err"])
        cg = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        ne = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        newp, inner = opt.update(cg, state["inner"], params)
        return newp, {"inner": inner, "err": ne}

    return Optimizer(init, update)
