"""Optimizers: AdamW and a factored-second-moment variant (for the 1T run).

Self-contained optax-style (init/update) transforms — no external deps.
Moments are dtype-configurable: bf16 moments halve optimizer HBM, which
together with the factored variant is what lets kimi-k2 train_4k fit the
16 GiB v5e budget (see EXPERIMENTS.md §Dry-run memory table).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Optimizer", "adamw", "adafactor", "cosine_schedule", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5
                         * (1 + jnp.cos(np.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw(lr: float | Callable = 3e-4, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          moment_dtype=jnp.float32, grad_clip: float = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr_t = lr_fn(step)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            u = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            return newp, mf.astype(moment_dtype), vf.astype(moment_dtype)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        newp = jax.tree.map(lambda t3: t3[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree.map(lambda t3: t3[1], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        newv = jax.tree.map(lambda t3: t3[2], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"m": newm, "v": newv, "step": step}

    return Optimizer(init, update)


def adafactor(lr: float | Callable = 1e-3, *, b1: float = 0.9,
              decay: float = 0.99, eps: float = 1e-30,
              weight_decay: float = 0.0, moment_dtype=jnp.bfloat16,
              grad_clip: float = 1.0) -> Optimizer:
    """First moment in ``moment_dtype``; second moment row/col factored for
    rank>=2 leaves (O(n+m) instead of O(n*m)), full for vectors."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def v_init(p):
        if p.ndim >= 2:
            return {
                "row": jnp.zeros(p.shape[:-1], jnp.float32),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"full": jnp.zeros(p.shape, jnp.float32)}

    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype),
                              params),
            "v": jax.tree.map(v_init, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr_t = lr_fn(step)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                row = decay * v["row"] + (1 - decay) * g2.mean(-1)
                col = decay * v["col"] + (1 - decay) * g2.mean(-2)
                denom = (row[..., None] * col[..., None, :]
                         / jnp.maximum(row.mean(-1)[..., None, None], eps))
                newv = {"row": row, "col": col}
            else:
                full = decay * v["full"] + (1 - decay) * g2
                denom = full
                newv = {"full": full}
            u = gf / jnp.sqrt(denom + eps)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * u
            upd_ = mf + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * upd_).astype(p.dtype)
            return newp, mf.astype(moment_dtype), newv

        flat, tdef = jax.tree_util.tree_flatten(params)
        gflat = tdef.flatten_up_to(grads)
        mflat = tdef.flatten_up_to(state["m"])
        vflat = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat, gflat, mflat, vflat)]
        newp = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        newm = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        newv = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
        return newp, {"m": newm, "v": newv, "step": step}

    return Optimizer(init, update)
