from .adamw import Optimizer, adafactor, adamw, clip_by_global_norm, cosine_schedule
from .compression import dequantize_int8, error_feedback, quantize_int8

__all__ = [
    "Optimizer", "adamw", "adafactor", "cosine_schedule", "clip_by_global_norm",
    "quantize_int8", "dequantize_int8", "error_feedback",
]
