"""Schedule-variant sweeps — the "testbed for potential optimizations".

The paper's workflow: express a kernel once, then fork schedule variants
(tile sizes, interleave factors, data-space layouts) and measure each.
``sweep`` automates that loop and returns the argmax; the launcher's perf
pass uses it to pick Pallas block shapes for the model kernels.

``sweep`` is a thin facade over the suite's plan engine
(:mod:`repro.suite.engine`): the working sets become a one-env-axis
:class:`~repro.suite.axes.SweepPlan` and every variant runs it through
the staged lower/compile pipeline sharing one translation cache — a
variant is validated once (not per working set), repeated (variant, n)
tuples hit the compiled-executable cache, and the result carries the
cache's hit/miss accounting so callers can see what the sweep actually
paid for.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from .drivers import DriverConfig
from .measure import Record
from .pattern import PatternSpec
from .staging import GLOBAL_CACHE, TranslationCache

__all__ = ["Variant", "SweepResult", "sweep"]


@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    config: DriverConfig


@dataclasses.dataclass
class SweepResult:
    records: list[tuple[str, Record]]            # (variant name, record)
    best: tuple[str, Record]
    cache_stats: dict | None = None              # translation-cache accounting

    def table(self) -> str:
        lines = ["variant,n,GB/s,us_per_call"]
        for name, r in self.records:
            lines.append(f"{name},{r.n},{r.gbs:.3f},{r.seconds*1e6:.2f}")
        return "\n".join(lines)


def sweep(
    pattern_factory: Callable[[Mapping[str, int]], PatternSpec],
    variants: Sequence[Variant],
    working_sets: Sequence[int],
    *, validate: bool = True,
    key: Callable[[Record], float] = lambda r: r.gbs,
    cache: TranslationCache | None = None,
) -> SweepResult:
    """Measure every variant over every working set; best = max ``key``.

    All variants share ``cache`` (default: the process-wide cache), and
    every (variant, working set) executable is staged up front so the
    XLA compiles overlap before any timing starts. Executes through the
    suite plan engine (imported lazily — ``repro.suite`` depends on
    ``repro.core``, not vice versa at import time).
    """
    from repro.suite.axes import SweepPlan, env_axis
    from repro.suite.engine import run_plan
    from repro.suite.workload import VariantSpec

    cache = cache if cache is not None else GLOBAL_CACHE
    plan = SweepPlan.product(env_axis(tuple(working_sets)))
    # strict: an autotune caller wants the argmax over ALL variants — a
    # silently missing candidate would bias the pick, so faults raise
    rows = run_plan(
        pattern_factory,
        [VariantSpec(v.name, v.config) for v in variants],
        plan, quick=True, cache=cache, validate=validate, parametric=None,
        on_error="raise",
    )
    records = [(row.variant, row.record) for row in rows]
    best = max(records, key=lambda nr: key(nr[1]))
    return SweepResult(records, best, cache_stats=cache.stats())
