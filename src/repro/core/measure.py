"""Measurement: timing, bandwidth accounting, and counter surrogates.

The paper's drivers wrap every kernel with (a) a repetition loop, (b)
timers, and (c) PAPI counters. On this target:

* timing — ``time_fn`` with ``block_until_ready`` fencing; medians over
  repeats. On the CPU container these are CPU numbers and records say so.
* achieved bandwidth — derived from the pattern's access list (bytes per
  iteration point x points x ntimes / seconds), the same accounting STREAM
  and the paper use (write-allocate traffic excluded, as in STREAM).
* counters — two surrogates for PAPI:
    - ``hlo_counters``: FLOPs / bytes-accessed from
      ``compiled.cost_analysis()`` (what the XLA:TPU compiler claims);
    - ``tile_traffic``: an analytic model of (8,128)-native-tile fetches
      and writebacks per program, the analogue of L1 line fills and
      requests-for-exclusive-access. It is exact for the affine patterns
      here, which is the point: the paper uses counters to *detect* false
      sharing; we can *prove* tile sharing from the schedule and report it
      in the same shape.
"""
from __future__ import annotations

import dataclasses
import json
import statistics
import time
from typing import Callable, Mapping, Sequence

import numpy as np

import jax

from .errors import BudgetExceeded

__all__ = [
    "TimingResult",
    "time_fn",
    "time_pair",
    "hlo_counters",
    "TileTraffic",
    "tile_traffic",
    "NATIVE_TILE",
    "Record",
    "latency_ns",
]

# TPU v5e native tile for f32 operands: 8 sublanes x 128 lanes.
NATIVE_TILE = (8, 128)
NATIVE_TILE_BYTES = NATIVE_TILE[0] * NATIVE_TILE[1] * 4


def _cv(times: Sequence[float]) -> float:
    """Sample coefficient of variation; 0 for fewer than two samples."""
    if len(times) < 2:
        return 0.0
    mean = sum(times) / len(times)
    if mean <= 0:
        return 0.0
    var = sum((t - mean) ** 2 for t in times) / (len(times) - 1)
    return (var ** 0.5) / mean


@dataclasses.dataclass
class TimingResult:
    seconds: float          # median per-call wall time
    reps: int
    all_seconds: tuple[float, ...]   # chronological, unsorted
    # staged pipeline: AOT compile time, reported separately from run
    # time so sweep records never fold translation cost into bandwidth
    compile_seconds: float | None = None
    target_cv: float | None = None   # adaptive mode's convergence target
    converged: bool = True           # CV <= target within the rep budget
    slow_reps: int = 0               # reps flagged by the straggler check

    @property
    def minimum(self) -> float:
        """Fastest rep — the Mess-style noise-floor estimator (system
        noise only ever inflates a rep, never deflates it)."""
        return min(self.all_seconds) if self.all_seconds else self.seconds

    @property
    def cv(self) -> float:
        return _cv(self.all_seconds)

    def quality(self) -> dict:
        """The ``extra["timing_quality"]`` payload every Record stamps."""
        return {
            "median_s": self.seconds,
            "min_s": self.minimum,
            "cv": round(self.cv, 6),
            "reps": self.reps,
            "target_cv": self.target_cv,
            "converged": self.converged,
            "slow_reps": self.slow_reps,
        }


def time_fn(fn: Callable, *args, reps: int = 5, warmup: int = 2,
            compile_seconds: float | None = None,
            target_cv: float | None = None, max_reps: int | None = None,
            budget_s: float | None = None,
            straggler_factor: float = 3.0) -> TimingResult:
    """Median wall time of ``fn(*args)`` with device fencing.

    ``fn`` may be a pre-compiled executable from the staged pipeline
    (``staging.Compiled`` or a jax AOT executable); pass ``warmup=1``
    then — the first call only absorbs dispatch warm-up, compilation
    already happened — and thread its measured ``compile_seconds``
    through so records can report translation cost separately.

    Donated executables must arrive *bound* (``Compiled.bind()`` /
    ``ParamCompiled.bind(env)`` — what ``Prepared.executable()``
    returns): the timing loop re-passes the same seed tuple every rep,
    and the bound wrapper threads each call's output buffers into the
    next call, so the consumed donation stream stays valid.

    Adaptive quality mode: with ``target_cv`` set, keep adding reps past
    ``reps`` until the sample CV drops to the target or the rep budget
    (``max_reps``, default ``max(4*reps, 8)``) is spent; the result
    reports whether it ``converged``. Guard rails in any mode: a rep
    slower than ``straggler_factor`` x the trailing median (last 20
    reps) is counted in ``slow_reps`` — the ``FaultTolerantLoop``
    straggler policy applied to measurement; and with ``budget_s`` set,
    exceeding the wall-clock budget raises :class:`BudgetExceeded`
    (checked between reps — a single in-flight XLA call cannot be
    preempted, so the budget granularity is one rep).
    """
    t_start = time.perf_counter()

    def _check_budget(done: int, trailing: float | None) -> None:
        if budget_s is None:
            return
        elapsed = time.perf_counter() - t_start
        if elapsed > budget_s:
            raise BudgetExceeded(
                f"measurement exceeded its {budget_s:.3f}s wall-clock budget "
                f"after {elapsed:.3f}s ({done} reps timed)",
                context={"budget_s": budget_s, "elapsed_s": elapsed,
                         "reps_done": done, "trailing_median_s": trailing})

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
        _check_budget(0, None)
    cap = reps if target_cv is None else max(
        reps, max_reps if max_reps is not None else max(4 * reps, 8))
    times: list[float] = []
    slow = 0
    converged = True
    while True:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        trailing = statistics.median(times[-20:]) if len(times) >= 3 else None
        if trailing is not None and straggler_factor \
                and dt > straggler_factor * trailing:
            slow += 1
        times.append(dt)
        _check_budget(len(times), trailing)
        if len(times) >= reps:
            if target_cv is None:
                break
            if _cv(times) <= target_cv:
                break
            if len(times) >= cap:
                converged = False
                break
    ordered = sorted(times)
    return TimingResult(ordered[len(ordered) // 2], len(times), tuple(times),
                        compile_seconds, target_cv, converged, slow)


def time_pair(fn_a: Callable, args_a: tuple, fn_b: Callable, args_b: tuple,
              *, reps: int = 7, passes: int = 1,
              warmup: int = 1) -> tuple[TimingResult, TimingResult]:
    """Matched-load interleaved A/B timing (the Mess discipline).

    Wall-clock on a shared machine is only comparable *under the same
    load*, so A and B are timed in strict alternation — every A rep has
    a B rep as its temporal neighbour, and a background-load spike hits
    both sides. Spikes can only inflate a rep, never deflate it, so
    consume the results via ``.minimum`` (min-of-reps) for ratio gates;
    ``.cv`` reports how noisy the session was. ``passes`` repeats the
    whole alternation block — callers wanting temporally *separated*
    passes (the PR-5 probe) call with ``passes=1`` from their own outer
    loop and fold the minima.

    Donated executables: same binding contract as :func:`time_fn`.
    """
    pairs = ((fn_a, args_a), (fn_b, args_b))
    for _ in range(warmup):
        for fn, args in pairs:
            jax.block_until_ready(fn(*args))
    times_a: list[float] = []
    times_b: list[float] = []
    for _ in range(passes):
        for _ in range(reps):
            for sink, (fn, args) in zip((times_a, times_b), pairs):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                sink.append(time.perf_counter() - t0)

    def _result(ts: list[float]) -> TimingResult:
        ordered = sorted(ts)
        return TimingResult(ordered[len(ordered) // 2], len(ts), tuple(ts))

    return _result(times_a), _result(times_b)


def hlo_counters(target, *args) -> dict[str, float]:
    """FLOPs and bytes-accessed as claimed by the compiled executable.

    ``target`` is either an already-compiled executable exposing
    ``cost_analysis()`` (``staging.Compiled`` / jax AOT executable — no
    recompile) or a jitted function, which is lowered and compiled here
    with ``*args``.
    """
    try:
        if hasattr(target, "cost_analysis"):
            ca = target.cost_analysis() or {}
        else:
            compiled = target.lower(*args).compile()
            ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        return {
            "hlo_flops": float(ca.get("flops", float("nan"))),
            "hlo_bytes": float(
                sum(v for k, v in ca.items() if k.startswith("bytes accessed"))
            ),
        }
    except Exception as e:  # pragma: no cover - backend-specific
        return {"hlo_flops": float("nan"), "hlo_bytes": float("nan"),
                "hlo_error": str(e)}


# ---------------------------------------------------------------------------
# Analytic native-tile traffic (the PAPI surrogate)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TileTraffic:
    """Per-sweep tile-granular traffic, split the way PAPI splits it.

    fetches            — tiles loaded across all programs (≈ L1 line fills)
    writebacks         — tiles written across all programs
    shared_write_tiles — tiles written by >1 program (the false-sharing
                         signal: each extra writer forces a read-modify-
                         write of a tile another program owns; on CPU this
                         is the request-for-exclusive-access storm)
    """

    fetches: int
    writebacks: int
    shared_write_tiles: int

    def as_dict(self) -> dict[str, int]:
        return dataclasses.asdict(self)


def _touched_tiles(lo: int, hi: int, tile_elems: int) -> set[int]:
    if hi <= lo:
        return set()
    return set(range(lo // tile_elems, (hi - 1) // tile_elems + 1))


def tile_traffic(
    *, spaces: Mapping[str, tuple[int, ...]],
    program_slices: Sequence[Mapping[str, tuple[int, int]]],
    written: str, itemsize: int = 4,
) -> TileTraffic:
    """Tile traffic for 1D-per-program slices (the paper's SMP studies).

    ``program_slices[p][space] = (lo, hi)`` is program p's contiguous
    element range in the *flattened* space. Tiles are NATIVE_TILE_BYTES
    blocks of the flat layout — the exact analogue of 64B cache lines.
    """
    tile_elems = NATIVE_TILE_BYTES // itemsize
    fetches = 0
    writebacks = 0
    writers: dict[tuple[str, int], int] = {}
    for sl in program_slices:
        for space, (lo, hi) in sl.items():
            tiles = _touched_tiles(lo, hi, tile_elems)
            fetches += len(tiles)
            if space == written:
                writebacks += len(tiles)
                for t in tiles:
                    writers[(space, t)] = writers.get((space, t), 0) + 1
    shared = sum(1 for v in writers.values() if v > 1)
    return TileTraffic(fetches, writebacks, shared)


# ---------------------------------------------------------------------------
# Output records (machine parsable + human readable, per the paper)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Record:
    pattern: str
    template: str
    schedule: str
    backend: str
    n: int
    working_set_bytes: int
    programs: int
    ntimes: int
    seconds: float
    gbs: float
    gflops: float
    level: str = ""            # which memory level the working set sits in
    extra: dict = dataclasses.field(default_factory=dict)

    @property
    def axis_point(self) -> dict:
        """The sweep-plan coordinates that produced this record (axis
        name -> labelled point), attached by the plan engine; empty for
        records measured outside a plan."""
        return dict(self.extra.get("axis_point", {}))

    def csv(self) -> str:
        us = self.seconds * 1e6
        return (
            f"{self.pattern}/{self.template}/{self.schedule}/{self.backend},"
            f"{us:.2f},{self.gbs:.3f}"
        )

    def json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


def latency_ns(rec: "Record", accesses_per_point: int = 1) -> float:
    """Per-access time of a record in ns — the latency view of a
    measurement (``seconds`` covers ``ntimes`` sweeps of
    ``extra["points"]`` iteration points each). For serially-dependent
    patterns (pointer chase) this IS load-to-use latency; for throughput
    patterns it is the Mess-style time-per-access under the record's
    load point, paired with ``rec.gbs`` for bandwidth–latency curves.
    """
    pts = int(rec.extra.get("points", rec.n)) or 1
    return rec.seconds / (rec.ntimes * pts * accesses_per_point) * 1e9


def classify_level(working_set_bytes: int) -> str:
    """Bucket a working set by the v5e hierarchy (per-core view)."""
    if working_set_bytes <= 96 * 2 ** 10:          # fits VREG+small VMEM slice
        return "vreg"
    if working_set_bytes <= 64 * 2 ** 20:          # VMEM-resident half budget
        return "vmem"
    return "hbm"
