"""Affine iteration domains — the Presburger-lite layer.

AdaptMemBench expresses kernel iteration spaces as integer sets in ISCC
(``[n] -> { S[i] : 1 <= i < n-1 }``) and generates loop nests from them.
This module is the JAX-native analogue: rectangular integer domains whose
bounds are affine expressions of symbolic *parameters* (the polyhedral
"context"). Parameters are resolved to concrete integers before lowering,
because XLA requires static shapes — this mirrors how the paper's drivers
instantiate ``n`` per working-set size before compiling a variant.

Scope note (documented deviation from full ISL): domains here are boxes
with affine bounds per dimension (inner bounds may reference outer
iterators with unit coefficients — enough for triangular/skewed spaces).
The paper itself only exercises rectangular domains (triad, Jacobi 1/2/3D)
plus tiling relations; everything in the paper's case studies is exactly
representable.
"""
from __future__ import annotations

import dataclasses
import itertools
from fractions import Fraction
from typing import Iterable, Mapping, Sequence

import numpy as np

__all__ = [
    "Affine",
    "Dim",
    "IterDomain",
    "domain",
]


def _norm(v: "int | Fraction") -> "int | Fraction":
    """Collapse integral Fractions back to int (canonical, hash-stable)."""
    if isinstance(v, Fraction) and v.denominator == 1:
        return int(v)
    return v


@dataclasses.dataclass(frozen=True)
class Affine:
    """An affine expression ``const + sum(coeffs[s] * s)`` over symbols.

    Symbols are strings naming either parameters ("n") or outer iterators
    ("i"). Immutable and hashable so schedules can be compared/cached.

    Coefficients and the constant are usually ints; the symbolic
    (parametric) lowering path additionally produces exact rationals
    (``Fraction``), e.g. the per-program chunk extent ``n/4`` of the
    unified template. Rational values are only legal when a recorded
    divisibility constraint guarantees they evaluate to integers;
    ``eval`` enforces integrality.
    """

    const: "int | Fraction" = 0
    coeffs: tuple[tuple[str, "int | Fraction"], ...] = ()

    @staticmethod
    def of(value: "Affine | int | str") -> "Affine":
        if isinstance(value, Affine):
            return value
        if isinstance(value, (int, np.integer)):
            return Affine(const=int(value))
        if isinstance(value, str):
            return Affine(coeffs=((value, 1),))
        raise TypeError(f"cannot coerce {value!r} to Affine")

    def _terms(self) -> dict[str, int]:
        return dict(self.coeffs)

    def __add__(self, other: "Affine | int | str") -> "Affine":
        other = Affine.of(other)
        terms = self._terms()
        for sym, c in other.coeffs:
            terms[sym] = terms.get(sym, 0) + c
        terms = {s: _norm(c) for s, c in terms.items() if c != 0}
        return Affine(_norm(self.const + other.const),
                      tuple(sorted(terms.items())))

    __radd__ = __add__

    def __sub__(self, other: "Affine | int | str") -> "Affine":
        return self + (Affine.of(other) * -1)

    def __mul__(self, k: "int | Fraction") -> "Affine":
        return Affine(_norm(self.const * k),
                      tuple((s, _norm(c * k)) for s, c in self.coeffs))

    __rmul__ = __mul__

    def __truediv__(self, k: int) -> "Affine":
        """Exact division (rational coefficients). ``eval`` later checks
        the result is integral for the given environment."""
        return self * Fraction(1, k)

    @property
    def is_const(self) -> bool:
        return not self.coeffs

    @property
    def denominator(self) -> int:
        """lcm of all coefficient denominators (1 for purely-int exprs)."""
        d = 1
        for v in (self.const, *(c for _, c in self.coeffs)):
            if isinstance(v, Fraction):
                d = d * v.denominator // np.gcd(d, v.denominator)
        return int(d)

    def subs(self, env: Mapping[str, int]) -> "Affine | int":
        """Substitute symbols; returns an int if fully resolved."""
        const = self.const
        remaining: dict[str, int | Fraction] = {}
        for sym, c in self.coeffs:
            if sym in env:
                const += c * int(env[sym])
            else:
                remaining[sym] = remaining.get(sym, 0) + c
        if not remaining:
            return _norm(const)
        return Affine(_norm(const), tuple(sorted(remaining.items())))

    def eval(self, env: Mapping[str, int]) -> int:
        out = self.subs(env)
        if isinstance(out, Affine):
            missing = [s for s, _ in out.coeffs]
            raise KeyError(f"unbound symbols {missing} in {self!r}")
        if isinstance(out, Fraction):
            raise ValueError(
                f"{self!r} is not integral under {dict(env)!r} "
                f"(got {out}); a divisibility constraint was violated"
            )
        return out

    @property
    def symbols(self) -> tuple[str, ...]:
        return tuple(s for s, _ in self.coeffs)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        parts = [str(self.const)] if self.const or not self.coeffs else []
        parts += [f"{c}*{s}" if c != 1 else s for s, c in self.coeffs]
        return " + ".join(parts) or "0"


@dataclasses.dataclass(frozen=True)
class Dim:
    """One iteration dimension: ``lo <= it < hi`` (half-open, step 1)."""

    name: str
    lo: Affine
    hi: Affine

    @staticmethod
    def of(name: str, lo, hi) -> "Dim":
        return Dim(name, Affine.of(lo), Affine.of(hi))

    def extent(self, env: Mapping[str, int]) -> int:
        return max(0, self.hi.eval(env) - self.lo.eval(env))


@dataclasses.dataclass(frozen=True)
class IterDomain:
    """An ordered product of :class:`Dim` — the iteration set of one statement.

    Order is the *lexicographic execution order* of the untransformed nest,
    exactly as ISCC's ``codegen`` would scan the set.
    """

    dims: tuple[Dim, ...]

    def __post_init__(self) -> None:
        names = [d.name for d in self.dims]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate iterator names: {names}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(d.name for d in self.dims)

    @property
    def rank(self) -> int:
        return len(self.dims)

    def dim(self, name: str) -> Dim:
        for d in self.dims:
            if d.name == name:
                return d
        raise KeyError(name)

    def extents(self, env: Mapping[str, int]) -> tuple[int, ...]:
        """Extents for rectangular domains (no iterator-dependent bounds)."""
        out = []
        for d in self.dims:
            lo, hi = d.lo.subs(env), d.hi.subs(env)
            if isinstance(lo, Affine) or isinstance(hi, Affine):
                raise ValueError(
                    f"dim {d.name} has iterator-dependent bounds; not rectangular"
                )
            out.append(max(0, hi - lo))
        return tuple(out)

    def size(self, env: Mapping[str, int]) -> int:
        return int(np.prod(self.extents(env))) if self.dims else 1

    def is_rectangular(self, env: Mapping[str, int]) -> bool:
        try:
            self.extents(env)
            return True
        except ValueError:
            return False

    def points(self, env: Mapping[str, int]) -> Iterable[tuple[int, ...]]:
        """Enumerate points in lexicographic order.

        Supports inner bounds referencing outer iterators (triangular
        spaces). Used by tests and the serial oracle; never on hot paths.
        """
        def rec(prefix: dict[str, int], i: int):
            if i == len(self.dims):
                yield tuple(prefix[d.name] for d in self.dims)
                return
            d = self.dims[i]
            scope = {**env, **prefix}
            lo, hi = d.lo.eval(scope), d.hi.eval(scope)
            for v in range(lo, hi):
                prefix[d.name] = v
                yield from rec(prefix, i + 1)
            prefix.pop(d.name, None)

        yield from rec({}, 0)

    def point_count(self, env: Mapping[str, int]) -> int:
        if self.is_rectangular(env):
            return self.size(env)
        return sum(1 for _ in self.points(env))


def domain(*dims: tuple) -> IterDomain:
    """Sugar: ``domain(("i", 1, "n" - 1)) -> IterDomain``.

    Bounds may be ints, parameter names, or :class:`Affine` expressions,
    e.g. ``domain(("i", 0, "n"), ("j", 0, Affine.of("n") - 1))``.
    """
    return IterDomain(tuple(Dim.of(name, lo, hi) for name, lo, hi in dims))
