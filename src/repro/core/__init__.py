"""repro.core — AdaptMemBench's contribution as a composable JAX library.

Layers (each maps to a component of the paper's Figure 1):

    domain / schedule   polyhedral-lite iteration sets + transformations
    pattern             pattern specifications (header + ISCC analogue)
    codegen             ISCC codegen analogue: -> vectorized JAX / Pallas
    staging             staged lower -> compile -> execute + translation cache
    drivers             unified / independent / measured driver templates
    measure             timing, bandwidth accounting, counter surrogates
    errors              failure taxonomy for fault-isolated sweeps
    autotune            schedule-variant sweeps (optimization testbed)
"""
from .domain import Affine, Dim, IterDomain, domain
from .errors import (
    BenchFailure,
    BudgetExceeded,
    CapacityRefused,
    CompileFailure,
    Demotion,
    FailureRecord,
    LowerFailure,
    MeasureFailure,
    ResiliencePolicy,
    SweepFailures,
    ValidateFailure,
    classify_failure,
)
from .schedule import ParamNest, Schedule, SymbolicLowerError, identity
from .pattern import (
    Access,
    DataSpace,
    PatternSpec,
    Statement,
    gather,
    gather_scatter,
    jacobi1d,
    jacobi2d,
    jacobi3d,
    mix_patterns,
    mix_space,
    nstream,
    pointer_chase,
    scatter,
    stream_copy,
    stream_scale,
    stream_sum,
    triad,
)
from .codegen import (
    NestPlan,
    ParamStridedPlan,
    lower_jax,
    lower_jax_parametric,
    lower_pallas,
    param_strided_plan,
    plan_nest,
    serial_oracle,
    windowed_oracle,
)
from .staging import (
    GLOBAL_CACHE,
    Compiled,
    Lowered,
    ParamCompiled,
    ParamLowered,
    TranslationCache,
    disk_cache_stats,
    precompile,
    stage_lower,
    stage_lower_parametric,
)
from .drivers import (
    Driver,
    DriverConfig,
    Prepared,
    independent_view,
    unified_program_schedule,
)
from .measure import (
    Record,
    TimingResult,
    classify_level,
    hlo_counters,
    latency_ns,
    tile_traffic,
    time_fn,
    time_pair,
)
from .autotune import SweepResult, Variant, sweep

__all__ = [
    "Affine", "Dim", "IterDomain", "domain",
    "Schedule", "ParamNest", "SymbolicLowerError", "identity",
    "Access", "DataSpace", "PatternSpec", "Statement",
    "triad", "stream_copy", "stream_scale", "stream_sum", "nstream",
    "jacobi1d", "jacobi2d", "jacobi3d",
    "gather", "scatter", "gather_scatter", "pointer_chase",
    "mix_patterns", "mix_space",
    "lower_jax", "lower_jax_parametric", "lower_pallas", "serial_oracle",
    "plan_nest", "NestPlan", "ParamStridedPlan", "param_strided_plan",
    "windowed_oracle",
    "Lowered", "Compiled", "ParamLowered", "ParamCompiled",
    "TranslationCache", "GLOBAL_CACHE",
    "stage_lower", "stage_lower_parametric", "precompile",
    "disk_cache_stats",
    "Driver", "DriverConfig", "Prepared",
    "independent_view", "unified_program_schedule",
    "Record", "TimingResult", "classify_level", "hlo_counters",
    "latency_ns", "tile_traffic", "time_fn", "time_pair",
    "BenchFailure", "LowerFailure", "CompileFailure", "ValidateFailure",
    "MeasureFailure", "BudgetExceeded", "CapacityRefused", "SweepFailures",
    "FailureRecord", "Demotion", "ResiliencePolicy", "classify_failure",
    "SweepResult", "Variant", "sweep",
]
