"""Code generation: lower (PatternSpec, Schedule) to executable JAX.

This is the analogue of ISCC's ``codegen`` call, retargeted at two
backends:

``lower_jax``
    Vectorized jax.numpy. Instances whose affine maps use **one band per
    domain dim** (identity, interchange, reverse, interleave, unroll — all
    of the paper's triad-family experiments) lower to static strided-slice
    reads + ``.at[...].set`` writes, which XLA fuses into a single
    streaming loop — the moral equivalent of the paper's generated C.
    General maps (tiling, skew) lower to a gather/scatter form used for
    validation and small working sets.

``lower_pallas``
    A Pallas kernel per schedule. Loop bands become the ``grid``; vector
    bands become the block. Refs are *unblocked* (whole array) and the
    kernel issues explicit dynamic slices — on TPU this corresponds to the
    HBM->VMEM manual-DMA style used for halo'd stencils. Blocked-
    ``BlockSpec`` showcase kernels live in ``repro.kernels``. Executed
    with ``interpret=True`` on this CPU container.

``serial_oracle``
    Pure-numpy point-by-point execution in generated-code order. The
    ground truth every backend is validated against (the paper's
    ``<kernel>_val.in`` stage).

Traversal-direction note: slices generated from the same band are paired
elementwise across reads and the write, so negative-coefficient maps
(reverse) need no flips — pairing by band value is automatically
consistent *provided all accesses agree on coefficient sign per band*,
which holds for every Schedule-generated nest (transforms rewrite all
instances uniformly). Hand-built accesses that mix signs fall back to the
gather path (checked).
"""
from __future__ import annotations

from typing import Callable, Mapping

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .domain import Affine
from .pattern import Access, PatternSpec
from .schedule import LoweredInstance, LoweredNest, Schedule

__all__ = [
    "serial_oracle",
    "lower_jax",
    "lower_pallas",
    "resolve_access",
]

_GATHER_POINT_CAP = 8_000_000  # refuse to embed bigger index constants


# ---------------------------------------------------------------------------
# Access resolution: Access (affine in iterator names) -> per-dim (row, const)
# over *bands*, by composing with a LoweredInstance.
# ---------------------------------------------------------------------------


def resolve_access(
    acc: Access, nest: LoweredNest, inst: LoweredInstance,
    iter_names: tuple[str, ...], env: Mapping[str, int],
) -> list[tuple[tuple[int, ...], int]]:
    """Compose an access's affine index with an instance's band map.

    Returns, per array dim, ``(coeff_per_band, const)`` such that
    ``array_index = coeff . bands + const``.
    """
    out = []
    pos = {n: i for i, n in enumerate(iter_names)}
    for ix in acc.resolved():
        ix = Affine.of(ix.subs(env))  # fold parameters like n
        row = [0] * nest.n_bands
        const = ix.const
        for sym, c in ix.coeffs:
            if sym not in pos:
                raise KeyError(f"access symbol {sym!r} is not an iterator or param")
            d = pos[sym]
            const += c * inst.c[d]
            for b in range(nest.n_bands):
                row[b] += c * inst.A[d][b]
        out.append((tuple(row), const))
    return out


def _signs_consistent(plans) -> bool:
    """All accesses in each instance agree on coeff sign per band."""
    for racc, wacc in plans:
        sign: dict[int, int] = {}
        for rows in list(racc) + [wacc]:
            for row, _ in rows:
                for b, c in enumerate(row):
                    if c == 0:
                        continue
                    s = 1 if c > 0 else -1
                    if sign.setdefault(b, s) != s:
                        return False
    return True


# ---------------------------------------------------------------------------
# Serial oracle
# ---------------------------------------------------------------------------


def serial_oracle(
    pattern: PatternSpec, nest: LoweredNest, arrays: dict[str, np.ndarray],
    env: Mapping[str, int], ntimes: int = 1,
) -> dict[str, np.ndarray]:
    """Execute the scheduled nest point-by-point in numpy. Copies inputs."""
    arrays = {k: np.array(v) for k, v in arrays.items()}
    names = pattern.domain.names
    stmt = pattern.statement
    for _ in range(ntimes):
        for point in nest.executed_points():
            scope = dict(zip(names, point))
            scope.update(env)
            vals = []
            for acc in stmt.reads:
                idx = tuple(Affine.of(ix).eval(scope) for ix in acc.index)
                vals.append(np.asarray(arrays[acc.space][idx]))
            res = stmt.combine(vals, dict(env))
            widx = tuple(Affine.of(ix).eval(scope) for ix in stmt.write.index)
            arrays[stmt.write.space][widx] = res
    return arrays


# ---------------------------------------------------------------------------
# Vectorized JAX backend
# ---------------------------------------------------------------------------


def _single_band_per_dim(nest: LoweredNest, inst: LoweredInstance) -> bool:
    """True if each domain dim reads exactly one band and each band feeds
    at most one dim — the strided-slice fast path precondition."""
    used: dict[int, int] = {}
    for d in range(nest.rank):
        nz = [b for b, c in enumerate(inst.A[d]) if c != 0]
        if len(nz) != 1:
            return False
        b = nz[0]
        if b in used:
            return False
        used[b] = d
    return True


def _slice_for(row: tuple[int, ...], const: int,
               extents: tuple[int, ...]) -> tuple[slice, int]:
    """Static strided slice covering ``{row.b + const : b in band box}``.

    ``row`` must have at most one nonzero coeff. The slice is always
    ascending-index; see the traversal-direction note in the module doc.
    Returns (slice, band_index) with band_index=-1 for constant indices.
    """
    nz = [(b, c) for b, c in enumerate(row) if c != 0]
    if not nz:
        return slice(const, const + 1), -1
    (b, c), = nz
    e = extents[b]
    if c > 0:
        return slice(const, const + c * (e - 1) + 1, c), b
    lo = const + c * (e - 1)
    return slice(lo, const + 1, -c), b


def _axis_perm(src_bands: list[int], dst_bands: list[int]):
    """Permutation taking value axes (ordered by src_bands) to dst order,
    or None if already aligned / not a permutation (broadcast case)."""
    if src_bands == dst_bands:
        return None
    if sorted(src_bands) != sorted(dst_bands):
        return None
    return tuple(src_bands.index(b) for b in dst_bands)


def lower_jax(
    pattern: PatternSpec, schedule: Schedule, env: Mapping[str, int],
    *, force_gather: bool = False,
) -> Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]:
    """Build ``step(arrays) -> arrays`` executing one sweep of the pattern."""
    nest = schedule.lower(pattern.domain, env)
    stmt = pattern.statement
    iter_names = pattern.domain.names
    guarded = nest.needs_guard()

    plans = []
    for inst in nest.instances:
        racc = [resolve_access(a, nest, inst, iter_names, env) for a in stmt.reads]
        wacc = resolve_access(stmt.write, nest, inst, iter_names, env)
        plans.append((racc, wacc))

    fast = (
        not force_gather
        and not guarded
        and all(_single_band_per_dim(nest, i) for i in nest.instances)
        and _signs_consistent(plans)
    )

    if fast:
        def step(arrays: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
            arrays = dict(arrays)
            for racc, wacc in plans:
                w_sl, w_bands = [], []
                for row, const in wacc:
                    sl, b = _slice_for(row, const, nest.band_extents)
                    w_sl.append(sl)
                    w_bands.append(b)
                vals = []
                for acc, rr in zip(stmt.reads, racc):
                    sls, bands_order = [], []
                    for row, const in rr:
                        sl, b = _slice_for(row, const, nest.band_extents)
                        sls.append(sl)
                        bands_order.append(b)
                    v = arrays[acc.space][tuple(sls)]
                    perm = _axis_perm(bands_order, w_bands)
                    if perm is not None:
                        v = jnp.transpose(v, perm)
                    vals.append(v)
                res = stmt.combine(vals, dict(env))
                tgt = arrays[stmt.write.space]
                arrays[stmt.write.space] = tgt.at[tuple(w_sl)].set(
                    jnp.asarray(res).astype(tgt.dtype)
                )
            return arrays

        return step

    # -- gather/scatter general path ---------------------------------------
    n_pts = int(np.prod(nest.band_extents)) if nest.band_extents else 1
    if n_pts > _GATHER_POINT_CAP:
        raise ValueError(
            f"gather path would embed {n_pts} index points; use lower_pallas"
        )
    grids = np.indices(nest.band_extents).reshape(nest.n_bands, -1)
    gather_plans = []
    for inst in nest.instances:
        iters = (
            np.array(inst.A, dtype=np.int64) @ grids
            + np.array(inst.c, dtype=np.int64)[:, None]
        )  # (rank, P)
        mask = np.ones(iters.shape[1], dtype=bool)
        for d in range(nest.rank):
            mask &= (iters[d] >= nest.domain_lo[d]) & (iters[d] < nest.domain_hi[d])
        scope: dict[str, np.ndarray] = {
            n: iters[d] for d, n in enumerate(iter_names)
        }
        scope.update({k: np.int64(v) for k, v in env.items()})

        def resolve_idx(acc: Access):
            return tuple(
                np.asarray(_affine_np(Affine.of(ix), scope), dtype=np.int32)
                for ix in acc.index
            )

        gather_plans.append(
            ([resolve_idx(a) for a in stmt.reads], resolve_idx(stmt.write), mask)
        )

    def step(arrays: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        for ridx, widx, mask in gather_plans:
            # OOB reads clamp (jit default); their lanes are dropped on write
            vals = [
                arrays[acc.space][idx]
                for acc, idx in zip(stmt.reads, ridx)
            ]
            res = stmt.combine(vals, dict(env))
            tgt = arrays[stmt.write.space]
            if not mask.all():
                widx = tuple(np.where(mask, ix, -1) for ix in widx)
            arrays[stmt.write.space] = tgt.at[widx].set(
                jnp.asarray(res).astype(tgt.dtype), mode="drop"
            )
        return arrays

    return step


def _affine_np(a: Affine, scope: Mapping[str, np.ndarray]) -> np.ndarray:
    acc = np.int64(a.const)
    for sym, c in a.coeffs:
        acc = acc + c * scope[sym]
    return acc


# ---------------------------------------------------------------------------
# Pallas backend (manual-DMA style; blocked showcase kernels in repro.kernels)
# ---------------------------------------------------------------------------


def lower_pallas(
    pattern: PatternSpec, schedule: Schedule, env: Mapping[str, int],
    *, interpret: bool = True, grid_bands: tuple[str, ...] | None = None,
) -> Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]:
    """Lower to ``pl.pallas_call``.

    Bands are split into *grid bands* (pallas grid) and *vector bands*
    (in-kernel slice extents). By default the innermost unit-stride band
    of each domain dim is the vector band; ``grid_bands`` forces named
    bands into the grid (used by the tile-sweep benchmarks so tile loops
    become grid steps, exactly like the generated ISCC tile loops).
    The output space is aliased to its input so un-iterated elements
    (stencil borders) keep their initial values, matching the oracle.
    """
    nest = schedule.lower(pattern.domain, env)
    if nest.needs_guard():
        raise NotImplementedError(
            "guarded schedules on the pallas backend: pick divisible tile "
            "sizes (the drivers choose divisible working sets)"
        )
    stmt = pattern.statement
    iter_names = pattern.domain.names
    rank = nest.rank

    inst0 = nest.instances[0]
    vec_band_for_dim: list[int] = []
    for d in range(rank):
        cands = [b for b, c in enumerate(inst0.A[d]) if abs(c) == 1]
        if not cands:
            raise ValueError(f"dim {d} has no unit-stride band; cannot vectorize")
        vec_band_for_dim.append(max(cands))
    vec_bands = sorted(set(vec_band_for_dim))
    if grid_bands is not None:
        vec_bands = [b for b in vec_bands if nest.band_names[b] not in grid_bands]
    gbs = [b for b in range(nest.n_bands) if b not in vec_bands]
    for inst in nest.instances:
        for d in range(rank):
            for b in vec_bands:
                if inst.A[d][b] not in (-1, 0, 1):
                    raise ValueError("vector band with non-unit stride")

    grid = tuple(nest.band_extents[b] for b in gbs) or (1,)
    vec_extents = {b: nest.band_extents[b] for b in vec_bands}

    acc_plans = []
    for inst in nest.instances:
        racc = [resolve_access(a, nest, inst, iter_names, env) for a in stmt.reads]
        wacc = resolve_access(stmt.write, nest, inst, iter_names, env)
        acc_plans.append((racc, wacc))
    if not _signs_consistent(acc_plans):
        raise ValueError("mixed coefficient signs per band; not vectorizable")

    space_order = [s.name for s in pattern.spaces]
    out_name = stmt.write.space
    out_pos = space_order.index(out_name)
    shapes = {s.name: s.concrete_shape(env) for s in pattern.spaces}
    dtypes = {s.name: s.dtype for s in pattern.spaces}
    env_dict = dict(env)

    def kernel(*refs):
        in_refs = {nm: r for nm, r in zip(space_order, refs[:len(space_order)])}
        out_ref = refs[len(space_order)]
        gvals = [pl.program_id(i) for i in range(len(gbs))] if gbs else []

        def base_of(rows_const):
            """(base index at vector-band==0/origin, vector band per dim)."""
            base, vb = [], []
            for row, const in rows_const:
                off = const
                for gi, b in enumerate(gbs):
                    off = off + row[b] * gvals[gi]
                bsel, bstep = -1, 1
                for b in vec_bands:
                    if row[b] != 0:
                        bsel, bstep = b, row[b]
                if bsel >= 0 and bstep == -1:
                    # ascending-index window: [off - (e-1), off]
                    off = off - (vec_extents[bsel] - 1)
                base.append(off)
                vb.append(bsel)
            return base, vb

        for racc, wacc in acc_plans:
            wbase, wvb = base_of(wacc)
            vals = []
            for acc, rows in zip(stmt.reads, racc):
                base, vb = base_of(rows)
                idx = tuple(
                    pl.ds(b0, vec_extents[bsel] if bsel >= 0 else 1)
                    for b0, bsel in zip(base, vb)
                )
                v = in_refs[acc.space][idx]
                perm = _axis_perm(vb, wvb)
                if perm is not None:
                    v = jnp.transpose(v, perm)
                vals.append(v)
            res = stmt.combine(vals, env_dict)
            want = tuple(1 if b < 0 else vec_extents[b] for b in wvb)
            res = jnp.asarray(res).astype(out_ref.dtype)
            if res.shape != want:
                res = jnp.broadcast_to(res, want)
            widx = tuple(
                pl.ds(b0, vec_extents[bsel] if bsel >= 0 else 1)
                for b0, bsel in zip(wbase, wvb)
            )
            out_ref[widx] = res

    call = pl.pallas_call(
        kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct(shapes[out_name], dtypes[out_name]),
        input_output_aliases={out_pos: 0},
        interpret=interpret,
    )

    def step(arrays: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        arrays[out_name] = call(*[arrays[nm] for nm in space_order])
        return arrays

    return step
