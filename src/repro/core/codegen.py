"""Code generation: lower (PatternSpec, Schedule) to executable JAX.

This is the analogue of ISCC's ``codegen`` call, retargeted at two
backends and split into explicit stages so drivers, sweeps, and the
autotuner can share work through the translation cache (see
``staging.py``):

``plan_nest``
    Stage 0 of the pipeline: lower the schedule against a concrete env
    and resolve every access into per-band ``(coeffs, const)`` rows.
    Plan building is pure Python (no tracing) and is what the staged
    ``Lowered`` artifact memoizes.

``lower_jax``
    Vectorized jax.numpy. Instances whose affine maps use **one band per
    domain dim** (identity, interchange, reverse, interleave, unroll — all
    of the paper's triad-family experiments) lower to static strided-slice
    reads + ``.at[...].set`` writes, which XLA fuses into a single
    streaming loop — the moral equivalent of the paper's generated C.
    General maps (tiling, skew) lower to a gather/scatter form whose
    indices are built *inside* the traced program from
    ``lax.broadcasted_iota`` (never embedded as host constants), so large
    grids stay cheap to trace and compile.

``lower_jax_parametric``
    Shape-polymorphic twin of ``lower_jax``: the working-set parameters
    become traced operands so one AOT executable serves a whole ladder.
    Two regimes, selected by ``param_path``: the **strided fast path**
    (``lax.dynamic_slice``/``dynamic_update_slice`` windows, chosen
    whenever the symbolic nest satisfies the same single-band precondition
    as the specialized strided path — per-call cost matches it; windows
    are **multi-dimensional** for stencil nests, covering an
    (i-chunk x j-chunk x ...) box per step over every dynamic band the
    write references, with stencil reads fused into one halo'd hull
    slice per space) and the **masked gather/scatter** fallback for
    everything else (guards, splits, diagonals). ``step.param_path`` /
    ``step.param_window_rank`` report what was built.

``lower_pallas``
    A Pallas kernel per schedule. Loop bands become the ``grid``; vector
    bands become the block. Refs are *unblocked* (whole array) and the
    kernel issues explicit dynamic slices — on TPU this corresponds to the
    HBM->VMEM manual-DMA style used for halo'd stencils. Blocked-
    ``BlockSpec`` showcase kernels live in ``repro.kernels``. Execution
    mode is platform-probed once per process (``pallas_platform_mode``):
    native/compiled where the backend supports ``pl.pallas_call``
    lowering, ``interpret=True`` otherwise (XLA:CPU).

``lower_pallas_parametric``
    Shape-polymorphic twin of ``lower_pallas``, strided regime only: the
    ``param_strided_window`` specs become pallas *grid* steps over N-D
    ``pl.ds`` windows, with the working-set parameters read from a traced
    i32 operand — one pallas executable serves a whole working-set
    ladder, same contract as ``lower_jax_parametric``'s strided path.

``serial_oracle``
    Pure-numpy execution in generated-code order. The ground truth every
    backend is validated against (the paper's ``<kernel>_val.in`` stage).
    Nests whose statement never reads its written space and whose maps
    admit the strided-slice form are executed with vectorized numpy
    slices (provably order-independent there); everything else falls
    back to the point-by-point loop.

Traversal-direction note: slices generated from the same band are paired
elementwise across reads and the write, so negative-coefficient maps
(reverse) need no flips — pairing by band value is automatically
consistent *provided all accesses agree on coefficient sign per band*,
which holds for every Schedule-generated nest (transforms rewrite all
instances uniformly). Hand-built accesses that mix signs fall back to the
gather path (checked).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .domain import Affine
from .errors import LowerFailure
from .pattern import Access, PatternSpec, mix_space
from .schedule import (
    LoweredInstance,
    LoweredNest,
    ParamInstance,
    ParamNest,
    Schedule,
    _const_int,
)

__all__ = [
    "serial_oracle",
    "replay_component",
    "lower_jax",
    "lower_mix",
    "lower_jax_parametric",
    "lower_pallas",
    "lower_pallas_parametric",
    "pallas_platform_mode",
    "resolve_access",
    "resolve_access_symbolic",
    "plan_nest",
    "NestPlan",
    "ParamStridedPlan",
    "param_strided_plan",
    "param_strided_in_bounds",
    "param_strided_window",
    "param_window_bands",
    "windowed_oracle",
]

# Indices are now built in-program from broadcasted_iota (no host-side
# constants), so the cap only bounds runtime index-array memory.
_GATHER_POINT_CAP = 1 << 26

# Lane-block size of the parametric (shape-polymorphic) path: points are
# executed in fixed-shape chunks under a dynamic trip count, so the work
# a call performs scales with the runtime working set, not the capacity.
_PARAM_CHUNK = 8192


# ---------------------------------------------------------------------------
# Access resolution: Access (affine in iterator names) -> per-dim (row, const)
# over *bands*, by composing with a LoweredInstance.
# ---------------------------------------------------------------------------


def resolve_access(
    acc: Access, nest: LoweredNest, inst: LoweredInstance,
    iter_names: tuple[str, ...], env: Mapping[str, int],
) -> list[tuple[tuple[int, ...], int]]:
    """Compose an access's affine index with an instance's band map.

    Returns, per array dim, ``(coeff_per_band, const)`` such that
    ``array_index = coeff . bands + const``.
    """
    out = []
    pos = {n: i for i, n in enumerate(iter_names)}
    for ix in acc.resolved():
        ix = Affine.of(ix.subs(env))  # fold parameters like n
        row = [0] * nest.n_bands
        const = ix.const
        for sym, c in ix.coeffs:
            if sym not in pos:
                raise KeyError(f"access symbol {sym!r} is not an iterator or param")
            d = pos[sym]
            const += c * inst.c[d]
            for b in range(nest.n_bands):
                row[b] += c * inst.A[d][b]
        out.append((tuple(row), const))
    return out


def _signs_consistent(plans) -> bool:
    """All accesses in each instance agree on coeff sign per band."""
    for racc, wacc in plans:
        sign: dict[int, int] = {}
        for rows in list(racc) + [wacc]:
            for row, _ in rows:
                for b, c in enumerate(row):
                    if c == 0:
                        continue
                    s = 1 if c > 0 else -1
                    if sign.setdefault(b, s) != s:
                        return False
    return True


# ---------------------------------------------------------------------------
# Access plans (stage 0 of the pipeline)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NestPlan:
    """Resolved access plans for one (pattern, schedule, env) instance.

    ``plans[k] = (read_rows, write_rows)`` for statement instance k, where
    each rows entry is ``resolve_access`` output: per array dim,
    ``(coeff_per_band, const)``. Building a plan never traces; it is the
    unit of work the translation cache's lower stage memoizes.
    """

    nest: LoweredNest
    plans: tuple
    guarded: bool
    single_band: bool
    signs_ok: bool

    @property
    def fast(self) -> bool:
        """Strided-slice fast path precondition."""
        return not self.guarded and self.single_band and self.signs_ok


def plan_nest(pattern: PatternSpec, schedule: Schedule,
              env: Mapping[str, int], nest: LoweredNest | None = None,
              ) -> NestPlan:
    """Lower the schedule and resolve every access against its bands."""
    if nest is None:
        nest = schedule.lower(pattern.domain, env)
    return _plan_from_nest(pattern, nest, env)


def _plan_from_nest(pattern: PatternSpec, nest: LoweredNest,
                    env: Mapping[str, int]) -> NestPlan:
    stmt = pattern.statement
    iter_names = pattern.domain.names
    plans = tuple(
        (
            tuple(
                resolve_access(a, nest, inst, iter_names, env)
                for a in stmt.reads
            ),
            resolve_access(stmt.write, nest, inst, iter_names, env),
        )
        for inst in nest.instances
    )
    return NestPlan(
        nest=nest,
        plans=plans,
        guarded=nest.needs_guard(),
        single_band=all(_single_band_per_dim(nest, i) for i in nest.instances),
        signs_ok=_signs_consistent(plans),
    )


# ---------------------------------------------------------------------------
# Serial oracle
# ---------------------------------------------------------------------------


def serial_oracle(
    pattern: PatternSpec, nest: LoweredNest, arrays: dict[str, np.ndarray],
    env: Mapping[str, int], ntimes: int = 1, *, force_loop: bool = False,
) -> dict[str, np.ndarray]:
    """Execute the scheduled nest in numpy. Copies inputs.

    Fast path: when the statement never reads its written space, the nest
    needs no guards, and every instance admits the strided-slice form,
    sweeps are executed with vectorized numpy slice assignments — result
    is provably identical to the point loop (reads cannot observe writes
    within a sweep; schedule bijectivity keeps instance writes disjoint).
    ``force_loop=True`` pins the point-by-point reference (tests).
    """
    if pattern.oracle is not None:
        # serial-dependent patterns (pointer chase) carry their own
        # ground truth; the affine replay below cannot express them
        return pattern.oracle(pattern, arrays, env, ntimes)
    arrays = {k: np.array(v) for k, v in arrays.items()}
    names = pattern.domain.names
    stmt = pattern.statement
    if not force_loop:
        plan = _oracle_plan(pattern, nest, env)
        if plan is not None:
            return _oracle_vectorized(pattern, plan, arrays, env, ntimes)
    for _ in range(ntimes):
        for point in nest.executed_points():
            scope = dict(zip(names, point))
            scope.update(env)
            vals = []
            for acc in stmt.reads:
                idx = tuple(Affine.of(ix).eval(scope) for ix in acc.index)
                vals.append(np.asarray(arrays[acc.space][idx]))
            res = stmt.combine(vals, dict(env))
            widx = tuple(Affine.of(ix).eval(scope) for ix in stmt.write.index)
            arrays[stmt.write.space][widx] = res
    return arrays


def replay_component(comp: PatternSpec, arrays: dict[str, np.ndarray],
                     env: Mapping[str, int], ntimes: int = 1) -> dict:
    """Numpy ground truth for ONE mix component: its own oracle when it
    carries one (value-dependent components), else the serial oracle
    over its identity nest. Mix components execute under the identity
    schedule inside the fused step, so the identity nest is exactly what
    :func:`lower_mix` runs."""
    from .schedule import identity

    if comp.oracle is not None:
        return comp.oracle(comp, arrays, env, ntimes)
    nest = identity().lower(comp.domain, env)
    return serial_oracle(comp, nest, arrays, env, ntimes=ntimes)


def lower_mix(pattern: PatternSpec, components: tuple) -> Callable:
    """Build the fused step of a :func:`~repro.core.pattern.mix_patterns`
    spec: every component's own step (affine statements lower through
    :func:`lower_jax`; custom-kernel components contribute their kernel)
    runs once per sweep against its ``m{k}_``-namespaced slice of the
    array dict, inside ONE jitted executable — the access streams share
    the compiled program, so the fused ``ntimes`` repetition loop
    alternates the components' sweeps through the memory system.

    ``components`` is the concretized ``(label, spec, env)`` tuple the
    mix kernel closed over (each component's env is baked — mixes always
    specialize, like every custom-kernel pattern).
    """
    from .schedule import identity

    steps = tuple(
        (k, comp, lower_jax(comp, identity(), cenv))
        for k, (_label, comp, cenv) in enumerate(components)
    )

    def step(arrays):
        arrays = dict(arrays)
        for k, comp, st in steps:
            sub = {s.name: arrays[mix_space(k, s.name)] for s in comp.spaces}
            sub = st(sub)
            for s in comp.spaces:
                arrays[mix_space(k, s.name)] = sub[s.name]
        return arrays

    return step


def _oracle_plan(pattern: PatternSpec, nest: LoweredNest,
                 env: Mapping[str, int]) -> NestPlan | None:
    """NestPlan if the vectorized oracle path is provably safe, else None."""
    stmt = pattern.statement
    if any(a.space == stmt.write.space for a in stmt.reads):
        return None
    try:
        plan = _plan_from_nest(pattern, nest, env)
    except Exception:
        return None
    return plan if plan.fast else None


def _oracle_vectorized(pattern: PatternSpec, plan: NestPlan,
                       arrays: dict[str, np.ndarray],
                       env: Mapping[str, int], ntimes: int,
                       ) -> dict[str, np.ndarray]:
    """Numpy mirror of the strided-slice fast path (see lower_jax)."""
    stmt = pattern.statement
    nest = plan.nest
    for _ in range(ntimes):
        for racc, wacc in plan.plans:
            w_sl, w_bands = [], []
            for row, const in wacc:
                sl, b = _slice_for(row, const, nest.band_extents)
                w_sl.append(sl)
                w_bands.append(b)
            vals = []
            for acc, rows in zip(stmt.reads, racc):
                sls, bands_order = [], []
                for row, const in rows:
                    sl, b = _slice_for(row, const, nest.band_extents)
                    sls.append(sl)
                    bands_order.append(b)
                v = arrays[acc.space][tuple(sls)]
                perm = _axis_perm(bands_order, w_bands)
                if perm is not None:
                    v = np.transpose(v, perm)
                vals.append(v)
            res = stmt.combine(vals, dict(env))
            tgt = arrays[stmt.write.space]
            tgt[tuple(w_sl)] = np.asarray(res).astype(tgt.dtype)
    return arrays


# ---------------------------------------------------------------------------
# Vectorized JAX backend
# ---------------------------------------------------------------------------


def _single_band_per_dim(nest: LoweredNest, inst: LoweredInstance) -> bool:
    """True if each domain dim reads exactly one band and each band feeds
    at most one dim — the strided-slice fast path precondition."""
    used: dict[int, int] = {}
    for d in range(nest.rank):
        nz = [b for b, c in enumerate(inst.A[d]) if c != 0]
        if len(nz) != 1:
            return False
        b = nz[0]
        if b in used:
            return False
        used[b] = d
    return True


def _slice_for(row: tuple[int, ...], const: int,
               extents: tuple[int, ...]) -> tuple[slice, int]:
    """Static strided slice covering ``{row.b + const : b in band box}``.

    ``row`` must have at most one nonzero coeff. The slice is always
    ascending-index; see the traversal-direction note in the module doc.
    Returns (slice, band_index) with band_index=-1 for constant indices.
    """
    nz = [(b, c) for b, c in enumerate(row) if c != 0]
    if not nz:
        return slice(const, const + 1), -1
    (b, c), = nz
    e = extents[b]
    if c > 0:
        return slice(const, const + c * (e - 1) + 1, c), b
    lo = const + c * (e - 1)
    return slice(lo, const + 1, -c), b


def _axis_perm(src_bands: list[int], dst_bands: list[int]):
    """Permutation taking value axes (ordered by src_bands) to dst order,
    or None if already aligned / not a permutation (broadcast case)."""
    if src_bands == dst_bands:
        return None
    if sorted(src_bands) != sorted(dst_bands):
        return None
    return tuple(src_bands.index(b) for b in dst_bands)


def lower_jax(
    pattern: PatternSpec, schedule: Schedule, env: Mapping[str, int],
    *, force_gather: bool = False, plan: NestPlan | None = None,
) -> Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]:
    """Build ``step(arrays) -> arrays`` executing one sweep of the pattern.

    ``plan`` lets the staged pipeline reuse an already-resolved NestPlan
    instead of re-deriving access rows.
    """
    if pattern.kernel is not None:
        # serial-dependent patterns replace the generated step wholesale;
        # schedule transforms would be silently ignored, so refuse them
        if schedule.transforms:
            raise ValueError(
                f"pattern {pattern.name!r} has a custom kernel; schedule "
                f"{schedule.name!r} cannot be applied to it"
            )
        return pattern.kernel(pattern, env)
    if plan is None:
        plan = plan_nest(pattern, schedule, env)
    nest = plan.nest
    stmt = pattern.statement
    plans = plan.plans

    if plan.fast and not force_gather:
        def step(arrays: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
            arrays = dict(arrays)
            for racc, wacc in plans:
                w_sl, w_bands = [], []
                for row, const in wacc:
                    sl, b = _slice_for(row, const, nest.band_extents)
                    w_sl.append(sl)
                    w_bands.append(b)
                vals = []
                for acc, rr in zip(stmt.reads, racc):
                    sls, bands_order = [], []
                    for row, const in rr:
                        sl, b = _slice_for(row, const, nest.band_extents)
                        sls.append(sl)
                        bands_order.append(b)
                    v = arrays[acc.space][tuple(sls)]
                    perm = _axis_perm(bands_order, w_bands)
                    if perm is not None:
                        v = jnp.transpose(v, perm)
                    vals.append(v)
                res = stmt.combine(vals, dict(env))
                tgt = arrays[stmt.write.space]
                arrays[stmt.write.space] = tgt.at[tuple(w_sl)].set(
                    jnp.asarray(res).astype(tgt.dtype)
                )
            return arrays

        return step

    # -- gather/scatter general path ---------------------------------------
    # Band coordinates come from lax.broadcasted_iota inside the traced
    # program, so no index constants are embedded in the HLO and trace
    # size stays O(accesses), not O(points).
    n_pts = int(np.prod(nest.band_extents)) if nest.band_extents else 1
    if n_pts > _GATHER_POINT_CAP:
        raise ValueError(
            f"gather path would materialize {n_pts} index points; "
            "use lower_pallas"
        )
    guarded = plan.guarded
    used_bands = sorted({
        b
        for racc, wacc in plans
        for rows in list(racc) + [wacc]
        for row, _ in rows
        for b, c in enumerate(row)
        if c != 0
    } | ({
        b
        for inst in nest.instances
        for d in range(nest.rank)
        for b, c in enumerate(inst.A[d])
        if c != 0
    } if guarded else set()))
    extents = nest.band_extents

    def step(arrays: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        cols = {
            b: jax.lax.broadcasted_iota(jnp.int32, extents, b).reshape(-1)
            for b in used_bands
        }

        def lin(row, const):
            acc = None
            for b, c in enumerate(row):
                if c == 0:
                    continue
                term = c * cols[b]
                acc = term if acc is None else acc + term
            if acc is None:
                return jnp.full((n_pts,), const, jnp.int32)
            return acc + jnp.int32(const)

        for (racc, wacc), inst in zip(plans, nest.instances):
            mask = None
            if guarded:
                mask = jnp.ones((n_pts,), bool)
                for d in range(nest.rank):
                    it = lin(inst.A[d], inst.c[d])
                    mask &= (it >= nest.domain_lo[d]) & (it < nest.domain_hi[d])
            # OOB reads clamp (jit default); their lanes are dropped on write
            vals = [
                arrays[acc.space][tuple(lin(row, const) for row, const in rows)]
                for acc, rows in zip(stmt.reads, racc)
            ]
            res = stmt.combine(vals, dict(env))
            tgt = arrays[stmt.write.space]
            widx = tuple(lin(row, const) for row, const in wacc)
            if mask is not None:
                widx = tuple(jnp.where(mask, ix, -1) for ix in widx)
            arrays[stmt.write.space] = tgt.at[widx].set(
                jnp.asarray(res).astype(tgt.dtype), mode="drop"
            )
        return arrays

    return step


# ---------------------------------------------------------------------------
# Parametric (shape-polymorphic) JAX backend
# ---------------------------------------------------------------------------


def resolve_access_symbolic(
    acc: Access, pnest: ParamNest, inst: ParamInstance,
    iter_names: tuple[str, ...],
) -> list[tuple[tuple[Affine, ...], Affine]]:
    """Symbolic twin of :func:`resolve_access`: compose an access with a
    :class:`ParamInstance` without resolving parameters, so per array dim
    ``array_index = row . bands + const`` with Affine-in-params entries."""
    out = []
    pos = {n: i for i, n in enumerate(iter_names)}
    for ix in acc.resolved():
        row = [Affine.of(0)] * pnest.n_bands
        const = Affine.of(ix.const)
        for sym, c in ix.coeffs:
            if sym in pos:
                d = pos[sym]
                const = const + inst.c[d] * c
                for b in range(pnest.n_bands):
                    row[b] = row[b] + inst.A[d][b] * c
            elif sym in pnest.params:
                const = const + Affine(coeffs=((sym, c),))
            else:
                raise KeyError(
                    f"access symbol {sym!r} is not an iterator or param"
                )
        out.append((tuple(row), const))
    return out


def _affine_traced(aff: Affine, scope: Mapping[str, jnp.ndarray]):
    """Evaluate an Affine whose symbols map to traced int32 scalars.

    Rational coefficients are handled exactly: the whole expression is
    scaled by the lcm of the denominators, evaluated in integers, and
    divided back out — by construction (divisibility constraints) the
    result is integral, so the floor division is exact.
    """
    L = aff.denominator
    acc = jnp.int32(int(aff.const * L))
    for sym, c in aff.coeffs:
        acc = acc + jnp.int32(int(c * L)) * scope[sym]
    return acc // L if L != 1 else acc


# -- parametric strided fast path (dynamic-slice windows) --------------------
#
# The third lowering regime: when the symbolic nest satisfies the same
# precondition as the specialized strided-slice path (single-band affine
# instance maps with constant integer strides, provably unguarded, one
# window dim per access, consistent coefficient signs), lane chunks are
# executed as ``lax.dynamic_slice`` / ``dynamic_update_slice`` windows
# whose starts are computed from the traced extent operands — per-call
# cost tracks the specialized path instead of paying the masked
# gather/scatter tax, so ``programs``-axis sweeps on one executable stay
# regime-comparable.
#
# Window mechanics: windows are **multi-dimensional**. Every
# dynamic-extent band that the write references is a *window band* — the
# innermost (lane) band always, plus, for stencil nests lowered under an
# N-D spec, the outer i/j bands of jacobi2d/3d — and one
# ``lax.dynamic_slice`` covers an (i-chunk x j-chunk x ...) box per loop
# step instead of a row per step. Bands with *static* extents that the
# write references (the independent template's ``programs`` axis) are
# vectorized into the window itself — a ``(programs, Ci, Cj)``-shaped
# dynamic slice per step, so the hot loop matches the specialized path's
# full-width slice ops instead of serializing programs. Dynamic bands
# the write ignores stay serial loop bands (last-value-wins) and
# contribute point (size-1) dims per loop step. The window geometry is
# resolved per ladder by :func:`param_strided_window` into either a
# plain int (rank-1: the legacy lane chunk) or a ``((band, C), ...)``
# spec; ``param_window_bands`` names the candidate bands.
#
# One traced ``fori_loop`` body in one of two emission modes (NEVER a
# ``lax.cond`` between them: XLA:CPU loses buffer aliasing through
# conditionals, which resurrects a capacity-sized copy per call and
# defeats the whole regime):
#
# * ``assume_full`` (drivers emit this whenever they can clamp the
#   chunk to the ladder's smallest window extent — every measurement
#   chunk is then provably full): the final window of a rung is pulled
#   back to ``min(ws, ext - C)`` instead of masked — the overlapped
#   lanes recompute identical values (writes are idempotent), so every
#   lane is a valid point, no masks, no clamped slices, and the write
#   is a plain ``dynamic_update_slice``. Calling this executable at an
#   env with ``ext < C`` is a caller-contract violation.
# * masked (the default; correct for every rung): windows are anchored
#   sign-aware — band range ``[ws, ws+C)`` with the start floored at 0
#   for ascending accesses and allowed to go negative (range
#   ``[ext-C, ext)``) for descending ones, so slice starts stay at
#   valid in-bounds positions even when a rung is smaller than one
#   window — and the write *blends*: lanes outside [0, ext) keep the
#   target's current contents (they may sit in the independent
#   template's pad columns, which the oracle checks).
#
# Strided accesses (|coeff| > 1) use windows of ``(extent-1)*|coeff|+1``
# elements (exactly the strided span) and subsample/blend with static
# strided slices. ``param_strided_in_bounds`` is the exact per-env
# capacity-bounds check drivers run before committing a ladder to this
# regime (a clamped dynamic slice would silently misalign, so any env
# whose windows could leave the capacity shapes falls back to gather),
# and ``param_strided_window`` is the ladder-level (chunk, assume_full)
# policy they resolve it with.


@dataclasses.dataclass(frozen=True)
class ParamStridedPlan:
    """Access-level window plan for the parametric strided regime.

    ``plans[k] = (reads, write, window_sign)`` for instance k; each
    access is a tuple over its array dims of ``(band, stride, const)``
    where ``band`` is the driving band (-1 for a constant index),
    ``stride`` the constant integer coefficient, and ``const`` the
    symbolic offset (Affine in the params). ``window_sign`` is the shared
    coefficient sign of the window band across the instance's accesses
    (sign consistency is part of eligibility), which picks the partial-
    window anchor. ``window_band`` is the nest's innermost band — the one
    lane windows run along.
    """

    window_band: int
    plans: tuple


def param_strided_plan(pattern: PatternSpec,
                       pnest: ParamNest) -> ParamStridedPlan | None:
    """The window plan when (pattern, pnest) admits the strided regime,
    else None (caller falls back to masked gather/scatter).

    On top of :meth:`ParamNest.strided_eligible` (nest-level), every
    access must be sliceable: at most one band per array dim, constant
    integer coefficients, consistent signs per band across an instance's
    accesses, the write referencing the window band, and no access
    referencing it in more than one dim (diagonals stay on gather).
    Statements that read their own write space are rejected outright —
    the min-start window overlap recomputes the final lanes of a rung,
    and a re-read of already-updated values would corrupt them (the
    serial oracle's vectorized path guards the same case).
    """
    if pattern.kernel is not None or not pnest.strided_eligible():
        return None
    stmt = pattern.statement
    if any(a.space == stmt.write.space for a in stmt.reads):
        return None
    iter_names = pattern.domain.names
    w = pnest.n_bands - 1
    zero = Affine.of(0)
    insts = []
    for inst in pnest.instances:
        try:
            raccs = [resolve_access_symbolic(a, pnest, inst, iter_names)
                     for a in stmt.reads]
            wacc = resolve_access_symbolic(stmt.write, pnest, inst, iter_names)
        except KeyError:
            return None
        sign: dict[int, int] = {}

        def conv(rows):
            out, seen = [], set()
            for row, const in rows:
                nz = [(b, _const_int(c)) for b, c in enumerate(row)
                      if c != zero]
                if not nz:
                    out.append((-1, 0, const))
                    continue
                if len(nz) > 1:
                    return None
                b, cf = nz[0]
                if cf is None or cf == 0:
                    return None
                s = 1 if cf > 0 else -1
                if sign.setdefault(b, s) != s:
                    return None
                if b in seen:  # diagonal (one band, two dims): gather
                    return None
                seen.add(b)
                out.append((b, cf, const))
            return tuple(out)

        w_conv = conv(wacc)
        if w_conv is None or not any(b == w for b, _, _ in w_conv):
            return None
        r_convs = []
        for rows in raccs:
            rc = conv(rows)
            if rc is None:
                return None
            r_convs.append(rc)
        insts.append((tuple(r_convs), w_conv, sign.get(w, 1)))
    return ParamStridedPlan(window_band=w, plans=tuple(insts))


def _static_extents(pnest: ParamNest) -> dict[int, int]:
    """Bands whose extents are parameter-free: candidates for window
    vectorization (the independent template's ``programs`` axis)."""
    out = {}
    for b, e in enumerate(pnest.band_extents):
        v = _const_int(e)
        if v is not None and v > 0:
            out[b] = v
    return out


def _vector_bands(splan: ParamStridedPlan, static_ext: Mapping[int, int],
                  ) -> tuple[int, ...]:
    """Static-extent bands every instance's write references: these are
    folded into the window shape instead of the chunk loop (all their
    points execute per step, so the write must cover them — a band the
    write ignores must stay serial for last-value-wins semantics)."""
    vec = set(static_ext)
    for _, wacc, _ in splan.plans:
        vec &= {b for b, _, _ in wacc if b >= 0}
    return tuple(sorted(vec))


def param_window_bands(pnest: ParamNest,
                       splan: ParamStridedPlan) -> tuple[int, ...]:
    """Ordered (outer -> inner) window-band candidates of the strided
    regime: every *dynamic*-extent band that the write of every instance
    references — the dims an N-D dynamic window may span — always ending
    with the innermost lane band. Dynamic bands the write ignores must
    stay serial loop bands (a window over them would collapse their
    last-value-wins writes), and static-extent bands are vectorized into
    the window shape instead (see :func:`_vector_bands`)."""
    static = _static_extents(pnest)
    cands = set(range(pnest.n_bands)) - set(static)
    for _, wacc, _ in splan.plans:
        cands &= {b for b, _, _ in wacc if b >= 0}
    cands.add(splan.window_band)
    return tuple(sorted(cands))


def _window_chunks(pnest: ParamNest, splan: ParamStridedPlan,
                   cap_env: Mapping[str, int], chunk,
                   ) -> tuple[tuple[int, ...], dict[int, int]]:
    """Normalize a window spec into ``(window bands, {band: chunk})``.

    An int is the legacy rank-1 form: the lane band alone is windowed
    (clamped to the capacity extent) and every other dynamic band loops.
    A ``((band, C), ...)`` tuple is the explicit N-D geometry the ladder
    policy (:func:`param_strided_window`) resolved — pairs in band
    order, ending with the lane band. All three window consumers (the
    jax emitter, the numpy mirror, the bounds check) normalize through
    here, so their geometry can never drift apart.
    """
    w = splan.window_band
    if isinstance(chunk, (tuple, list)):
        bands = tuple(int(b) for b, _ in chunk)
        if not bands or bands[-1] != w or list(bands) != sorted(set(bands)):
            raise ValueError(
                f"window spec {tuple(chunk)!r} must list distinct "
                f"(band, chunk) pairs in band order ending with the lane "
                f"band {w}"
            )
        return bands, {int(b): max(1, int(c)) for b, c in chunk}
    cap_ext_w = max(1, pnest.band_extents[w].eval(cap_env))
    return (w,), {w: int(min(chunk, cap_ext_w))}


class _WindowPlan:
    """Shared window geometry for the jax emitter and its numpy mirror.

    Splits bands into ``wins`` — the window bands (dynamic extents,
    chunked; the innermost lane band ``w`` always, plus any outer
    dynamic bands an N-D spec promotes) — ``vec`` bands (static extents,
    vectorized into each window) and ``loop`` bands (everything else —
    one point per chunk step). ``spec(rows, ws, ob)`` computes per-dim
    dynamic-slice starts/sizes plus the static lane selector and per-dim
    band tags for one access, with ``ws`` mapping each window band to
    its traced start.
    """

    def __init__(self, pnest: ParamNest, splan: ParamStridedPlan,
                 wins: tuple[int, ...], chunks: Mapping[int, int]):
        self.w = splan.window_band
        self.wins = tuple(wins)
        self.Cs = {int(b): int(chunks[b]) for b in wins}
        self.C = self.Cs[self.w]
        self.static_ext = _static_extents(pnest)
        self.vec = tuple(
            b for b in _vector_bands(splan, self.static_ext)
            if b not in self.Cs
        )
        self.loop = tuple(
            b for b in range(pnest.n_bands)
            if b not in self.Cs and b not in self.vec
        )

    def lane_extent(self, b: int) -> int:
        return self.Cs[b] if b in self.Cs else self.static_ext[b]

    def spec(self, rows, ws, ob):
        """(starts, sizes, selector, per-dim band-or-None) for one access
        at window starts ``ws`` (band -> start) / loop-band coords ``ob``."""
        starts, sizes, sel, axes = [], [], [], []
        for b, cf, kc in rows:
            if b in self.Cs or b in self.vec:
                e = self.lane_extent(b)
                base = ws[b] if b in self.Cs else 0
                if cf > 0:
                    starts.append(cf * base + kc)
                else:
                    starts.append(cf * (base + (e - 1)) + kc)
                sizes.append((e - 1) * abs(cf) + 1)
                sel.append(slice(None, None, cf))
                axes.append(b)
            elif b >= 0:
                starts.append(cf * ob[b] + kc)
                sizes.append(1)
                sel.append(slice(None))
                axes.append(None)
            else:
                starts.append(kc)
                sizes.append(1)
                sel.append(slice(None))
                axes.append(None)
        return starts, sizes, tuple(sel), axes

    def align(self, waxes):
        """Return ``fit(v, raxes)`` mapping a read's lane value onto the
        write's dim layout: banded axes permuted into the write's band
        order, point axes squeezed, missing bands broadcast as size 1."""
        worder = [b for b in waxes if b is not None]
        wshape_of = {b: self.lane_extent(b) for b in worder}

        def fit(xp, v, raxes):
            perm = [d for b in worder for d, rb in enumerate(raxes)
                    if rb == b]
            perm += [d for d, rb in enumerate(raxes) if rb is None]
            if perm != list(range(len(raxes))):
                v = xp.transpose(v, tuple(perm))
            have = {rb for rb in raxes if rb is not None}
            tshape = tuple(
                wshape_of[b] if (b is not None and b in have) else 1
                for b in waxes
            )
            return v.reshape(tshape)

        return fit


def _read_hulls(stmt, racc_sym):
    """Group an instance's reads into per-space *hull* windows.

    Stencil statements read the same space at several constant offsets
    (``B[i-1], B[i], B[i+1]``). Slicing each one dynamically costs a
    materialized temporary per read; the specialized path instead takes
    static slices of one array, which XLA fuses. The hull is the
    parametric analogue: reads that agree on ``(band, stride)`` per dim
    and differ only by *constant* index offsets share one dynamic slice
    of their union span (the halo'd window), and each member becomes a
    static subslice of the hull — same elements, same values, one
    dynamic op per space.

    Returns ``[(space, hull_rows, spans, members), ...]`` where
    ``hull_rows`` are symbolic ``(band, stride, const)`` rows at the
    hull's minimal offset, ``spans[d]`` is the extra static extent the
    union adds per dim, and ``members`` maps each original read index to
    its static offsets inside the hull.
    """
    groups: list[dict] = []
    for ridx, (acc, rows) in enumerate(zip(stmt.reads, racc_sym)):
        placed = False
        for g in groups:
            if g["space"] != acc.space or len(g["rows"]) != len(rows):
                continue
            deltas = []
            for (b0, cf0, k0), (b, cf, kc) in zip(g["rows"], rows):
                if b != b0 or cf != cf0:
                    deltas = None
                    break
                dv = _const_int(Affine.of(kc - k0))
                if dv is None:
                    deltas = None
                    break
                deltas.append(dv)
            if deltas is not None:
                g["members"].append((ridx, tuple(deltas)))
                placed = True
                break
        if not placed:
            groups.append({
                "space": acc.space,
                "rows": tuple(rows),
                "members": [(ridx, (0,) * len(rows))],
            })
    out = []
    for g in groups:
        rank = len(g["rows"])
        lo = [min(d[i] for _, d in g["members"]) for i in range(rank)]
        hi = [max(d[i] for _, d in g["members"]) for i in range(rank)]
        hull_rows = tuple(
            (b, cf, kc + l) for (b, cf, kc), l in zip(g["rows"], lo)
        )
        spans = tuple(h - l for l, h in zip(lo, hi))
        members = tuple(
            (ridx, tuple(d - l for d, l in zip(deltas, lo)))
            for ridx, deltas in g["members"]
        )
        out.append((g["space"], hull_rows, spans, members))
    return out


def param_strided_window(
    pnest: ParamNest, splan: ParamStridedPlan,
    envs: "list[Mapping[str, int]]", cap_env: Mapping[str, int],
    chunk: int = _PARAM_CHUNK, floor: int = 1024,
) -> "tuple[int | tuple, bool]":
    """The ladder-level window policy: ``(window_spec, assume_full)``.

    Rank 1 (the lane band is the only windowable dynamic band): the
    PR-4 policy — when the smallest rung's window extent is at least
    ``floor`` lanes, the chunk is clamped down to it, so every chunk of
    every rung is provably full and the emitter skips masks and blend
    reads entirely (the hot mode); ladders with tinier rungs take the
    masked emission mode instead.  Masked mode gets a second clamp
    tier: the lane chunk is bounded by ``max(floor, smallest rung
    extent)`` rather than the capacity extent, so the per-chunk masked
    work scales with the rung being measured (the runtime trip count
    ``ceil(extent / chunk)`` does the rest) instead of every rung
    paying a capacity-sized blend.  The spec stays a plain int.

    Rank >= 2 (outer dynamic bands the write references — stencil
    nests): the spec is a ``((band, C), ...)`` tuple. Outer window
    bands are clamped to the ladder's smallest rung extent, so their
    windows are provably full at every declared env (min-start overlap,
    never a mask; an outer band some rung zeroes out is left as a loop
    band). The lane band joins the mask-free mode when the smallest
    rung's whole window — window-band chunks times vectorized static
    extents — carries at least ``floor`` points (an N-D window is big
    even when each per-band chunk is small); otherwise it takes the
    sign-anchored masked emission with the same second-tier lane clamp
    (``max(floor, smallest rung extent)``, never the capacity). The
    ``chunk`` budget bounds the window's total dynamic-lane count,
    distributed innermost-first.
    """
    w = splan.window_band
    cap_scope = {k: int(v) for k, v in cap_env.items()}
    scopes = [{**cap_scope, **{k: int(v) for k, v in e.items()}}
              for e in envs]
    bands = param_window_bands(pnest, splan)
    m = {
        b: (min(max(0, pnest.band_extents[b].eval(s)) for s in scopes)
            if scopes else 0)
        for b in bands
    }
    cap_ext_w = max(1, pnest.band_extents[w].eval(cap_env))
    outer = [b for b in bands[:-1] if m[b] >= 1]
    masked_cw = int(min(chunk, cap_ext_w, max(floor, m[w])))
    if not outer:
        if m[w] >= floor:
            return int(min(chunk, m[w], cap_ext_w)), True
        return masked_cw, False
    static_ext = _static_extents(pnest)
    lanes = max(0, m[w])
    for b in outer:
        lanes *= m[b]
    for b in _vector_bands(splan, static_ext):
        if b not in bands:
            lanes *= static_ext[b]
    full = lanes >= floor and m[w] >= 1
    cw = int(min(chunk, m[w], cap_ext_w)) if full else masked_cw
    spec = [(w, max(1, cw))]
    used = max(1, cw)
    for b in reversed(outer):
        cb = int(max(1, min(m[b], chunk // used)))
        spec.append((b, cb))
        used *= cb
    return tuple(sorted(spec)), full


def param_strided_in_bounds(
    pattern: PatternSpec, pnest: ParamNest, splan: ParamStridedPlan,
    env: Mapping[str, int], cap_env: Mapping[str, int],
    chunk: "int | tuple" = _PARAM_CHUNK,
) -> bool:
    """Exact check that every window the strided step could slice at
    ``env`` stays inside the capacity-allocated shapes.

    ``lax.dynamic_slice`` silently clamps out-of-range starts, which
    would *misalign* a window rather than fail — so drivers verify every
    ladder point here before choosing the strided regime, and any unsafe
    env demotes its whole ladder to the gather regime. ``chunk`` is the
    resolved window spec (int or N-D tuple — see
    :func:`_window_chunks`); for N-D specs every window band's anchor
    range is checked, including the negative start an outer band smaller
    than its chunk would take. Real patterns (spans scaling with the
    working set) always pass; the check guards hand-built specs with
    fixed-size spaces and mis-sized ladders.
    """
    stmt = pattern.statement
    w = splan.window_band
    scope = {**{k: int(v) for k, v in cap_env.items()},
             **{k: int(v) for k, v in env.items()}}
    try:
        ext = [max(0, e.eval(scope)) for e in pnest.band_extents]
    except (KeyError, ValueError):
        return False
    wins, Cs = _window_chunks(pnest, splan, cap_env, chunk)
    if any(ext[b] < 1 for b in wins):
        return True  # a zero-extent window band: the trip count is 0
    static_ext = _static_extents(pnest)
    shapes = {s.name: s.concrete_shape(cap_env) for s in pattern.spaces}
    for racc, wacc, s_w in splan.plans:
        anchors = {}
        for b in wins:
            C = Cs[b]
            if ext[b] >= C:
                anchors[b] = (0, ext[b] - 1)
            elif b == w:
                # lane partial-window anchor: [0, C) ascending,
                # [ext-C, ext) descending
                anchors[b] = ((0, C - 1) if s_w > 0
                              else (ext[b] - C, ext[b] - 1))
            else:
                # outer windows are always full-anchored: a rung smaller
                # than its chunk starts at ext-C < 0 (and is demoted)
                anchors[b] = (ext[b] - C, ext[b] - 1)
        for acc, rows in zip((*stmt.reads, stmt.write), (*racc, wacc)):
            dims = shapes[acc.space]
            for d, (b, cf, kc) in enumerate(rows):
                try:
                    k = kc.eval(scope)
                except (KeyError, ValueError):
                    return False
                if b in anchors:
                    lo, hi = anchors[b]
                elif b in static_ext:
                    lo, hi = 0, static_ext[b] - 1
                elif b >= 0:
                    lo, hi = 0, max(0, ext[b] - 1)
                else:
                    lo = hi = 0
                pts = (k + cf * lo, k + cf * hi)
                if min(pts) < 0 or max(pts) > dims[d] - 1:
                    return False
    return True


def _lower_param_strided(pattern: PatternSpec, pnest: ParamNest,
                         splan: ParamStridedPlan,
                         params: tuple[str, ...],
                         cap_env: Mapping[str, int], chunk,
                         assume_full: bool = False) -> Callable:
    """Emit the windowed step: same calling convention as the gather
    parametric step (capacity-shaped arrays + traced param scalars).

    ``assume_full=True`` emits the mask-free hot mode; the caller must
    only invoke the step at envs whose window extent is >= the chunk
    (drivers guarantee this via :func:`param_strided_window`).
    """
    stmt = pattern.statement
    w = splan.window_band
    wins, Cs = _window_chunks(pnest, splan, cap_env, chunk)
    C = Cs[w]
    rest_env = {k: int(v) for k, v in cap_env.items() if k not in params}
    wp = _WindowPlan(pnest, splan, wins, Cs)
    outer_wins = wins[:-1]
    # per instance: reads fused into per-space hull windows (one dynamic
    # slice per space, static subslices per stencil offset — see
    # _read_hulls), resolved symbolically once at lower time
    grouped = [
        (_read_hulls(stmt, racc), wacc, s_w)
        for racc, wacc, s_w in splan.plans
    ]

    def step(arrays: dict[str, jnp.ndarray], pvals) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        scope = {p: jnp.asarray(v, jnp.int32) for p, v in zip(params, pvals)}
        cenv = {**rest_env, **scope}
        ext = [jnp.maximum(_affine_traced(e, scope), 0)
               for e in pnest.band_extents]
        ext_w = ext[w]
        nw = {b: (ext[b] + (Cs[b] - 1)) // Cs[b] for b in wins}
        win_lo = {b: ext[b] - Cs[b] for b in wins}
        # mixed-radix trip space: serial loop bands outermost, window
        # bands (outer -> inner) innermost, so the lane band varies
        # fastest — identical decomposition to the numpy mirror
        radii = [(b, ext[b]) for b in wp.loop] + [(b, nw[b]) for b in wins]
        strides = {}
        total = jnp.int32(1)
        for b, r in reversed(radii):
            strides[b] = total
            total = total * r
        # loop-invariant traced offsets, computed once outside the body
        tr = [
            (
                [
                    (space,
                     [(b, cf, _affine_traced(kc, scope))
                      for b, cf, kc in hull_rows],
                     spans, members)
                    for space, hull_rows, spans, members in groups
                ],
                [(b, cf, _affine_traced(kc, scope)) for b, cf, kc in wacc],
                s_w,
            )
            for groups, wacc, s_w in grouped
        ]
        lane = (None if assume_full
                else jax.lax.broadcasted_iota(jnp.int32, (C,), 0))

        def instance(arrs, groups, wacc, ws, ob, valid):
            """One instance's window step at window starts ``ws`` (band
            -> start); lanes where ``valid`` is False (masked lane mode
            only) keep the target's current contents."""
            wstarts, wsizes, wsel, waxes = wp.spec(wacc, ws, ob)
            fit = wp.align(waxes)
            vals: list = [None] * len(stmt.reads)
            for space, hull_rows, spans, members in groups:
                starts, sizes, sel, raxes = wp.spec(hull_rows, ws, ob)
                hsizes = [s + sp for s, sp in zip(sizes, spans)]
                hull = jax.lax.dynamic_slice(arrs[space], starts, hsizes)
                for ridx, offs in members:
                    sub = hull[tuple(
                        slice(o, o + s) for o, s in zip(offs, sizes)
                    )]
                    vals[ridx] = fit(jnp, sub[sel], raxes)
            res = stmt.combine(vals, cenv)
            tgt = arrs[stmt.write.space]
            lanes = tuple(
                wp.lane_extent(b) if b is not None else 1 for b in waxes
            )
            res = jnp.broadcast_to(jnp.asarray(res).astype(tgt.dtype), lanes)
            if valid is None and all(cf == 1 for b, cf, _ in wacc if b >= 0):
                return jax.lax.dynamic_update_slice(tgt, res, wstarts)
            # strided / reversed / masked write: blend into the window
            # so gap elements and invalid lanes stay untouched
            win = jax.lax.dynamic_slice(tgt, wstarts, wsizes)
            if valid is not None:
                vshape = tuple(C if b == w else 1 for b in waxes)
                res = jnp.where(valid.reshape(vshape), res, win[wsel])
            win = win.at[wsel].set(res)
            return jax.lax.dynamic_update_slice(tgt, win, wstarts)

        def body(ci, arrs):
            arrs = dict(arrs)
            idx = {b: (ci // strides[b]) % r for b, r in radii}
            ob = {b: idx[b] for b in wp.loop}
            # outer window bands always take full windows: their chunks
            # are clamped to the ladder's smallest rung, so the min-
            # start overlap keeps every slice in bounds with no masks
            ws0 = {b: jnp.minimum(idx[b] * Cs[b], win_lo[b])
                   for b in outer_wins}
            wsq = idx[w] * C
            for groups, wacc, s_w in tr:
                if assume_full:
                    # every lane chunk is a full window too: min-start
                    # overlap, no masks (caller guarantees ext_w >= C)
                    ws = dict(ws0)
                    ws[w] = jnp.minimum(wsq, win_lo[w])
                    arrs[stmt.write.space] = instance(
                        arrs, groups, wacc, ws, ob, None)
                    continue
                # sign-aware lane anchor: ascending accesses floor the
                # start at 0, descending ones let it go negative so the
                # partial window sits at [ext-C, ext) — either way slice
                # starts stay at valid positions
                wsl = jnp.minimum(wsq, win_lo[w])
                if s_w > 0:
                    wsl = jnp.maximum(wsl, 0)
                band = wsl + lane
                valid = (band >= 0) & (band < ext_w)
                ws = dict(ws0)
                ws[w] = wsl
                arrs[stmt.write.space] = instance(
                    arrs, groups, wacc, ws, ob, valid)
            return arrs

        return jax.lax.fori_loop(0, total, body, arrays)

    step.param_window_rank = len(wins)
    return step


def windowed_oracle(
    pattern: PatternSpec, schedule: Schedule, env: Mapping[str, int],
    cap_env: Mapping[str, int], arrays: dict[str, np.ndarray],
    ntimes: int = 1, *, params: tuple[str, ...] = ("n",),
    chunk: "int | tuple" = _PARAM_CHUNK, assume_full: bool = False,
) -> dict[str, np.ndarray]:
    """Numpy mirror of the parametric strided regime, window for window.

    Replays the exact chunk decomposition (vectorized static bands,
    N-D window boxes with per-band min-start overlap, sign-aware
    partial-window anchors, strided subsampling, blend writes, tail-lane
    masking) on capacity-shaped numpy arrays, so tests can prove the
    window arithmetic against plain semantics — bit-for-bit against the
    jax step over the *whole* capacity arrays, not just the [0, n)
    region — without tracing. ``chunk`` accepts the same int / N-D
    ``((band, C), ...)`` window specs as the jax emitter and mirrors
    whichever geometry it names. Raises when (pattern, schedule) is not
    strided-eligible.
    """
    pnest = schedule.lower_symbolic(pattern.domain, tuple(params))
    splan = param_strided_plan(pattern, pnest)
    if splan is None:
        raise ValueError(
            f"pattern {pattern.name!r} / schedule {schedule.name!r} is not "
            "strided-eligible; the windowed mirror has nothing to replay"
        )
    stmt = pattern.statement
    w = splan.window_band
    scope = {**{k: int(v) for k, v in cap_env.items()
                if k not in params},
             **{p: int(env[p]) for p in params}}
    ext = [max(0, e.eval(scope)) for e in pnest.band_extents]
    ext_w = ext[w]
    wins, Cs = _window_chunks(pnest, splan, cap_env, chunk)
    C = Cs[w]
    wp = _WindowPlan(pnest, splan, wins, Cs)
    outer_wins = wins[:-1]
    nw = {b: -(-ext[b] // Cs[b]) if ext[b] else 0 for b in wins}
    radii = [(b, ext[b]) for b in wp.loop] + [(b, nw[b]) for b in wins]
    strides = {}
    total = 1
    for b, r in reversed(radii):
        strides[b] = total
        total = total * int(r)
    arrays = {k: np.array(v) for k, v in arrays.items()}
    plans = [
        (
            [[(b, cf, kc.eval(scope)) for b, cf, kc in rows] for rows in racc],
            [(b, cf, kc.eval(scope)) for b, cf, kc in wacc],
            s_w,
        )
        for racc, wacc, s_w in splan.plans
    ]
    for _ in range(int(ntimes)):
        for ci in range(int(total)):
            idx = {b: (ci // strides[b]) % int(r) for b, r in radii}
            ob = {b: idx[b] for b in wp.loop}
            ws0 = {b: min(idx[b] * Cs[b], ext[b] - Cs[b])
                   for b in outer_wins}
            wsq = idx[w] * C
            for racc, wacc, s_w in plans:
                ws = dict(ws0)
                if assume_full:
                    ws[w], valid = min(wsq, ext_w - C), None
                else:
                    wsl = min(wsq, ext_w - C)
                    if s_w > 0:
                        wsl = max(wsl, 0)
                    ws[w] = wsl
                    band = wsl + np.arange(C)
                    valid = (band >= 0) & (band < ext_w)
                wstarts, wsizes, wsel, waxes = wp.spec(wacc, ws, ob)
                fit = wp.align(waxes)
                vals = []
                for acc, rows in zip(stmt.reads, racc):
                    starts, sizes, sel, raxes = wp.spec(rows, ws, ob)
                    win = arrays[acc.space][tuple(
                        slice(s, s + z) for s, z in zip(starts, sizes))]
                    vals.append(fit(np, np.asarray(win[sel]), raxes))
                res = stmt.combine(vals, dict(scope))
                tgt = arrays[stmt.write.space]
                lanes = tuple(
                    wp.lane_extent(b) if b is not None else 1 for b in waxes
                )
                res = np.broadcast_to(
                    np.asarray(res).astype(tgt.dtype), lanes)
                osel = tuple(
                    slice(s, s + z) for s, z in zip(wstarts, wsizes))
                win = np.array(tgt[osel])
                if valid is not None:
                    vshape = tuple(C if b == w else 1 for b in waxes)
                    res = np.where(valid.reshape(vshape), res, win[wsel])
                win[wsel] = res
                tgt[osel] = win
    return arrays


def lower_jax_parametric(
    pattern: PatternSpec, schedule: Schedule, cap_env: Mapping[str, int],
    *, params: tuple[str, ...] = ("n",), chunk: "int | tuple" = _PARAM_CHUNK,
    pnest: ParamNest | None = None, param_path: str = "auto",
    assume_full: bool = False,
) -> Callable:
    """Build ``step(arrays, pvals) -> arrays`` with the working-set
    parameter(s) as *traced operands* instead of baked constants.

    One executable serves every working set up to the capacity
    ``cap_env`` (arrays are allocated at capacity shapes): band extents,
    instance maps, and domain bounds are computed inside the trace from
    the ``pvals`` scalars, and points are executed in fixed-shape lane
    chunks under a dynamic trip count (``fori_loop`` over
    ``ceil(points/chunk)``), so the work a call performs scales with the
    *runtime* working set — a ladder shares one compiled program without
    every rung paying capacity-sized sweeps.

    ``param_path`` picks the lowering regime: ``"auto"`` prefers the
    strided fast path (dynamic-slice windows — see
    :func:`param_strided_plan`) and falls back to masked gather/scatter;
    ``"strided"`` requires the fast path (raises
    :class:`~repro.core.schedule.SymbolicLowerError` when ineligible);
    ``"gather"`` pins the masked form (the reference regime the tests
    compare against). The returned step carries the chosen regime as
    ``step.param_path`` and its window dimensionality as
    ``step.param_window_rank`` (0 on the gather path). On the strided
    path, ``chunk`` is either a lane-chunk int (rank-1 windows, outer
    dynamic bands loop serially) or a ``((band, C), ...)`` N-D window
    spec from :func:`param_strided_window` (stencil nests window an
    (i-chunk x j-chunk x ...) box per step). ``assume_full`` selects the
    strided emitter's mask-free hot mode — only valid when every env the
    step will run satisfies ``lane window extent >= lane chunk`` (outer
    N-D window bands are clamped by the ladder policy, so they are
    always full).

    Caller contract of the strided regime: every env the step runs must
    pass :func:`param_strided_in_bounds` — a window that leaves the
    capacity shapes is silently *clamped* by ``lax.dynamic_slice``, i.e.
    misaligned, not an error. ``Driver`` verifies this per ladder before
    choosing the regime; direct users of this function (with patterns
    whose spaces do not scale with the working set) must check it
    themselves or pin ``param_path="gather"``, which is safe at every
    env that ``ParamNest.admits``.

    On the gather path, reads and the write are gather/scatter over the
    chunk lanes; lanes past the dynamic point count (or outside the
    domain, for guarded nests) are masked onto index -1 and dropped,
    mirroring the specialized gather path. Preconditions checked by the
    caller via ``ParamNest.admits``: every requested env must satisfy
    the nest's divisibility constraints.
    """
    from .schedule import SymbolicLowerError

    if param_path not in ("auto", "strided", "gather"):
        raise ValueError(f"unknown param_path {param_path!r}")
    if pattern.kernel is not None:
        raise SymbolicLowerError(
            f"pattern {pattern.name!r} has a custom kernel; the parametric "
            "path cannot share it (env is baked into the step)"
        )
    if pnest is None:
        pnest = schedule.lower_symbolic(pattern.domain, params)
    splan = (param_strided_plan(pattern, pnest)
             if param_path != "gather" else None)
    if param_path == "strided" and splan is None:
        raise SymbolicLowerError(
            f"pattern {pattern.name!r} under schedule {schedule.name!r} is "
            "not strided-eligible (single-band constant-stride unguarded "
            "nests only); use param_path='auto' to fall back to gather"
        )
    if splan is not None:
        step = _lower_param_strided(
            pattern, pnest, splan, tuple(params), cap_env, chunk,
            assume_full=assume_full,
        )
        step.param_path = "strided"
        return step
    if not isinstance(chunk, int):
        # an N-D window spec only means something to the strided
        # emitter; the gather fallback keeps its default lane chunk
        chunk = _PARAM_CHUNK
    stmt = pattern.statement
    iter_names = pattern.domain.names
    plans = tuple(
        (
            tuple(
                resolve_access_symbolic(a, pnest, inst, iter_names)
                for a in stmt.reads
            ),
            resolve_access_symbolic(stmt.write, pnest, inst, iter_names),
        )
        for inst in pnest.instances
    )
    n_bands = pnest.n_bands
    rank = pnest.rank
    cap_extents = tuple(max(0, e.eval(cap_env)) for e in pnest.band_extents)
    cap_pts = int(np.prod(cap_extents)) if cap_extents else 1
    if cap_pts > _GATHER_POINT_CAP:
        raise ValueError(
            f"parametric path would stage {cap_pts} capacity points; "
            "use lower_pallas"
        )
    C = int(min(chunk, max(1, cap_pts)))
    rest_env = {k: int(v) for k, v in cap_env.items() if k not in params}

    def step(arrays: dict[str, jnp.ndarray], pvals) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        scope = {p: jnp.asarray(v, jnp.int32) for p, v in zip(params, pvals)}
        cenv = {**rest_env, **scope}

        ext = [jnp.maximum(_affine_traced(e, scope), 0)
               for e in pnest.band_extents]
        strides = [None] * n_bands
        s = jnp.int32(1)
        for b in reversed(range(n_bands)):
            strides[b] = s
            s = s * ext[b]
        npts = s if n_bands else jnp.int32(1)
        nchunks = (npts + (C - 1)) // C
        lane0 = jax.lax.broadcasted_iota(jnp.int32, (C,), 0)
        lo = [_affine_traced(l, scope) for l in pnest.domain_lo]
        hi = [_affine_traced(h, scope) for h in pnest.domain_hi]
        # loop-invariant scalar coefficients, computed once outside the body
        tr_plans = [
            (
                [
                    [
                        ([_affine_traced(cf, scope) for cf in row],
                         _affine_traced(const, scope))
                        for row, const in rows
                    ]
                    for rows in racc
                ],
                [
                    ([_affine_traced(cf, scope) for cf in row],
                     _affine_traced(const, scope))
                    for row, const in wacc
                ],
                [
                    ([_affine_traced(cf, scope) for cf in inst.A[d]],
                     _affine_traced(inst.c[d], scope))
                    for d in range(rank)
                ],
            )
            for (racc, wacc), inst in zip(plans, pnest.instances)
        ]

        def body(ci, arrs):
            arrs = dict(arrs)
            lanes = ci * C + lane0
            valid0 = lanes < npts
            cols = [(lanes // strides[b]) % ext[b] for b in range(n_bands)]

            def lin(coeffs, const):
                acc = jnp.full((C,), 1, jnp.int32) * const
                for b, cf in enumerate(coeffs):
                    acc = acc + cf * cols[b]
                return acc

            for racc, wacc, imap in tr_plans:
                valid = valid0
                for d in range(rank):
                    it = lin(*imap[d])
                    valid = valid & (it >= lo[d]) & (it < hi[d])
                vals = [
                    arrs[acc.space][tuple(lin(*rc) for rc in rows)]
                    for acc, rows in zip(stmt.reads, racc)
                ]
                res = stmt.combine(vals, cenv)
                tgt = arrs[stmt.write.space]
                widx = tuple(
                    jnp.where(valid, lin(*rc), -1) for rc in wacc
                )
                arrs[stmt.write.space] = tgt.at[widx].set(
                    jnp.asarray(res).astype(tgt.dtype), mode="drop"
                )
            return arrs

        return jax.lax.fori_loop(0, nchunks, body, arrays)

    step.param_path = "gather"
    step.param_window_rank = 0
    return step


# ---------------------------------------------------------------------------
# Pallas backend (manual-DMA style; blocked showcase kernels in repro.kernels)
# ---------------------------------------------------------------------------


_PALLAS_MODE: dict[str, str] = {}


def pallas_platform_mode() -> str:
    """Probe-once resolution of how ``pl.pallas_call`` executes here.

    Returns ``"compiled"`` when the default jax backend lowers and runs
    a trivial pallas kernel natively (TPU/GPU), ``"interpret"`` when
    only the interpreter is available (XLA:CPU refuses
    ``interpret=False``). Memoized per process: translation-cache keys,
    journal fingerprints, and every measurement record embed the result
    (``extra.pallas_mode``), so artifacts measured under one mode are
    never replayed as the other's on a different platform.
    """
    mode = _PALLAS_MODE.get("mode")
    if mode is None:
        def _probe(x_ref, o_ref):
            o_ref[...] = x_ref[...] + 1.0

        try:
            call = pl.pallas_call(
                _probe,
                out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
                interpret=False,
            )
            jax.block_until_ready(jax.jit(call)(jnp.zeros((8,), jnp.float32)))
            mode = "compiled"
        except Exception:  # any refusal to lower natively means interpret
            mode = "interpret"
        _PALLAS_MODE["mode"] = mode
    return mode


def _resolve_pallas_mode(mode: str | None) -> str:
    if mode in ("compiled", "interpret"):
        return mode
    return pallas_platform_mode()


def lower_pallas(
    pattern: PatternSpec, schedule: Schedule, env: Mapping[str, int],
    *, mode: str | None = None, interpret: bool | None = None,
    grid_bands: tuple[str, ...] | None = None,
    plan: NestPlan | None = None,
) -> Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]:
    """Lower to ``pl.pallas_call``.

    Bands are split into *grid bands* (pallas grid) and *vector bands*
    (in-kernel slice extents). By default the innermost unit-stride band
    of each domain dim is the vector band; ``grid_bands`` forces named
    bands into the grid (used by the tile-sweep benchmarks so tile loops
    become grid steps, exactly like the generated ISCC tile loops).
    The output space is aliased to its input so un-iterated elements
    (stencil borders) keep their initial values, matching the oracle.

    ``mode`` selects ``"compiled"`` (native ``pl.pallas_call`` lowering)
    or ``"interpret"``; ``None`` auto-resolves via
    :func:`pallas_platform_mode` so capable platforms run compiled and
    XLA:CPU falls back to the interpreter. The legacy ``interpret`` bool
    overrides ``mode`` when given. The built step reports the resolved
    mode as ``step.pallas_mode``.

    Refusals (custom kernels, guarded schedules) raise
    :class:`~repro.core.errors.LowerFailure` with structured context
    naming the backend and reason, so sweep ``FailureRecord``s classify
    them instead of carrying a bare exception string.
    """
    if interpret is not None:  # legacy kwarg: explicit mode override
        mode = "interpret" if interpret else "compiled"
    mode = _resolve_pallas_mode(mode)
    if pattern.kernel is not None:
        raise LowerFailure(
            f"pattern {pattern.name!r} has a custom (jax) kernel; "
            "the pallas backend cannot lower it",
            context={"backend": "pallas", "reason": "custom_kernel"},
        )
    if plan is None:
        plan = plan_nest(pattern, schedule, env)
    nest = plan.nest
    if plan.guarded:
        raise LowerFailure(
            "guarded schedules on the pallas backend: pick divisible tile "
            "sizes (the drivers choose divisible working sets)",
            context={"backend": "pallas", "reason": "guarded_schedule"},
        )
    stmt = pattern.statement
    rank = nest.rank

    inst0 = nest.instances[0]
    vec_band_for_dim: list[int] = []
    for d in range(rank):
        cands = [b for b, c in enumerate(inst0.A[d]) if abs(c) == 1]
        if not cands:
            raise LowerFailure(
                f"dim {d} has no unit-stride band; cannot vectorize",
                context={"backend": "pallas", "reason": "no_unit_stride"},
            )
        vec_band_for_dim.append(max(cands))
    vec_bands = sorted(set(vec_band_for_dim))
    if grid_bands is not None:
        vec_bands = [b for b in vec_bands if nest.band_names[b] not in grid_bands]
    gbs = [b for b in range(nest.n_bands) if b not in vec_bands]
    for inst in nest.instances:
        for d in range(rank):
            for b in vec_bands:
                if inst.A[d][b] not in (-1, 0, 1):
                    raise LowerFailure(
                        "vector band with non-unit stride",
                        context={"backend": "pallas",
                                 "reason": "non_unit_vector_stride"},
                    )

    grid = tuple(nest.band_extents[b] for b in gbs) or (1,)
    vec_extents = {b: nest.band_extents[b] for b in vec_bands}

    acc_plans = plan.plans
    if not plan.signs_ok:
        raise LowerFailure(
            "mixed coefficient signs per band; not vectorizable",
            context={"backend": "pallas", "reason": "mixed_signs"},
        )
    # Accesses, not just nest bands, must be unit-stride along the
    # vector bands: the kernel reads/writes each access through a
    # contiguous ``pl.ds`` window, so a coefficient like the 4 in
    # ``S[4*i]`` would silently alias the wrong contiguous elements
    # (the jax emitter gathers these; pallas refuses -> the sweep
    # engine's ``pallas->jax`` rung picks them up structurally).
    for racc, wacc in acc_plans:
        for rows_const in list(racc) + [wacc]:
            for row, _const in rows_const:
                for b in vec_bands:
                    if row[b] not in (-1, 0, 1):
                        raise LowerFailure(
                            f"access coefficient {row[b]} on the vector band "
                            "is not unit-stride; a contiguous pallas window "
                            "cannot express it",
                            context={"backend": "pallas",
                                     "reason": "strided_access"},
                        )

    space_order = [s.name for s in pattern.spaces]
    out_name = stmt.write.space
    out_pos = space_order.index(out_name)
    shapes = {s.name: s.concrete_shape(env) for s in pattern.spaces}
    dtypes = {s.name: s.dtype for s in pattern.spaces}
    env_dict = dict(env)

    def kernel(*refs):
        in_refs = {nm: r for nm, r in zip(space_order, refs[:len(space_order)])}
        out_ref = refs[len(space_order)]
        gvals = [pl.program_id(i) for i in range(len(gbs))] if gbs else []

        def base_of(rows_const):
            """(base index at vector-band==0/origin, vector band per dim)."""
            base, vb = [], []
            for row, const in rows_const:
                off = const
                for gi, b in enumerate(gbs):
                    off = off + row[b] * gvals[gi]
                bsel, bstep = -1, 1
                for b in vec_bands:
                    if row[b] != 0:
                        bsel, bstep = b, row[b]
                if bsel >= 0 and bstep == -1:
                    # ascending-index window: [off - (e-1), off]
                    off = off - (vec_extents[bsel] - 1)
                base.append(off)
                vb.append(bsel)
            return base, vb

        for racc, wacc in acc_plans:
            wbase, wvb = base_of(wacc)
            vals = []
            for acc, rows in zip(stmt.reads, racc):
                base, vb = base_of(rows)
                idx = tuple(
                    pl.ds(b0, vec_extents[bsel] if bsel >= 0 else 1)
                    for b0, bsel in zip(base, vb)
                )
                v = in_refs[acc.space][idx]
                perm = _axis_perm(vb, wvb)
                if perm is not None:
                    v = jnp.transpose(v, perm)
                vals.append(v)
            res = stmt.combine(vals, env_dict)
            want = tuple(1 if b < 0 else vec_extents[b] for b in wvb)
            res = jnp.asarray(res).astype(out_ref.dtype)
            if res.shape != want:
                res = jnp.broadcast_to(res, want)
            widx = tuple(
                pl.ds(b0, vec_extents[bsel] if bsel >= 0 else 1)
                for b0, bsel in zip(wbase, wvb)
            )
            out_ref[widx] = res

    call = pl.pallas_call(
        kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct(shapes[out_name], dtypes[out_name]),
        input_output_aliases={out_pos: 0},
        interpret=(mode == "interpret"),
    )

    def step(arrays: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        arrays[out_name] = call(*[arrays[nm] for nm in space_order])
        return arrays

    step.pallas_mode = mode
    return step


def lower_pallas_parametric(
    pattern: PatternSpec, schedule: Schedule, cap_env: Mapping[str, int],
    *, params: tuple[str, ...] = ("n",), chunk: "int | tuple" = _PARAM_CHUNK,
    pnest: ParamNest | None = None, assume_full: bool = False,
    mode: str | None = None,
) -> Callable:
    """Grid-mapped twin of the strided parametric jax emitter.

    Builds ``step(arrays, pvals) -> arrays`` with the working-set
    parameter(s) as traced operands, exactly like
    :func:`lower_jax_parametric`'s strided path — same window geometry
    (:func:`_window_chunks` / :class:`_WindowPlan` / :func:`_read_hulls`),
    same caller contract (:func:`param_strided_in_bounds` per env) —
    but the mixed-radix trip space becomes the pallas *grid*: serial
    loop bands outermost, window bands (outer -> inner) innermost, one
    N-D ``pl.ds`` window per grid step. The grid is sized at *capacity*
    trip counts; steps past a rung's runtime radix are masked off
    in-kernel (``pl.when``), so one pallas executable serves the whole
    ladder (1 compile miss per ladder).

    Strided regime only: nests that would need the masked gather
    fallback raise :class:`~repro.core.schedule.SymbolicLowerError`, and
    drivers specialize per size instead (pallas has no parametric
    gather emitter).
    """
    from .schedule import SymbolicLowerError

    if pattern.kernel is not None:
        raise SymbolicLowerError(
            f"pattern {pattern.name!r} has a custom kernel; the parametric "
            "path cannot share it (env is baked into the step)"
        )
    if pnest is None:
        pnest = schedule.lower_symbolic(pattern.domain, params)
    splan = param_strided_plan(pattern, pnest)
    if splan is None:
        raise SymbolicLowerError(
            f"pattern {pattern.name!r} under schedule {schedule.name!r} is "
            "not strided-eligible; the pallas parametric path has no gather "
            "fallback — specialize per size instead"
        )
    mode = _resolve_pallas_mode(mode)
    params = tuple(params)
    stmt = pattern.statement
    w = splan.window_band
    wins, Cs = _window_chunks(pnest, splan, cap_env, chunk)
    C = Cs[w]
    rest_env = {k: int(v) for k, v in cap_env.items() if k not in params}
    wp = _WindowPlan(pnest, splan, wins, Cs)
    outer_wins = wins[:-1]
    grouped = [
        (_read_hulls(stmt, racc), wacc, s_w)
        for racc, wacc, s_w in splan.plans
    ]

    cap_scope = {k: int(v) for k, v in cap_env.items()}
    cap_ext = [max(0, e.eval(cap_scope)) for e in pnest.band_extents]
    # Static grid over the *capacity* trip space, loop bands outermost
    # and window bands innermost — pallas iterates the last grid dim
    # fastest, so execution order matches the jax emitter's mixed-radix
    # fori_loop step for step (loop-band writes stay last-value-wins).
    grid_order = list(wp.loop) + list(wins)
    grid = tuple(
        max(1, (cap_ext[b] + Cs[b] - 1) // Cs[b]) if b in Cs
        else max(1, cap_ext[b])
        for b in grid_order
    ) or (1,)

    space_order = [s.name for s in pattern.spaces]
    out_name = stmt.write.space
    out_pos = space_order.index(out_name)
    shapes = {s.name: s.concrete_shape(cap_env) for s in pattern.spaces}
    dtypes = {s.name: s.dtype for s in pattern.spaces}

    def kernel(*refs):
        in_refs = {nm: r for nm, r in zip(space_order, refs)}
        pv_ref = refs[len(space_order)]
        out_ref = refs[len(space_order) + 1]
        scope = {p: pv_ref[i] for i, p in enumerate(params)}
        cenv = {**rest_env, **scope}
        ext = [jnp.maximum(_affine_traced(e, scope), 0)
               for e in pnest.band_extents]
        ext_w = ext[w]
        nw = {b: (ext[b] + (Cs[b] - 1)) // Cs[b] for b in wins}
        win_lo = {b: ext[b] - Cs[b] for b in wins}
        idx = {b: pl.program_id(i) for i, b in enumerate(grid_order)}
        # runtime liveness: the capacity grid over-covers small rungs
        conds = [idx[b] < ext[b] for b in wp.loop]
        conds += [idx[b] < nw[b] for b in wins]
        # loop-invariant traced offsets, computed once per grid step
        tr = [
            (
                [
                    (space,
                     [(b, cf, _affine_traced(kc, scope))
                      for b, cf, kc in hull_rows],
                     spans, members)
                    for space, hull_rows, spans, members in groups
                ],
                [(b, cf, _affine_traced(kc, scope)) for b, cf, kc in wacc],
                s_w,
            )
            for groups, wacc, s_w in grouped
        ]
        lane = (None if assume_full
                else jax.lax.broadcasted_iota(jnp.int32, (C,), 0))

        def instance(groups, wacc, ws, ob, valid):
            """One instance's window step at window starts ``ws``; lanes
            where ``valid`` is False (masked lane mode) keep the target
            ref's current contents."""
            wstarts, wsizes, wsel, waxes = wp.spec(wacc, ws, ob)
            fit = wp.align(waxes)
            vals: list = [None] * len(stmt.reads)
            for space, hull_rows, spans, members in groups:
                starts, sizes, sel, raxes = wp.spec(hull_rows, ws, ob)
                hsizes = [s + sp for s, sp in zip(sizes, spans)]
                hull = in_refs[space][tuple(
                    pl.ds(st, hs) for st, hs in zip(starts, hsizes)
                )]
                for ridx, offs in members:
                    sub = hull[tuple(
                        slice(o, o + s) for o, s in zip(offs, sizes)
                    )]
                    vals[ridx] = fit(jnp, sub[sel], raxes)
            res = stmt.combine(vals, cenv)
            lanes = tuple(
                wp.lane_extent(b) if b is not None else 1 for b in waxes
            )
            res = jnp.broadcast_to(
                jnp.asarray(res).astype(out_ref.dtype), lanes)
            widx = tuple(pl.ds(st, sz) for st, sz in zip(wstarts, wsizes))
            if valid is None and all(cf == 1 for b, cf, _ in wacc if b >= 0):
                out_ref[widx] = res
                return
            # strided / reversed / masked write: blend into the window
            win = out_ref[widx]
            if valid is not None:
                vshape = tuple(C if b == w else 1 for b in waxes)
                res = jnp.where(valid.reshape(vshape), res, win[wsel])
            if all(s.step in (None, 1, -1) for s in wsel):
                # gap-free selector: the set IS the (possibly reversed)
                # value — .at[] with all-unit slices would make jnp build
                # an empty scatter-index constant, which a pallas kernel
                # cannot capture
                out_ref[widx] = res[wsel]
            else:
                out_ref[widx] = win.at[wsel].set(res)

        def body():
            ob = {b: idx[b] for b in wp.loop}
            # outer window bands always take full windows (chunks are
            # clamped to the ladder's smallest rung): min-start overlap
            ws0 = {b: jnp.minimum(idx[b] * Cs[b], win_lo[b])
                   for b in outer_wins}
            wsq = idx[w] * C
            for groups, wacc, s_w in tr:
                if assume_full:
                    ws = dict(ws0)
                    ws[w] = jnp.minimum(wsq, win_lo[w])
                    instance(groups, wacc, ws, ob, None)
                    continue
                # sign-aware lane anchor, identical to the jax emitter
                wsl = jnp.minimum(wsq, win_lo[w])
                if s_w > 0:
                    wsl = jnp.maximum(wsl, 0)
                band = wsl + lane
                valid = (band >= 0) & (band < ext_w)
                ws = dict(ws0)
                ws[w] = wsl
                instance(groups, wacc, ws, ob, valid)

        if conds:
            live = conds[0]
            for c in conds[1:]:
                live = live & c
            pl.when(live)(body)
        else:
            body()

    call = pl.pallas_call(
        kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct(shapes[out_name], dtypes[out_name]),
        input_output_aliases={out_pos: 0},
        interpret=(mode == "interpret"),
    )

    def step(arrays: dict[str, jnp.ndarray], pvals) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        pv = jnp.stack([jnp.asarray(v, jnp.int32) for v in pvals])
        arrays[out_name] = call(*[arrays[nm] for nm in space_order], pv)
        return arrays

    step.param_path = "strided"
    step.param_window_rank = len(wins)
    step.pallas_mode = mode
    return step
