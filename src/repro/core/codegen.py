"""Code generation: lower (PatternSpec, Schedule) to executable JAX.

This is the analogue of ISCC's ``codegen`` call, retargeted at two
backends and split into explicit stages so drivers, sweeps, and the
autotuner can share work through the translation cache (see
``staging.py``):

``plan_nest``
    Stage 0 of the pipeline: lower the schedule against a concrete env
    and resolve every access into per-band ``(coeffs, const)`` rows.
    Plan building is pure Python (no tracing) and is what the staged
    ``Lowered`` artifact memoizes.

``lower_jax``
    Vectorized jax.numpy. Instances whose affine maps use **one band per
    domain dim** (identity, interchange, reverse, interleave, unroll — all
    of the paper's triad-family experiments) lower to static strided-slice
    reads + ``.at[...].set`` writes, which XLA fuses into a single
    streaming loop — the moral equivalent of the paper's generated C.
    General maps (tiling, skew) lower to a gather/scatter form whose
    indices are built *inside* the traced program from
    ``lax.broadcasted_iota`` (never embedded as host constants), so large
    grids stay cheap to trace and compile.

``lower_pallas``
    A Pallas kernel per schedule. Loop bands become the ``grid``; vector
    bands become the block. Refs are *unblocked* (whole array) and the
    kernel issues explicit dynamic slices — on TPU this corresponds to the
    HBM->VMEM manual-DMA style used for halo'd stencils. Blocked-
    ``BlockSpec`` showcase kernels live in ``repro.kernels``. Executed
    with ``interpret=True`` on this CPU container.

``serial_oracle``
    Pure-numpy execution in generated-code order. The ground truth every
    backend is validated against (the paper's ``<kernel>_val.in`` stage).
    Nests whose statement never reads its written space and whose maps
    admit the strided-slice form are executed with vectorized numpy
    slices (provably order-independent there); everything else falls
    back to the point-by-point loop.

Traversal-direction note: slices generated from the same band are paired
elementwise across reads and the write, so negative-coefficient maps
(reverse) need no flips — pairing by band value is automatically
consistent *provided all accesses agree on coefficient sign per band*,
which holds for every Schedule-generated nest (transforms rewrite all
instances uniformly). Hand-built accesses that mix signs fall back to the
gather path (checked).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .domain import Affine
from .pattern import Access, PatternSpec
from .schedule import (
    LoweredInstance,
    LoweredNest,
    ParamInstance,
    ParamNest,
    Schedule,
)

__all__ = [
    "serial_oracle",
    "lower_jax",
    "lower_jax_parametric",
    "lower_pallas",
    "resolve_access",
    "resolve_access_symbolic",
    "plan_nest",
    "NestPlan",
]

# Indices are now built in-program from broadcasted_iota (no host-side
# constants), so the cap only bounds runtime index-array memory.
_GATHER_POINT_CAP = 1 << 26

# Lane-block size of the parametric (shape-polymorphic) path: points are
# executed in fixed-shape chunks under a dynamic trip count, so the work
# a call performs scales with the runtime working set, not the capacity.
_PARAM_CHUNK = 8192


# ---------------------------------------------------------------------------
# Access resolution: Access (affine in iterator names) -> per-dim (row, const)
# over *bands*, by composing with a LoweredInstance.
# ---------------------------------------------------------------------------


def resolve_access(
    acc: Access, nest: LoweredNest, inst: LoweredInstance,
    iter_names: tuple[str, ...], env: Mapping[str, int],
) -> list[tuple[tuple[int, ...], int]]:
    """Compose an access's affine index with an instance's band map.

    Returns, per array dim, ``(coeff_per_band, const)`` such that
    ``array_index = coeff . bands + const``.
    """
    out = []
    pos = {n: i for i, n in enumerate(iter_names)}
    for ix in acc.resolved():
        ix = Affine.of(ix.subs(env))  # fold parameters like n
        row = [0] * nest.n_bands
        const = ix.const
        for sym, c in ix.coeffs:
            if sym not in pos:
                raise KeyError(f"access symbol {sym!r} is not an iterator or param")
            d = pos[sym]
            const += c * inst.c[d]
            for b in range(nest.n_bands):
                row[b] += c * inst.A[d][b]
        out.append((tuple(row), const))
    return out


def _signs_consistent(plans) -> bool:
    """All accesses in each instance agree on coeff sign per band."""
    for racc, wacc in plans:
        sign: dict[int, int] = {}
        for rows in list(racc) + [wacc]:
            for row, _ in rows:
                for b, c in enumerate(row):
                    if c == 0:
                        continue
                    s = 1 if c > 0 else -1
                    if sign.setdefault(b, s) != s:
                        return False
    return True


# ---------------------------------------------------------------------------
# Access plans (stage 0 of the pipeline)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NestPlan:
    """Resolved access plans for one (pattern, schedule, env) instance.

    ``plans[k] = (read_rows, write_rows)`` for statement instance k, where
    each rows entry is ``resolve_access`` output: per array dim,
    ``(coeff_per_band, const)``. Building a plan never traces; it is the
    unit of work the translation cache's lower stage memoizes.
    """

    nest: LoweredNest
    plans: tuple
    guarded: bool
    single_band: bool
    signs_ok: bool

    @property
    def fast(self) -> bool:
        """Strided-slice fast path precondition."""
        return not self.guarded and self.single_band and self.signs_ok


def plan_nest(pattern: PatternSpec, schedule: Schedule,
              env: Mapping[str, int], nest: LoweredNest | None = None,
              ) -> NestPlan:
    """Lower the schedule and resolve every access against its bands."""
    if nest is None:
        nest = schedule.lower(pattern.domain, env)
    return _plan_from_nest(pattern, nest, env)


def _plan_from_nest(pattern: PatternSpec, nest: LoweredNest,
                    env: Mapping[str, int]) -> NestPlan:
    stmt = pattern.statement
    iter_names = pattern.domain.names
    plans = tuple(
        (
            tuple(
                resolve_access(a, nest, inst, iter_names, env)
                for a in stmt.reads
            ),
            resolve_access(stmt.write, nest, inst, iter_names, env),
        )
        for inst in nest.instances
    )
    return NestPlan(
        nest=nest,
        plans=plans,
        guarded=nest.needs_guard(),
        single_band=all(_single_band_per_dim(nest, i) for i in nest.instances),
        signs_ok=_signs_consistent(plans),
    )


# ---------------------------------------------------------------------------
# Serial oracle
# ---------------------------------------------------------------------------


def serial_oracle(
    pattern: PatternSpec, nest: LoweredNest, arrays: dict[str, np.ndarray],
    env: Mapping[str, int], ntimes: int = 1, *, force_loop: bool = False,
) -> dict[str, np.ndarray]:
    """Execute the scheduled nest in numpy. Copies inputs.

    Fast path: when the statement never reads its written space, the nest
    needs no guards, and every instance admits the strided-slice form,
    sweeps are executed with vectorized numpy slice assignments — result
    is provably identical to the point loop (reads cannot observe writes
    within a sweep; schedule bijectivity keeps instance writes disjoint).
    ``force_loop=True`` pins the point-by-point reference (tests).
    """
    if pattern.oracle is not None:
        # serial-dependent patterns (pointer chase) carry their own
        # ground truth; the affine replay below cannot express them
        return pattern.oracle(pattern, arrays, env, ntimes)
    arrays = {k: np.array(v) for k, v in arrays.items()}
    names = pattern.domain.names
    stmt = pattern.statement
    if not force_loop:
        plan = _oracle_plan(pattern, nest, env)
        if plan is not None:
            return _oracle_vectorized(pattern, plan, arrays, env, ntimes)
    for _ in range(ntimes):
        for point in nest.executed_points():
            scope = dict(zip(names, point))
            scope.update(env)
            vals = []
            for acc in stmt.reads:
                idx = tuple(Affine.of(ix).eval(scope) for ix in acc.index)
                vals.append(np.asarray(arrays[acc.space][idx]))
            res = stmt.combine(vals, dict(env))
            widx = tuple(Affine.of(ix).eval(scope) for ix in stmt.write.index)
            arrays[stmt.write.space][widx] = res
    return arrays


def _oracle_plan(pattern: PatternSpec, nest: LoweredNest,
                 env: Mapping[str, int]) -> NestPlan | None:
    """NestPlan if the vectorized oracle path is provably safe, else None."""
    stmt = pattern.statement
    if any(a.space == stmt.write.space for a in stmt.reads):
        return None
    try:
        plan = _plan_from_nest(pattern, nest, env)
    except Exception:
        return None
    return plan if plan.fast else None


def _oracle_vectorized(pattern: PatternSpec, plan: NestPlan,
                       arrays: dict[str, np.ndarray],
                       env: Mapping[str, int], ntimes: int,
                       ) -> dict[str, np.ndarray]:
    """Numpy mirror of the strided-slice fast path (see lower_jax)."""
    stmt = pattern.statement
    nest = plan.nest
    for _ in range(ntimes):
        for racc, wacc in plan.plans:
            w_sl, w_bands = [], []
            for row, const in wacc:
                sl, b = _slice_for(row, const, nest.band_extents)
                w_sl.append(sl)
                w_bands.append(b)
            vals = []
            for acc, rows in zip(stmt.reads, racc):
                sls, bands_order = [], []
                for row, const in rows:
                    sl, b = _slice_for(row, const, nest.band_extents)
                    sls.append(sl)
                    bands_order.append(b)
                v = arrays[acc.space][tuple(sls)]
                perm = _axis_perm(bands_order, w_bands)
                if perm is not None:
                    v = np.transpose(v, perm)
                vals.append(v)
            res = stmt.combine(vals, dict(env))
            tgt = arrays[stmt.write.space]
            tgt[tuple(w_sl)] = np.asarray(res).astype(tgt.dtype)
    return arrays


# ---------------------------------------------------------------------------
# Vectorized JAX backend
# ---------------------------------------------------------------------------


def _single_band_per_dim(nest: LoweredNest, inst: LoweredInstance) -> bool:
    """True if each domain dim reads exactly one band and each band feeds
    at most one dim — the strided-slice fast path precondition."""
    used: dict[int, int] = {}
    for d in range(nest.rank):
        nz = [b for b, c in enumerate(inst.A[d]) if c != 0]
        if len(nz) != 1:
            return False
        b = nz[0]
        if b in used:
            return False
        used[b] = d
    return True


def _slice_for(row: tuple[int, ...], const: int,
               extents: tuple[int, ...]) -> tuple[slice, int]:
    """Static strided slice covering ``{row.b + const : b in band box}``.

    ``row`` must have at most one nonzero coeff. The slice is always
    ascending-index; see the traversal-direction note in the module doc.
    Returns (slice, band_index) with band_index=-1 for constant indices.
    """
    nz = [(b, c) for b, c in enumerate(row) if c != 0]
    if not nz:
        return slice(const, const + 1), -1
    (b, c), = nz
    e = extents[b]
    if c > 0:
        return slice(const, const + c * (e - 1) + 1, c), b
    lo = const + c * (e - 1)
    return slice(lo, const + 1, -c), b


def _axis_perm(src_bands: list[int], dst_bands: list[int]):
    """Permutation taking value axes (ordered by src_bands) to dst order,
    or None if already aligned / not a permutation (broadcast case)."""
    if src_bands == dst_bands:
        return None
    if sorted(src_bands) != sorted(dst_bands):
        return None
    return tuple(src_bands.index(b) for b in dst_bands)


def lower_jax(
    pattern: PatternSpec, schedule: Schedule, env: Mapping[str, int],
    *, force_gather: bool = False, plan: NestPlan | None = None,
) -> Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]:
    """Build ``step(arrays) -> arrays`` executing one sweep of the pattern.

    ``plan`` lets the staged pipeline reuse an already-resolved NestPlan
    instead of re-deriving access rows.
    """
    if pattern.kernel is not None:
        # serial-dependent patterns replace the generated step wholesale;
        # schedule transforms would be silently ignored, so refuse them
        if schedule.transforms:
            raise ValueError(
                f"pattern {pattern.name!r} has a custom kernel; schedule "
                f"{schedule.name!r} cannot be applied to it"
            )
        return pattern.kernel(pattern, env)
    if plan is None:
        plan = plan_nest(pattern, schedule, env)
    nest = plan.nest
    stmt = pattern.statement
    plans = plan.plans

    if plan.fast and not force_gather:
        def step(arrays: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
            arrays = dict(arrays)
            for racc, wacc in plans:
                w_sl, w_bands = [], []
                for row, const in wacc:
                    sl, b = _slice_for(row, const, nest.band_extents)
                    w_sl.append(sl)
                    w_bands.append(b)
                vals = []
                for acc, rr in zip(stmt.reads, racc):
                    sls, bands_order = [], []
                    for row, const in rr:
                        sl, b = _slice_for(row, const, nest.band_extents)
                        sls.append(sl)
                        bands_order.append(b)
                    v = arrays[acc.space][tuple(sls)]
                    perm = _axis_perm(bands_order, w_bands)
                    if perm is not None:
                        v = jnp.transpose(v, perm)
                    vals.append(v)
                res = stmt.combine(vals, dict(env))
                tgt = arrays[stmt.write.space]
                arrays[stmt.write.space] = tgt.at[tuple(w_sl)].set(
                    jnp.asarray(res).astype(tgt.dtype)
                )
            return arrays

        return step

    # -- gather/scatter general path ---------------------------------------
    # Band coordinates come from lax.broadcasted_iota inside the traced
    # program, so no index constants are embedded in the HLO and trace
    # size stays O(accesses), not O(points).
    n_pts = int(np.prod(nest.band_extents)) if nest.band_extents else 1
    if n_pts > _GATHER_POINT_CAP:
        raise ValueError(
            f"gather path would materialize {n_pts} index points; "
            "use lower_pallas"
        )
    guarded = plan.guarded
    used_bands = sorted({
        b
        for racc, wacc in plans
        for rows in list(racc) + [wacc]
        for row, _ in rows
        for b, c in enumerate(row)
        if c != 0
    } | ({
        b
        for inst in nest.instances
        for d in range(nest.rank)
        for b, c in enumerate(inst.A[d])
        if c != 0
    } if guarded else set()))
    extents = nest.band_extents

    def step(arrays: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        cols = {
            b: jax.lax.broadcasted_iota(jnp.int32, extents, b).reshape(-1)
            for b in used_bands
        }

        def lin(row, const):
            acc = None
            for b, c in enumerate(row):
                if c == 0:
                    continue
                term = c * cols[b]
                acc = term if acc is None else acc + term
            if acc is None:
                return jnp.full((n_pts,), const, jnp.int32)
            return acc + jnp.int32(const)

        for (racc, wacc), inst in zip(plans, nest.instances):
            mask = None
            if guarded:
                mask = jnp.ones((n_pts,), bool)
                for d in range(nest.rank):
                    it = lin(inst.A[d], inst.c[d])
                    mask &= (it >= nest.domain_lo[d]) & (it < nest.domain_hi[d])
            # OOB reads clamp (jit default); their lanes are dropped on write
            vals = [
                arrays[acc.space][tuple(lin(row, const) for row, const in rows)]
                for acc, rows in zip(stmt.reads, racc)
            ]
            res = stmt.combine(vals, dict(env))
            tgt = arrays[stmt.write.space]
            widx = tuple(lin(row, const) for row, const in wacc)
            if mask is not None:
                widx = tuple(jnp.where(mask, ix, -1) for ix in widx)
            arrays[stmt.write.space] = tgt.at[widx].set(
                jnp.asarray(res).astype(tgt.dtype), mode="drop"
            )
        return arrays

    return step


# ---------------------------------------------------------------------------
# Parametric (shape-polymorphic) JAX backend
# ---------------------------------------------------------------------------


def resolve_access_symbolic(
    acc: Access, pnest: ParamNest, inst: ParamInstance,
    iter_names: tuple[str, ...],
) -> list[tuple[tuple[Affine, ...], Affine]]:
    """Symbolic twin of :func:`resolve_access`: compose an access with a
    :class:`ParamInstance` without resolving parameters, so per array dim
    ``array_index = row . bands + const`` with Affine-in-params entries."""
    out = []
    pos = {n: i for i, n in enumerate(iter_names)}
    for ix in acc.resolved():
        row = [Affine.of(0)] * pnest.n_bands
        const = Affine.of(ix.const)
        for sym, c in ix.coeffs:
            if sym in pos:
                d = pos[sym]
                const = const + inst.c[d] * c
                for b in range(pnest.n_bands):
                    row[b] = row[b] + inst.A[d][b] * c
            elif sym in pnest.params:
                const = const + Affine(coeffs=((sym, c),))
            else:
                raise KeyError(
                    f"access symbol {sym!r} is not an iterator or param"
                )
        out.append((tuple(row), const))
    return out


def _affine_traced(aff: Affine, scope: Mapping[str, jnp.ndarray]):
    """Evaluate an Affine whose symbols map to traced int32 scalars.

    Rational coefficients are handled exactly: the whole expression is
    scaled by the lcm of the denominators, evaluated in integers, and
    divided back out — by construction (divisibility constraints) the
    result is integral, so the floor division is exact.
    """
    L = aff.denominator
    acc = jnp.int32(int(aff.const * L))
    for sym, c in aff.coeffs:
        acc = acc + jnp.int32(int(c * L)) * scope[sym]
    return acc // L if L != 1 else acc


def lower_jax_parametric(
    pattern: PatternSpec, schedule: Schedule, cap_env: Mapping[str, int],
    *, params: tuple[str, ...] = ("n",), chunk: int = _PARAM_CHUNK,
    pnest: ParamNest | None = None,
) -> Callable:
    """Build ``step(arrays, pvals) -> arrays`` with the working-set
    parameter(s) as *traced operands* instead of baked constants.

    One executable serves every working set up to the capacity
    ``cap_env`` (arrays are allocated at capacity shapes): band extents,
    instance maps, and domain bounds are computed inside the trace from
    the ``pvals`` scalars, and points are executed in fixed-shape lane
    chunks under a dynamic trip count (``fori_loop`` over
    ``ceil(points/chunk)``), so the work a call performs scales with the
    *runtime* working set — a ladder shares one compiled program without
    every rung paying capacity-sized sweeps.

    Reads and the write are gather/scatter over the chunk lanes; lanes
    past the dynamic point count (or outside the domain, for guarded
    nests) are masked onto index -1 and dropped, mirroring the
    specialized gather path. Preconditions checked by the caller via
    ``ParamNest.admits``: every requested env must satisfy the nest's
    divisibility constraints.
    """
    if pattern.kernel is not None:
        from .schedule import SymbolicLowerError

        raise SymbolicLowerError(
            f"pattern {pattern.name!r} has a custom kernel; the parametric "
            "path cannot share it (env is baked into the step)"
        )
    if pnest is None:
        pnest = schedule.lower_symbolic(pattern.domain, params)
    stmt = pattern.statement
    iter_names = pattern.domain.names
    plans = tuple(
        (
            tuple(
                resolve_access_symbolic(a, pnest, inst, iter_names)
                for a in stmt.reads
            ),
            resolve_access_symbolic(stmt.write, pnest, inst, iter_names),
        )
        for inst in pnest.instances
    )
    n_bands = pnest.n_bands
    rank = pnest.rank
    cap_extents = tuple(max(0, e.eval(cap_env)) for e in pnest.band_extents)
    cap_pts = int(np.prod(cap_extents)) if cap_extents else 1
    if cap_pts > _GATHER_POINT_CAP:
        raise ValueError(
            f"parametric path would stage {cap_pts} capacity points; "
            "use lower_pallas"
        )
    C = int(min(chunk, max(1, cap_pts)))
    rest_env = {k: int(v) for k, v in cap_env.items() if k not in params}

    def step(arrays: dict[str, jnp.ndarray], pvals) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        scope = {p: jnp.asarray(v, jnp.int32) for p, v in zip(params, pvals)}
        cenv = {**rest_env, **scope}

        ext = [jnp.maximum(_affine_traced(e, scope), 0)
               for e in pnest.band_extents]
        strides = [None] * n_bands
        s = jnp.int32(1)
        for b in reversed(range(n_bands)):
            strides[b] = s
            s = s * ext[b]
        npts = s if n_bands else jnp.int32(1)
        nchunks = (npts + (C - 1)) // C
        lane0 = jax.lax.broadcasted_iota(jnp.int32, (C,), 0)
        lo = [_affine_traced(l, scope) for l in pnest.domain_lo]
        hi = [_affine_traced(h, scope) for h in pnest.domain_hi]
        # loop-invariant scalar coefficients, computed once outside the body
        tr_plans = [
            (
                [
                    [
                        ([_affine_traced(cf, scope) for cf in row],
                         _affine_traced(const, scope))
                        for row, const in rows
                    ]
                    for rows in racc
                ],
                [
                    ([_affine_traced(cf, scope) for cf in row],
                     _affine_traced(const, scope))
                    for row, const in wacc
                ],
                [
                    ([_affine_traced(cf, scope) for cf in inst.A[d]],
                     _affine_traced(inst.c[d], scope))
                    for d in range(rank)
                ],
            )
            for (racc, wacc), inst in zip(plans, pnest.instances)
        ]

        def body(ci, arrs):
            arrs = dict(arrs)
            lanes = ci * C + lane0
            valid0 = lanes < npts
            cols = [(lanes // strides[b]) % ext[b] for b in range(n_bands)]

            def lin(coeffs, const):
                acc = jnp.full((C,), 1, jnp.int32) * const
                for b, cf in enumerate(coeffs):
                    acc = acc + cf * cols[b]
                return acc

            for racc, wacc, imap in tr_plans:
                valid = valid0
                for d in range(rank):
                    it = lin(*imap[d])
                    valid = valid & (it >= lo[d]) & (it < hi[d])
                vals = [
                    arrs[acc.space][tuple(lin(*rc) for rc in rows)]
                    for acc, rows in zip(stmt.reads, racc)
                ]
                res = stmt.combine(vals, cenv)
                tgt = arrs[stmt.write.space]
                widx = tuple(
                    jnp.where(valid, lin(*rc), -1) for rc in wacc
                )
                arrs[stmt.write.space] = tgt.at[widx].set(
                    jnp.asarray(res).astype(tgt.dtype), mode="drop"
                )
            return arrs

        return jax.lax.fori_loop(0, nchunks, body, arrays)

    return step


# ---------------------------------------------------------------------------
# Pallas backend (manual-DMA style; blocked showcase kernels in repro.kernels)
# ---------------------------------------------------------------------------


def lower_pallas(
    pattern: PatternSpec, schedule: Schedule, env: Mapping[str, int],
    *, interpret: bool = True, grid_bands: tuple[str, ...] | None = None,
    plan: NestPlan | None = None,
) -> Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]:
    """Lower to ``pl.pallas_call``.

    Bands are split into *grid bands* (pallas grid) and *vector bands*
    (in-kernel slice extents). By default the innermost unit-stride band
    of each domain dim is the vector band; ``grid_bands`` forces named
    bands into the grid (used by the tile-sweep benchmarks so tile loops
    become grid steps, exactly like the generated ISCC tile loops).
    The output space is aliased to its input so un-iterated elements
    (stencil borders) keep their initial values, matching the oracle.
    """
    if pattern.kernel is not None:
        raise NotImplementedError(
            f"pattern {pattern.name!r} has a custom (jax) kernel; "
            "the pallas backend cannot lower it"
        )
    if plan is None:
        plan = plan_nest(pattern, schedule, env)
    nest = plan.nest
    if plan.guarded:
        raise NotImplementedError(
            "guarded schedules on the pallas backend: pick divisible tile "
            "sizes (the drivers choose divisible working sets)"
        )
    stmt = pattern.statement
    rank = nest.rank

    inst0 = nest.instances[0]
    vec_band_for_dim: list[int] = []
    for d in range(rank):
        cands = [b for b, c in enumerate(inst0.A[d]) if abs(c) == 1]
        if not cands:
            raise ValueError(f"dim {d} has no unit-stride band; cannot vectorize")
        vec_band_for_dim.append(max(cands))
    vec_bands = sorted(set(vec_band_for_dim))
    if grid_bands is not None:
        vec_bands = [b for b in vec_bands if nest.band_names[b] not in grid_bands]
    gbs = [b for b in range(nest.n_bands) if b not in vec_bands]
    for inst in nest.instances:
        for d in range(rank):
            for b in vec_bands:
                if inst.A[d][b] not in (-1, 0, 1):
                    raise ValueError("vector band with non-unit stride")

    grid = tuple(nest.band_extents[b] for b in gbs) or (1,)
    vec_extents = {b: nest.band_extents[b] for b in vec_bands}

    acc_plans = plan.plans
    if not plan.signs_ok:
        raise ValueError("mixed coefficient signs per band; not vectorizable")

    space_order = [s.name for s in pattern.spaces]
    out_name = stmt.write.space
    out_pos = space_order.index(out_name)
    shapes = {s.name: s.concrete_shape(env) for s in pattern.spaces}
    dtypes = {s.name: s.dtype for s in pattern.spaces}
    env_dict = dict(env)

    def kernel(*refs):
        in_refs = {nm: r for nm, r in zip(space_order, refs[:len(space_order)])}
        out_ref = refs[len(space_order)]
        gvals = [pl.program_id(i) for i in range(len(gbs))] if gbs else []

        def base_of(rows_const):
            """(base index at vector-band==0/origin, vector band per dim)."""
            base, vb = [], []
            for row, const in rows_const:
                off = const
                for gi, b in enumerate(gbs):
                    off = off + row[b] * gvals[gi]
                bsel, bstep = -1, 1
                for b in vec_bands:
                    if row[b] != 0:
                        bsel, bstep = b, row[b]
                if bsel >= 0 and bstep == -1:
                    # ascending-index window: [off - (e-1), off]
                    off = off - (vec_extents[bsel] - 1)
                base.append(off)
                vb.append(bsel)
            return base, vb

        for racc, wacc in acc_plans:
            wbase, wvb = base_of(wacc)
            vals = []
            for acc, rows in zip(stmt.reads, racc):
                base, vb = base_of(rows)
                idx = tuple(
                    pl.ds(b0, vec_extents[bsel] if bsel >= 0 else 1)
                    for b0, bsel in zip(base, vb)
                )
                v = in_refs[acc.space][idx]
                perm = _axis_perm(vb, wvb)
                if perm is not None:
                    v = jnp.transpose(v, perm)
                vals.append(v)
            res = stmt.combine(vals, env_dict)
            want = tuple(1 if b < 0 else vec_extents[b] for b in wvb)
            res = jnp.asarray(res).astype(out_ref.dtype)
            if res.shape != want:
                res = jnp.broadcast_to(res, want)
            widx = tuple(
                pl.ds(b0, vec_extents[bsel] if bsel >= 0 else 1)
                for b0, bsel in zip(wbase, wvb)
            )
            out_ref[widx] = res

    call = pl.pallas_call(
        kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct(shapes[out_name], dtypes[out_name]),
        input_output_aliases={out_pos: 0},
        interpret=interpret,
    )

    def step(arrays: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        arrays = dict(arrays)
        arrays[out_name] = call(*[arrays[nm] for nm in space_order])
        return arrays

    return step
