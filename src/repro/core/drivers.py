"""Benchmark driver templates — the kernel-independent layer.

The paper ships three driver templates; each has a direct analogue here:

* **Unified data spaces** (Listing 1): threads share one array through
  OpenMP work-sharing. Here: one array per data space; parallel "programs"
  are carved out of the iteration domain by tiling its outermost dim into
  ``programs`` contiguous chunks (exactly ``schedule(static, n/t)``). The
  chunks share native tiles at their seams — the false-sharing analogue.

* **Independent data spaces** (Listing 2): each thread owns a disjoint
  array. Here: every space gains a leading ``programs`` axis whose rows
  are optionally padded to the native tile (``pad`` elements), and the
  statement is rewritten to index through the program id — the exact
  transformation the paper performs in the memory-mapping macros
  (``A[t_id*8][i]``).

* **PAPI measurement** (template 3): ``measured=True`` attaches
  ``hlo_counters`` + analytic ``tile_traffic`` to every record.

A driver owns the repetition loop. ``sync_every_rep=False`` fuses all
``ntimes`` sweeps into one compiled ``lax.fori_loop`` — the ``nowait``
analogue (no host round-trip / no dispatch barrier between sweeps);
``True`` dispatches one sweep per call and fences, reproducing the
per-iteration barrier of Listing 1.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

import jax.numpy as jnp

from .codegen import serial_oracle
from .domain import Affine, Dim, IterDomain
from .measure import (
    Record,
    classify_level,
    hlo_counters,
    tile_traffic,
    time_fn,
)
from .pattern import Access, DataSpace, PatternSpec, Statement
from .schedule import Schedule, identity
from .staging import (
    GLOBAL_CACHE,
    Compiled,
    Lowered,
    TranslationCache,
    precompile,
    stage_lower,
)

__all__ = [
    "DriverConfig",
    "Driver",
    "Prepared",
    "independent_view",
    "unified_program_schedule",
]


# ---------------------------------------------------------------------------
# Template transformations
# ---------------------------------------------------------------------------


def independent_view(pattern: PatternSpec, programs: int, pad: int = 0) -> PatternSpec:
    """Rewrite a pattern to the *independent data spaces* form.

    Every space of shape ``(n, ...)`` becomes ``(programs, n/programs + pad,
    ...)`` (the caller passes the *per-program* ``n`` in env — mirroring the
    paper's ``int N = n/t``); a new outermost iterator ``p`` runs over
    programs and all accesses are prefixed with it. ``pad`` is the paper's
    padding factor (8 doubles -> one 64B line; here pad to the 1024-element
    native tile with ``pad=tile-remainder`` or any nonzero slack).
    """
    p = "p"
    if p in pattern.domain.names:
        raise ValueError("pattern already has a 'p' iterator")

    def pad_shape(shape):
        first = Affine.of(shape[0]) + pad
        return (Affine.of(programs), first) + tuple(shape[1:])

    def pad_init(init):
        if not callable(init):
            return init
        # per-row init: drop the program grid, apply the original to the rest
        return lambda pgrid, *grids: init(*grids)

    spaces = tuple(
        dataclasses.replace(s, shape=pad_shape(s.shape), init=pad_init(s.init))
        for s in pattern.spaces
    )

    def prefix(acc: Access) -> Access:
        return Access(acc.space, (p,) + tuple(acc.index))

    stmt = Statement(
        reads=tuple(prefix(a) for a in pattern.statement.reads),
        write=prefix(pattern.statement.write),
        combine=pattern.statement.combine,
    )
    dom = IterDomain((Dim.of(p, 0, programs),) + pattern.domain.dims)
    return dataclasses.replace(
        pattern,
        name=f"{pattern.name}.indep{programs}" + (f".pad{pad}" if pad else ""),
        spaces=spaces,
        statement=stmt,
        domain=dom,
    )


def unified_program_schedule(
    pattern: PatternSpec, programs: int, env: Mapping[str, int],
    base: Schedule | None = None,
) -> Schedule:
    """Tile the outermost domain dim into ``programs`` chunks — the
    ``schedule(static, n/t)`` work-sharing split of the unified template."""
    sch = base or identity()
    if programs == 1:
        return sch  # no work-sharing split needed
    d0 = pattern.domain.dims[0]
    extent = d0.extent(env)
    if extent % programs != 0:
        raise ValueError(
            f"unified template needs programs | extent ({programs} vs {extent})"
        )
    return sch.tile(d0.name, extent // programs, outer="prog", inner=d0.name)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DriverConfig:
    template: str = "unified"       # unified | independent
    programs: int = 8               # "threads"
    pad: int = 0                    # independent-template row padding (elems)
    backend: str = "jax"            # jax | pallas
    schedule: Schedule | None = None  # extra transforms (applied to the kernel dims)
    ntimes: int = 50                # sweeps per measurement
    sync_every_rep: bool = False    # True = per-sweep barrier (Listing 1)
    reps: int = 5                   # timing repetitions (median)
    measured: bool = False          # attach counter surrogates (template 3)
    grid_bands: tuple[str, ...] | None = None  # pallas grid override
    validate_n: int | None = 64     # oracle-check size (None = skip)


@dataclasses.dataclass
class Prepared:
    """One staged measurement point: env + both pipeline stages."""

    env: dict
    lowered: Lowered
    compiled: Compiled


class Driver:
    """Combine a PatternSpec with a driver template and measure it.

    ``pattern_factory(env)`` lets stream-count-style sweeps rebuild the
    pattern per point; for fixed patterns pass ``lambda env: pat``.

    Construction is staged (``lower -> compile -> execute``) through a
    :class:`~repro.core.staging.TranslationCache`; identical (pattern,
    schedule, template, backend, env) tuples never lower or compile
    twice across working-set loops, repeated runs, and sweeps. Pass
    ``cache=`` to isolate; the default pools work process-wide.
    """

    def __init__(self, pattern_factory: Callable[[Mapping[str, int]], PatternSpec],
                 config: DriverConfig,
                 cache: TranslationCache | None = None):
        self.factory = pattern_factory
        self.cfg = config
        self.cache = cache if cache is not None else GLOBAL_CACHE

    # -- construction -------------------------------------------------------

    def lower(self, env: Mapping[str, int]) -> Lowered:
        """Stage 1: apply the driver template and resolve access plans.

        Note the ``independent`` template treats the caller's ``n`` as
        the *per-program* row extent (mirroring the paper's
        ``int N = n/t`` macro): callers pass per-program ``n`` and every
        space grows a leading ``programs`` axis of such rows.
        """
        cfg = self.cfg
        base = self.factory(env)
        sch = cfg.schedule or identity()
        if cfg.template == "independent":
            pat = independent_view(base, cfg.programs, cfg.pad)
            grid_bands = ("p",) + tuple(cfg.grid_bands or ())
        elif cfg.template == "unified":
            pat = base
            sch = unified_program_schedule(base, cfg.programs, env, sch)
            grid_bands = ("prog",) + tuple(cfg.grid_bands or ())
        else:
            raise ValueError(cfg.template)
        return stage_lower(
            pat, sch, env, cfg.backend,
            grid_bands=grid_bands if cfg.backend == "pallas" else None,
            cache=self.cache,
        )

    def build(self, env: Mapping[str, int]):
        """Stage 1+2 plus initial arrays.

        Returns ``(pattern, schedule, env, compiled, arrays0, names)``;
        ``compiled(tup)`` executes ``ntimes`` sweeps under the configured
        barrier regime on a tuple of arrays ordered by ``names``.
        """
        cfg = self.cfg
        lowered = self.lower(env)
        compiled = lowered.compile(
            ntimes=cfg.ntimes, sync_every_rep=cfg.sync_every_rep,
            cache=self.cache,
        )
        pat = lowered.pattern
        arrays0 = {k: jnp.asarray(v) for k, v in pat.allocate(lowered.env).items()}
        names = compiled.names
        return (pat, lowered.schedule, lowered.env, compiled,
                tuple(arrays0[k] for k in names), names)

    def prepare(self, working_sets: Sequence[int],
                env_extra: Mapping[str, int] | None = None,
                parallel: bool = True) -> list[Prepared]:
        """Stage all working-set points: lower serially (cheap, GIL-bound),
        then AOT-compile the points concurrently (XLA releases the GIL)."""
        cfg = self.cfg
        lowereds = []
        for n in working_sets:
            env = {"n": int(n), **(env_extra or {})}
            lowereds.append((env, self.lower(env)))
        thunks = [
            (lambda lw=lw: lw.compile(
                ntimes=cfg.ntimes, sync_every_rep=cfg.sync_every_rep,
                cache=self.cache,
            ))
            for _, lw in lowereds
        ]
        compiled = (precompile(thunks) if parallel
                    else [t() for t in thunks])
        return [
            Prepared(env=env, lowered=lw, compiled=c)
            for (env, lw), c in zip(lowereds, compiled)
        ]

    # -- validation (the <kernel>_val.in stage) ------------------------------

    def validate(self, env: Mapping[str, int] | None = None) -> None:
        """Replay the run schedule against the numpy oracle.

        Memoized per lowered key: a sweep validates each variant once,
        not once per working set / per repeated call.
        """
        cfg = self.cfg
        n = cfg.validate_n or 64
        env = dict(env or {"n": n})
        lowered = self.lower(env)
        vkey = ("validate", lowered.key) if lowered.key is not None else None
        if vkey is not None and self.cache.was_validated(vkey):
            return
        pat, sch, env2 = lowered.pattern, lowered.schedule, lowered.env
        arrays = pat.allocate(env2)
        want = serial_oracle(pat, lowered.nest, arrays, env2, ntimes=2)
        got = {k: jnp.asarray(v) for k, v in arrays.items()}
        for _ in range(2):
            got = lowered.step(got)
        for k in want:
            np.testing.assert_allclose(
                np.asarray(got[k]), want[k], rtol=1e-5, atol=1e-5,
                err_msg=f"space {k} diverged under {sch.name}/{cfg.template}",
            )
        if vkey is not None:
            self.cache.mark_validated(vkey)

    # -- measurement ---------------------------------------------------------

    def run(self, working_sets: Sequence[int],
            env_extra: Mapping[str, int] | None = None) -> list[Record]:
        cfg = self.cfg
        records = []
        for p in self.prepare(working_sets, env_extra):
            pat, env = p.lowered.pattern, p.env
            arrays0 = {
                k: jnp.asarray(v) for k, v in pat.allocate(p.lowered.env).items()
            }
            tup = tuple(arrays0[k] for k in p.compiled.names)
            timing = time_fn(
                p.compiled, tup, reps=cfg.reps, warmup=1,
                compile_seconds=p.compiled.compile_seconds,
            )
            pts = pat.domain.point_count(p.lowered.env)
            bpp = pat.bytes_per_point()
            total_bytes = bpp * pts * cfg.ntimes
            ws_bytes = sum(
                int(np.prod(s.concrete_shape(p.lowered.env)))
                * np.dtype(s.dtype).itemsize
                for s in pat.spaces
            )
            rec = Record(
                pattern=pat.name,
                template=cfg.template,
                schedule=p.lowered.schedule.name,
                backend=cfg.backend,
                n=int(env["n"]),
                working_set_bytes=ws_bytes,
                programs=cfg.programs,
                ntimes=cfg.ntimes,
                seconds=timing.seconds,
                gbs=total_bytes / timing.seconds / 1e9,
                gflops=pat.flops_per_point * pts * cfg.ntimes
                / timing.seconds / 1e9,
                level=classify_level(ws_bytes),
                extra={
                    "barrier": cfg.sync_every_rep,
                    "compile_seconds": p.compiled.compile_seconds,
                    "lower_seconds": p.lowered.lower_seconds,
                    "cache_hit": p.compiled.from_cache,
                },
            )
            if cfg.measured:
                rec.extra.update(hlo_counters(p.compiled))
                rec.extra.update(self._traffic(pat, p.lowered.env).as_dict())
            records.append(rec)
        return records

    def _traffic(self, pat: PatternSpec, env: Mapping[str, int]):
        """Analytic tile traffic for the current template split (1D)."""
        cfg = self.cfg
        written = pat.statement.write.space
        slices: list[dict[str, tuple[int, int]]] = []
        if cfg.template == "independent":
            # rows are (n + pad) apart in the flat layout
            row = Affine.of(pat.space(written).shape[1]).eval(env)
            per = pat.domain.dims[1].extent(env)
            lo0 = pat.domain.dims[1].lo.eval(env)
            for p in range(cfg.programs):
                flat0 = p * row + lo0
                slices.append(
                    {s.name: (flat0, flat0 + per) for s in pat.spaces}
                )
        else:
            d0 = pat.domain.dims[0]
            lo, ext = d0.lo.eval(env), d0.extent(env)
            chunk = ext // cfg.programs
            for p in range(cfg.programs):
                a = lo + p * chunk
                slices.append({s.name: (a, a + chunk) for s in pat.spaces})
        return tile_traffic(
            spaces={s.name: s.concrete_shape(env) for s in pat.spaces},
            program_slices=slices,
            written=written,
            itemsize=np.dtype(pat.space(written).dtype).itemsize,
        )
