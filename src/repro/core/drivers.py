"""Benchmark driver templates — the kernel-independent layer.

The paper ships three driver templates; each has a direct analogue here:

* **Unified data spaces** (Listing 1): threads share one array through
  OpenMP work-sharing. Here: one array per data space; parallel "programs"
  are carved out of the iteration domain by tiling its outermost dim into
  ``programs`` contiguous chunks (exactly ``schedule(static, n/t)``). The
  chunks share native tiles at their seams — the false-sharing analogue.

* **Independent data spaces** (Listing 2): each thread owns a disjoint
  array. Here: every space gains a leading ``programs`` axis whose rows
  are optionally padded to the native tile (``pad`` elements), and the
  statement is rewritten to index through the program id — the exact
  transformation the paper performs in the memory-mapping macros
  (``A[t_id*8][i]``).

* **PAPI measurement** (template 3): ``measured=True`` attaches
  ``hlo_counters`` + analytic ``tile_traffic`` to every record.

A driver owns the repetition loop. ``sync_every_rep=False`` fuses all
``ntimes`` sweeps into one compiled ``lax.fori_loop`` — the ``nowait``
analogue (no host round-trip / no dispatch barrier between sweeps);
``True`` dispatches one sweep per call and fences, reproducing the
per-iteration barrier of Listing 1.

Measurement invariants: ``prepare``/``run`` stage through the
translation cache (identical tuples never lower or compile twice), the
executables they time are **donated** on the jax backend (no per-call
buffer copy on either the specialized or the parametric side — see
``staging``), ladders resolve one lowering regime up front
(``_resolve_param_path``: specialized / strided / gather, with the
exact per-env window-bounds check), and every record self-describes via
``extra`` (``param_path``, ``param_window_rank``, ``donated``,
``cache_hit``, ...).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from .codegen import serial_oracle
from .domain import Affine, Dim, IterDomain
from .errors import (
    BenchFailure,
    BudgetExceeded,
    CapacityRefused,
    CompileFailure,
    LowerFailure,
    default_capacity_budget,
)
from .measure import (
    Record,
    classify_level,
    hlo_counters,
    tile_traffic,
    time_fn,
)
from .pattern import Access, DataSpace, PatternSpec, Statement
from .schedule import Schedule, SymbolicLowerError, identity
from .staging import (
    GLOBAL_CACHE,
    Compiled,
    Lowered,
    ParamCompiled,
    ParamLowered,
    TranslationCache,
    fingerprint_pattern,
    precompile,
    stage_lower,
    stage_lower_parametric,
)

__all__ = [
    "DriverConfig",
    "Driver",
    "Prepared",
    "independent_view",
    "unified_program_schedule",
]


# ---------------------------------------------------------------------------
# Template transformations
# ---------------------------------------------------------------------------


def independent_view(pattern: PatternSpec, programs: int, pad: int = 0) -> PatternSpec:
    """Rewrite a pattern to the *independent data spaces* form.

    Every space of shape ``(n, ...)`` becomes ``(programs, n/programs + pad,
    ...)`` (the caller passes the *per-program* ``n`` in env — mirroring the
    paper's ``int N = n/t``); a new outermost iterator ``p`` runs over
    programs and all accesses are prefixed with it. ``pad`` is the paper's
    padding factor (8 doubles -> one 64B line; here pad to the 1024-element
    native tile with ``pad=tile-remainder`` or any nonzero slack).
    """
    p = "p"
    if p in pattern.domain.names:
        raise ValueError("pattern already has a 'p' iterator")
    if pattern.kernel is not None:
        raise ValueError(
            f"pattern {pattern.name!r} has a custom kernel; the independent "
            "template's access rewrite cannot apply to it (use unified with "
            "programs=1)"
        )

    def pad_shape(shape):
        first = Affine.of(shape[0]) + pad
        return (Affine.of(programs), first) + tuple(shape[1:])

    def pad_init(init):
        if not callable(init):
            return init
        # per-row init: drop the program grid, apply the original to the rest
        return lambda pgrid, *grids: init(*grids)

    spaces = tuple(
        dataclasses.replace(s, shape=pad_shape(s.shape), init=pad_init(s.init))
        for s in pattern.spaces
    )

    def prefix(acc: Access) -> Access:
        return Access(acc.space, (p,) + tuple(acc.index))

    stmt = Statement(
        reads=tuple(prefix(a) for a in pattern.statement.reads),
        write=prefix(pattern.statement.write),
        combine=pattern.statement.combine,
    )
    dom = IterDomain((Dim.of(p, 0, programs),) + pattern.domain.dims)
    return dataclasses.replace(
        pattern,
        name=f"{pattern.name}.indep{programs}" + (f".pad{pad}" if pad else ""),
        spaces=spaces,
        statement=stmt,
        domain=dom,
    )


def unified_program_schedule(
    pattern: PatternSpec, programs: int, env: Mapping[str, int],
    base: Schedule | None = None,
) -> Schedule:
    """Tile the outermost domain dim into ``programs`` chunks — the
    ``schedule(static, n/t)`` work-sharing split of the unified template."""
    sch = base or identity()
    if programs == 1:
        return sch  # no work-sharing split needed
    d0 = pattern.domain.dims[0]
    extent = d0.extent(env)
    if extent % programs != 0:
        raise ValueError(
            f"unified template needs programs | extent ({programs} vs {extent})"
        )
    # tile_by_count keeps the split affine in a symbolic extent (chunk
    # length n/programs becomes a rational coefficient), so the unified
    # template stays shape-polymorphic; concrete lowering is identical to
    # the old tile(extent // programs) form.
    return sch.tile_by_count(d0.name, programs, outer="prog", inner=d0.name)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DriverConfig:
    template: str = "unified"       # unified | independent
    programs: int = 8               # "threads"
    pad: int = 0                    # independent-template row padding (elems)
    backend: str = "jax"            # jax | pallas
    schedule: Schedule | None = None  # extra transforms (applied to the kernel dims)
    ntimes: int = 50                # sweeps per measurement
    sync_every_rep: bool = False    # True = per-sweep barrier (Listing 1)
    reps: int = 5                   # timing repetitions (median)
    measured: bool = False          # attach counter surrogates (template 3)
    grid_bands: tuple[str, ...] | None = None  # pallas grid override
    validate_n: int | None = 64     # oracle-check size (None = skip)
    # Shape-polymorphic ladders: None = unset (specialize; the suite
    # runner may apply its workload-level policy); False = always
    # specialize per working set (one executable per n, never
    # overridden); "auto" = share one executable across the whole ladder
    # when the schedule lowers symbolically and every point satisfies
    # its divisibility constraints, else fall back; True = require the
    # parametric path (raise if unsupported).
    parametric: bool | str | None = None
    # Parametric lowering regime: "auto" prefers the strided fast path
    # (dynamic-slice windows — per-call cost matches the specialized
    # strided path) and falls back to masked gather/scatter; "strided"
    # requires the fast path (the ladder specializes — or raises under
    # parametric=True — when the nest is ineligible); "gather" pins the
    # masked form (the reference regime conformance tests pin down).
    # Records report the chosen regime as extra["param_path"]
    # ("specialized" when the point did not share an executable at all).
    param_path: str = "auto"
    # Buffer donation on the jax backend: None = backend default (jax
    # donates, pallas does not). False is the resilience engine's last
    # demotion rung — undonated executables copy per call but sidestep
    # any donation-stream fault. Parametric sharing requires donation,
    # so donate=False also forces the specialized path.
    donate: bool | None = None
    # Adaptive measurement quality (see measure.time_fn): repeat past
    # `reps` until the sample CV drops to target_cv, bounded by
    # max_reps. None keeps the fixed-rep legacy estimator.
    target_cv: float | None = None
    max_reps: int | None = None
    # Straggler watchdog: wall-clock budget per measurement point;
    # exceeding it raises BudgetExceeded (recorded as a failure by the
    # plan engine rather than hanging the sweep).
    time_budget_s: float | None = None
    # Working-set pre-flight: refuse (CapacityRefused) points whose
    # allocation would exceed this budget. None = process default
    # (REPRO_CAPACITY_BUDGET env var, else 80% of MemAvailable).
    capacity_budget_bytes: int | None = None
    # Device pinning (the plan engine's device axis): an index into
    # jax.devices(), resolved modulo the device count so a plan written
    # for an 8-device mesh still runs (collapsed) on a smaller box.
    # Staged executables compile for — and arrays allocate on — the
    # resolved device (the index is part of the translation-cache
    # identity), which is what lets ThreadPoolBackend drive distinct
    # device groups genuinely in parallel. None = process default.
    device: int | None = None


@dataclasses.dataclass
class Prepared:
    """One staged measurement point: env + both pipeline stages.

    On the parametric path ``lowered``/``compiled`` are the ladder-shared
    :class:`ParamLowered`/:class:`ParamCompiled` (allocation happens at
    their capacity env) and ``env`` names this point's working set.
    """

    env: dict
    lowered: Lowered | ParamLowered
    compiled: Compiled | ParamCompiled

    @property
    def parametric(self) -> bool:
        return isinstance(self.lowered, ParamLowered)

    def executable(self) -> Callable:
        """A ``fn(tup) -> tup`` for this point (binds params if needed).

        Both paths thread donated buffers: the parametric bind closes
        over this point's param scalars, the specialized bind (donated
        measurement executables) threads each call's output tuple into
        the next so the timing loop never touches a consumed buffer."""
        if self.parametric:
            return self.compiled.bind(self.env)
        return self.compiled.bind()


class Driver:
    """Combine a PatternSpec with a driver template and measure it.

    ``pattern_factory(env)`` lets stream-count-style sweeps rebuild the
    pattern per point; for fixed patterns pass ``lambda env: pat``.

    Construction is staged (``lower -> compile -> execute``) through a
    :class:`~repro.core.staging.TranslationCache`; identical (pattern,
    schedule, template, backend, env) tuples never lower or compile
    twice across working-set loops, repeated runs, and sweeps. Pass
    ``cache=`` to isolate; the default pools work process-wide.
    """

    def __init__(self, pattern_factory: Callable[[Mapping[str, int]], PatternSpec],
                 config: DriverConfig,
                 cache: TranslationCache | None = None):
        if config.param_path not in ("auto", "strided", "gather"):
            raise ValueError(
                f"unknown param_path {config.param_path!r} "
                "(expected 'auto', 'strided', or 'gather')"
            )
        self.factory = pattern_factory
        self.cfg = config
        self.cache = cache if cache is not None else GLOBAL_CACHE

    # -- device pinning ------------------------------------------------------

    def _device(self):
        """The resolved jax device for ``cfg.device`` (None = default).
        Indices wrap modulo the device count so device-axis plans are
        portable to boxes with fewer devices."""
        if self.cfg.device is None:
            return None
        devs = jax.devices()
        return devs[self.cfg.device % len(devs)]

    def _dev_ctx(self):
        """Thread-local default-device scope wrapping every stage of
        this driver (lower, compile, allocate, execute). ``jax.default_
        device`` is a thread-local context, so concurrent backend
        workers pin their groups to distinct devices without fighting
        over process-global state. ``precompile``'s worker threads do
        NOT inherit the caller's context — compile thunks re-enter it
        themselves."""
        dev = self._device()
        return jax.default_device(dev) if dev is not None \
            else contextlib.nullcontext()

    # -- construction -------------------------------------------------------

    def _templated(
        self, env: Mapping[str, int]
    ) -> tuple[PatternSpec, Schedule, tuple[str, ...]]:
        """Apply the driver template: (pattern, schedule, grid_bands)."""
        cfg = self.cfg
        base = self.factory(env)
        sch = cfg.schedule or identity()
        if cfg.template == "independent":
            pat = independent_view(base, cfg.programs, cfg.pad)
            grid_bands = ("p",) + tuple(cfg.grid_bands or ())
        elif cfg.template == "unified":
            pat = base
            sch = unified_program_schedule(base, cfg.programs, env, sch)
            grid_bands = ("prog",) + tuple(cfg.grid_bands or ())
        else:
            raise ValueError(cfg.template)
        return pat, sch, grid_bands

    def lower(self, env: Mapping[str, int]) -> Lowered:
        """Stage 1: apply the driver template and resolve access plans.

        Note the ``independent`` template treats the caller's ``n`` as
        the *per-program* row extent (mirroring the paper's
        ``int N = n/t`` macro): callers pass per-program ``n`` and every
        space grows a leading ``programs`` axis of such rows.
        """
        cfg = self.cfg
        env = dict(env)
        pat, sch, grid_bands = self._templated(env)
        with self._dev_ctx():
            return stage_lower(
                pat, sch, env, cfg.backend,
                grid_bands=grid_bands if cfg.backend == "pallas" else None,
                device=cfg.device, cache=self.cache,
            )

    def lower_parametric(self, cap_env: Mapping[str, int],
                         params: tuple[str, ...] = ("n",),
                         param_path: str | None = None,
                         chunk: "int | tuple | None" = None,
                         assume_full: bool = False) -> ParamLowered:
        """Stage 1, shape-polymorphic: one artifact for a whole ladder,
        capacity-allocated at ``cap_env``.

        ``param_path``/``chunk``/``assume_full`` are the ladder-resolved
        regime — ``prepare``/``validate_parametric`` compute them from
        the concrete envs (including the per-env window-bounds check) so
        cache keys are deterministic per ladder. A direct call without
        ``param_path`` gets the **gather** regime: only ladder
        resolution can prove the strided windows safe for the rungs the
        caller intends to run, so the capacity-only entry point defaults
        to the regime that is safe at every admitted env. (The pallas
        backend has no gather regime, so a direct call without
        ``param_path='strided'`` raises ``SymbolicLowerError`` there.)
        """
        pat, sch, _ = self._templated(cap_env)
        with self._dev_ctx():
            return stage_lower_parametric(
                pat, sch, cap_env, params, self.cfg.backend,
                param_path=param_path or "gather", chunk=chunk,
                assume_full=assume_full, device=self.cfg.device,
                cache=self.cache
            )

    def _resolve_param_path(
        self, envs: Sequence[Mapping[str, int]],
        cap_env: Mapping[str, int],
    ) -> "tuple[str, int | tuple | None, bool]":
        """The concrete regime a viable ladder runs, as ``(path, chunk,
        assume_full)``: the config's preference checked against strided
        eligibility plus the exact per-env window-bounds test (a window
        that could leave the capacity shapes would be silently clamped —
        misaligned — so any such env demotes the whole ladder to
        gather). For strided ladders, ``param_strided_window`` resolves
        the window geometry: a lane-chunk int clamped to the smallest
        rung (1-D nests), or an N-D ``((band, C), ...)`` spec whose
        outer chunks are clamped to the smallest rung's extents (stencil
        nests) — either way buying the mask-free hot emitter wherever
        the ladder's smallest windows stay big."""
        cfg = self.cfg
        if cfg.param_path == "gather":
            if cfg.backend == "pallas":
                raise SymbolicLowerError(
                    "the pallas parametric path has no gather regime; "
                    "ineligible ladders specialize per size"
                )
            return "gather", None, False
        from .codegen import (
            param_strided_in_bounds,
            param_strided_plan,
            param_strided_window,
        )

        pat, sch, _ = self._templated(cap_env)
        pnest = sch.lower_symbolic(pat.domain, ("n",))
        splan = param_strided_plan(pat, pnest)
        if splan is not None:
            chunk, full = param_strided_window(pnest, splan, list(envs),
                                               cap_env)
            if all(param_strided_in_bounds(pat, pnest, splan, e, cap_env,
                                           chunk)
                   for e in envs):
                return "strided", chunk, full
        if cfg.param_path == "strided" or cfg.backend == "pallas":
            want = ("param_path='strided'" if cfg.param_path == "strided"
                    else "the pallas parametric path is strided-only")
            raise SymbolicLowerError(
                f"{want} but the ladder is not strided-eligible under "
                f"{cfg.template}/{(cfg.schedule or identity()).name}"
            )
        return "gather", None, False

    def _parametric_viable(self, envs: Sequence[Mapping[str, int]],
                           cap_env: Mapping[str, int]) -> bool:
        """Pre-flight (outside the cache, so failed probes never count as
        misses): the schedule must lower symbolically, every ladder point
        must satisfy the divisibility constraints, and the pattern
        factory must be structurally env-independent (one executable can
        only serve the ladder if every point shares its structure)."""
        cfg = self.cfg
        if cfg.backend not in ("jax", "pallas"):
            return False
        if cfg.donate is False:
            return False  # parametric executables are always donated
        # only the "n" param stays symbolic: points that disagree on any
        # *other* env entry cannot share one executable
        rest = {tuple(sorted((k, v) for k, v in e.items() if k != "n"))
                for e in envs}
        if len(rest) > 1:
            return False
        try:
            pat, sch, _ = self._templated(cap_env)
            if pat.kernel is not None:
                return False  # custom kernels bake env into the step
            pnest = sch.lower_symbolic(pat.domain, ("n",))
        except SymbolicLowerError:
            return False
        if not all(pnest.admits(e) for e in envs):
            return False
        from .codegen import _GATHER_POINT_CAP

        cap_pts = 1
        for e in pnest.band_extents:
            cap_pts *= max(0, e.eval(cap_env))
        if cap_pts > _GATHER_POINT_CAP:
            return False  # capacity too large to stage; specialize instead
        try:
            # every point's arrays must fit the capacity allocation
            cap_shapes = {s.name: s.concrete_shape(cap_env)
                          for s in pat.spaces}
            for e in envs:
                for s in pat.spaces:
                    if any(g > c for g, c in zip(s.concrete_shape(e),
                                                 cap_shapes[s.name])):
                        return False
            cap_fp = fingerprint_pattern(pat)
            for e in envs:
                if fingerprint_pattern(self._templated(e)[0]) != cap_fp:
                    return False
        except (KeyError, ValueError, TypeError, ArithmeticError,
                SymbolicLowerError):
            # expected shape-probe outcomes (missing env symbol, invalid
            # extent arithmetic, unfingerprintable structure): the ladder
            # simply is not parametric. Anything else is a real fault and
            # propagates to the resilience layer instead of being
            # silently swallowed as "specialize".
            return False
        return True

    def _failure_context(self, env: Mapping[str, int] | None = None) -> dict:
        """Diagnosable payload for taxonomy wrappers: pattern, schedule,
        template, backend, env — enough to reproduce the fault from the
        record alone."""
        cfg = self.cfg
        ctx = {
            "template": cfg.template,
            "schedule": (cfg.schedule or identity()).name,
            "backend": cfg.backend,
            "programs": cfg.programs,
        }
        if env is not None:
            ctx["env"] = dict(env)
            try:
                ctx["pattern"] = self.factory(dict(env)).name
            except Exception:
                pass  # the factory itself may be the fault
        return ctx

    def _preflight(self, pat: PatternSpec, alloc_env: Mapping[str, int]) -> None:
        """Working-set pre-flight: refuse allocations that would blow the
        capacity budget — a structured ``CapacityRefused`` instead of an
        OOM kill. ``alloc_env`` is the env the arrays are materialized
        at (the ladder capacity on the parametric path, the point's own
        env specialized — which is why demoting parametric→specialized
        can rescue the smaller rungs of a refused ladder)."""
        budget = (self.cfg.capacity_budget_bytes
                  if self.cfg.capacity_budget_bytes is not None
                  else default_capacity_budget())
        if budget is None:
            return
        ws = sum(
            int(np.prod(s.concrete_shape(alloc_env)))
            * np.dtype(s.dtype).itemsize
            for s in pat.spaces
        )
        need = 2 * ws  # seed tuple + output buffers live simultaneously
        if need > budget:
            raise CapacityRefused(
                f"refusing allocation: working set {ws} bytes (x2 for "
                f"in/out buffers = {need}) exceeds the capacity budget "
                f"of {budget} bytes at n={alloc_env.get('n')}",
                context={**self._failure_context(alloc_env),
                         "pattern": pat.name,
                         "working_set_bytes": int(ws),
                         "required_bytes": int(need),
                         "budget_bytes": int(budget)})

    def build(self, env: Mapping[str, int]):
        """Stage 1+2 plus initial arrays.

        Returns ``(pattern, schedule, env, compiled, arrays0, names)``;
        ``compiled(tup)`` executes ``ntimes`` sweeps under the configured
        barrier regime on a tuple of arrays ordered by ``names``.
        """
        cfg = self.cfg
        lowered = self.lower(env)
        with self._dev_ctx():
            compiled = lowered.compile(
                ntimes=cfg.ntimes, sync_every_rep=cfg.sync_every_rep,
                cache=self.cache,
            )
            pat = lowered.pattern
            arrays0 = {k: jnp.asarray(v)
                       for k, v in pat.allocate(lowered.env).items()}
        names = compiled.names
        return (pat, lowered.schedule, lowered.env, compiled,
                tuple(arrays0[k] for k in names), names)

    @staticmethod
    def _point_envs(points: "Sequence[int | Mapping[str, int]]",
                    env_extra: Mapping[str, int] | None) -> list[dict]:
        """Normalize measurement points to env dicts: a bare int is the
        working set ``n`` (the ladder form); a mapping is a full env
        point (the plan-engine form, any env axes)."""
        envs = []
        for p in points:
            if isinstance(p, Mapping):
                e = {str(k): int(v) for k, v in p.items()}
            else:
                e = {"n": int(p)}
            e.update({str(k): int(v) for k, v in (env_extra or {}).items()})
            envs.append(e)
        return envs

    def prepare(self, working_sets: "Sequence[int | Mapping[str, int]]",
                env_extra: Mapping[str, int] | None = None,
                parallel: bool = True) -> list[Prepared]:
        """Stage all measurement points (ints = working sets, mappings =
        full env points).

        Parametric path (``cfg.parametric``): the whole ladder maps onto
        ONE ``ParamLowered``/``ParamCompiled`` pair keyed at the ladder's
        capacity (max n) — the first point pays the single lower+compile,
        the rest are cache hits, and ``run`` passes each point's ``n`` at
        call time. Specialized path: lower serially (cheap, GIL-bound),
        then AOT-compile the points concurrently (XLA releases the GIL).
        """
        cfg = self.cfg
        envs = self._point_envs(working_sets, env_extra)
        # "auto" only shares when there is a ladder to share across: a
        # single-point run gains nothing from the parametric regime and
        # would pay its chunked-gather overhead for free, so it keeps the
        # specialized fast path. parametric=True still forces sharing.
        want_parametric = cfg.parametric and not (
            cfg.parametric == "auto" and len({e["n"] for e in envs}) < 2
        )
        if want_parametric:
            cap_env = max(envs, key=lambda e: e["n"])
            resolved = None
            if self._parametric_viable(envs, cap_env):
                try:
                    # single resolution pass: a forced-strided ladder
                    # that is not window-safe raises here and falls
                    # through to specialization (or re-raises under
                    # parametric=True)
                    resolved = self._resolve_param_path(envs, cap_env)
                except SymbolicLowerError:
                    resolved = None
            if resolved is not None:
                path, chunk, full = resolved
                preps = []
                for env in envs:
                    try:
                        lw = self.lower_parametric(
                            cap_env, param_path=path, chunk=chunk,
                            assume_full=full)
                    except (BenchFailure, SymbolicLowerError):
                        raise
                    except Exception as e:
                        raise LowerFailure(
                            f"{type(e).__name__}: {e}",
                            context=self._failure_context(cap_env),
                            cause=e) from e
                    try:
                        with self._dev_ctx():
                            c = lw.compile(
                                ntimes=cfg.ntimes,
                                sync_every_rep=cfg.sync_every_rep,
                                cache=self.cache,
                            )
                    except BenchFailure:
                        raise
                    except Exception as e:
                        raise CompileFailure(
                            f"{type(e).__name__}: {e}",
                            context=self._failure_context(cap_env),
                            cause=e) from e
                    preps.append(Prepared(env=env, lowered=lw, compiled=c))
                return preps
            if cfg.parametric is True:
                raise SymbolicLowerError(
                    f"parametric=True but the ladder {list(working_sets)} "
                    f"cannot share one executable under {cfg.template}/"
                    f"{(cfg.schedule or identity()).name}"
                )
        lowereds = []
        for env in envs:
            try:
                lowereds.append((env, self.lower(env)))
            except (BenchFailure, SymbolicLowerError):
                raise
            except Exception as e:
                raise LowerFailure(
                    f"{type(e).__name__}: {e}",
                    context=self._failure_context(env), cause=e) from e
        # measurement executables donate their buffers (no per-call
        # working-set-sized copy — the same copy-free economics as the
        # parametric path, so strided-vs-specialized comparisons are
        # fair on both sides); Prepared.executable() threads the
        # consumed tuples. This holds for pallas too: input_output_
        # aliases covers the kernel-internal aliasing, donation closes
        # the remaining jit-boundary copy. donate=False (the last
        # demotion rung) forces per-call copies everywhere.
        donate = (cfg.backend in ("jax", "pallas")) if cfg.donate is None \
            else bool(cfg.donate)

        def _compile_thunk(lw, env):
            def thunk():
                try:
                    # re-enter the device scope: precompile runs thunks
                    # in worker threads, which do not inherit the
                    # caller's thread-local default device
                    with self._dev_ctx():
                        return lw.compile(
                            ntimes=cfg.ntimes,
                            sync_every_rep=cfg.sync_every_rep,
                            donate=donate, cache=self.cache,
                        )
                except BenchFailure:
                    raise
                except Exception as e:
                    raise CompileFailure(
                        f"{type(e).__name__}: {e}",
                        context=self._failure_context(env), cause=e) from e
            return thunk

        thunks = [_compile_thunk(lw, env) for env, lw in lowereds]
        compiled = (precompile(thunks) if parallel
                    else [t() for t in thunks])
        return [
            Prepared(env=env, lowered=lw, compiled=c)
            for (env, lw), c in zip(lowereds, compiled)
        ]

    # -- validation (the <kernel>_val.in stage) ------------------------------

    def validate(self, env: Mapping[str, int] | None = None) -> None:
        """Replay the run schedule against the numpy oracle.

        Memoized per lowered key: a sweep validates each variant once,
        not once per working set / per repeated call.
        """
        cfg = self.cfg
        n = cfg.validate_n or 64
        env = dict(env or {"n": n})
        lowered = self.lower(env)
        vkey = ("validate", lowered.key) if lowered.key is not None else None
        if vkey is not None and self.cache.was_validated(vkey):
            return
        pat, sch, env2 = lowered.pattern, lowered.schedule, lowered.env
        arrays = pat.allocate(env2)
        want = serial_oracle(pat, lowered.nest, arrays, env2, ntimes=2)
        with self._dev_ctx():
            got = {k: jnp.asarray(v) for k, v in arrays.items()}
            for _ in range(2):
                got = lowered.step(got)
        for k in want:
            np.testing.assert_allclose(
                np.asarray(got[k]), want[k], rtol=1e-5, atol=1e-5,
                err_msg=f"space {k} diverged under {sch.name}/{cfg.template}",
            )
        if vkey is not None:
            self.cache.mark_validated(vkey)

    # -- measurement ---------------------------------------------------------

    def measure_point(self, p: Prepared) -> Record:
        """Measure ONE staged point — the per-point isolation unit the
        plan engine wraps (a fault here fails this point, not the
        group). Runs the working-set pre-flight, times under the
        configured quality policy, and stamps ``extra.timing_quality``
        on the record."""
        cfg = self.cfg
        pat, env = p.lowered.pattern, p.env
        # Parametric points allocate at the shared capacity env (the
        # executable's static shapes); the kernel only touches the
        # [0, n) region, and all *accounting* below uses the actual
        # per-point env so records match the specialized path.
        self._preflight(pat, p.lowered.env)
        dev = self._device()
        try:
            with self._dev_ctx():
                arrays0 = {
                    k: jnp.asarray(v)
                    for k, v in pat.allocate(p.lowered.env).items()
                }
                tup = tuple(arrays0[k] for k in p.compiled.names)
                timing = time_fn(
                    p.executable(), tup, reps=cfg.reps, warmup=1,
                    compile_seconds=p.compiled.compile_seconds,
                    target_cv=cfg.target_cv, max_reps=cfg.max_reps,
                    budget_s=cfg.time_budget_s,
                )
        except BudgetExceeded as e:
            for k, v in self._failure_context(env).items():
                e.context.setdefault(k, v)
            raise
        pts = pat.domain.point_count(env)
        bpp = pat.bytes_per_point()
        total_bytes = bpp * pts * cfg.ntimes
        mix_extra: dict = {}
        if pat.mix is not None:
            # multi-pattern mixes: the statement accounts the primary
            # component only; total traffic is every component's bytes,
            # and the per-component split rides into extra["mix"]
            comps = [dict(c) for c in pat.mix["components"]]
            total_bytes = sum(c["bytes"] for c in comps) * cfg.ntimes
            mix_extra = {"mix": {"primary": pat.mix["primary"],
                                 "components": comps}}
        ws_bytes = sum(
            int(np.prod(s.concrete_shape(env)))
            * np.dtype(s.dtype).itemsize
            for s in pat.spaces
        )
        rec = Record(
            pattern=pat.name,
            template=cfg.template,
            schedule=p.lowered.schedule.name,
            backend=cfg.backend,
            n=int(env["n"]),
            working_set_bytes=ws_bytes,
            programs=cfg.programs,
            ntimes=cfg.ntimes,
            seconds=timing.seconds,
            gbs=total_bytes / timing.seconds / 1e9,
            gflops=pat.flops_per_point * pts * cfg.ntimes
            / timing.seconds / 1e9,
            level=classify_level(ws_bytes),
            extra={
                "barrier": cfg.sync_every_rep,
                "points": int(pts),
                "compile_seconds": p.compiled.compile_seconds,
                "lower_seconds": p.lowered.lower_seconds,
                "cache_hit": p.compiled.from_cache,
                "parametric": p.parametric,
                "param_path": (p.compiled.param_path if p.parametric
                               else "specialized"),
                "donated": bool(getattr(p.compiled, "donated", True)),
                "timing_quality": timing.quality(),
                **({"device": {"axis": int(cfg.device),
                               "id": int(dev.id),
                               "platform": str(dev.platform)}}
                   if dev is not None else {}),
                **({"pallas_mode": p.lowered.pallas_mode}
                   if cfg.backend == "pallas" else {}),
                **({"derived": dict(pat.derived)}
                   if pat.derived is not None else {}),
                **({"trace": dict(pat.trace)}
                   if pat.trace is not None else {}),
                **mix_extra,
                **({"capacity": int(p.lowered.cap_env["n"]),
                    "param_window_rank": int(
                        p.compiled.param_window_rank)}
                   if p.parametric else {}),
            },
        )
        if cfg.measured:
            rec.extra.update(hlo_counters(p.compiled))
            rec.extra.update(self._traffic(pat, env).as_dict())
        return rec

    def run(self, working_sets: "Sequence[int | Mapping[str, int]]",
            env_extra: Mapping[str, int] | None = None) -> list[Record]:
        return [self.measure_point(p)
                for p in self.prepare(working_sets, env_extra)]

    def validate_parametric(self,
                            working_sets: "Sequence[int | Mapping[str, int]]",
                            env_extra: Mapping[str, int] | None = None,
                            max_check_n: int | None = None) -> None:
        """Check the ladder-shared executable point-by-point against the
        specialized serial oracle: for a working set, the [0, n)
        region of the parametric result must match the oracle run at
        exactly that n (the paper's ``<kernel>_val.in`` stage, replayed
        for the shape-polymorphic path).

        The executable is built at the ladder's true capacity, but
        ``max_check_n`` bounds which points are oracle-replayed (the
        serial oracle's point-loop fallback is O(points) Python); the
        smallest point is always checked. Memoized per (ladder key,
        checked points) like :meth:`validate`.
        """
        cfg = self.cfg
        envs = self._point_envs(working_sets, env_extra)
        cap_env = max(envs, key=lambda e: e["n"])
        if not self._parametric_viable(envs, cap_env):
            raise SymbolicLowerError(
                f"ladder {list(working_sets)} is not parametric under "
                f"{cfg.template}"
            )
        path, chunk, full = self._resolve_param_path(envs, cap_env)
        if max_check_n is not None:
            lo = min(envs, key=lambda e: e["n"])
            envs = [e for e in envs if e["n"] <= max_check_n] or [lo]
        lw = self.lower_parametric(cap_env, param_path=path, chunk=chunk,
                                   assume_full=full)
        vkey = None
        if lw.key is not None:
            vkey = ("pvalidate", lw.key,
                    tuple(sorted(e["n"] for e in envs)))
            if self.cache.was_validated(vkey):
                return
        pat = lw.pattern
        cap_arrays = pat.allocate(cap_env)
        for env in envs:
            pvals = tuple(np.int32(env[p]) for p in lw.params)
            with self._dev_ctx():
                got = {k: jnp.asarray(v) for k, v in cap_arrays.items()}
                for _ in range(2):
                    got = lw.step(got, pvals)
            spec = self.lower(env)
            want = serial_oracle(
                spec.pattern, spec.nest, spec.pattern.allocate(env), env,
                ntimes=2,
            )
            for k in want:
                region = tuple(
                    slice(0, d) for d in pat.space(k).concrete_shape(env)
                )
                np.testing.assert_allclose(
                    np.asarray(got[k])[region], want[k],
                    rtol=1e-5, atol=1e-5,
                    err_msg=(
                        f"space {k} diverged on the parametric path at "
                        f"n={env['n']} (capacity {cap_env['n']})"
                    ),
                )
        if vkey is not None:
            self.cache.mark_validated(vkey)

    def _traffic(self, pat: PatternSpec, env: Mapping[str, int]):
        """Analytic tile traffic for the current template split (1D)."""
        cfg = self.cfg
        written = pat.statement.write.space
        slices: list[dict[str, tuple[int, int]]] = []
        if cfg.template == "independent":
            # rows are (n + pad) apart in the flat layout
            row = Affine.of(pat.space(written).shape[1]).eval(env)
            per = pat.domain.dims[1].extent(env)
            lo0 = pat.domain.dims[1].lo.eval(env)
            for p in range(cfg.programs):
                flat0 = p * row + lo0
                slices.append(
                    {s.name: (flat0, flat0 + per) for s in pat.spaces}
                )
        else:
            d0 = pat.domain.dims[0]
            lo, ext = d0.lo.eval(env), d0.extent(env)
            chunk = ext // cfg.programs
            for p in range(cfg.programs):
                a = lo + p * chunk
                slices.append({s.name: (a, a + chunk) for s in pat.spaces})
        return tile_traffic(
            spaces={s.name: s.concrete_shape(env) for s in pat.spaces},
            program_slices=slices,
            written=written,
            itemsize=np.dtype(pat.space(written).dtype).itemsize,
        )
