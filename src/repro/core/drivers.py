"""Benchmark driver templates — the kernel-independent layer.

The paper ships three driver templates; each has a direct analogue here:

* **Unified data spaces** (Listing 1): threads share one array through
  OpenMP work-sharing. Here: one array per data space; parallel "programs"
  are carved out of the iteration domain by tiling its outermost dim into
  ``programs`` contiguous chunks (exactly ``schedule(static, n/t)``). The
  chunks share native tiles at their seams — the false-sharing analogue.

* **Independent data spaces** (Listing 2): each thread owns a disjoint
  array. Here: every space gains a leading ``programs`` axis whose rows
  are optionally padded to the native tile (``pad`` elements), and the
  statement is rewritten to index through the program id — the exact
  transformation the paper performs in the memory-mapping macros
  (``A[t_id*8][i]``).

* **PAPI measurement** (template 3): ``measured=True`` attaches
  ``hlo_counters`` + analytic ``tile_traffic`` to every record.

A driver owns the repetition loop. ``sync_every_rep=False`` fuses all
``ntimes`` sweeps into one compiled ``lax.fori_loop`` — the ``nowait``
analogue (no host round-trip / no dispatch barrier between sweeps);
``True`` dispatches one sweep per call and fences, reproducing the
per-iteration barrier of Listing 1.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from .codegen import lower_jax, lower_pallas, serial_oracle
from .domain import Affine, Dim, IterDomain
from .measure import (
    Record,
    classify_level,
    hlo_counters,
    tile_traffic,
    time_fn,
)
from .pattern import Access, DataSpace, PatternSpec, Statement
from .schedule import Schedule, identity

__all__ = [
    "DriverConfig",
    "Driver",
    "independent_view",
    "unified_program_schedule",
]


# ---------------------------------------------------------------------------
# Template transformations
# ---------------------------------------------------------------------------


def independent_view(pattern: PatternSpec, programs: int, pad: int = 0) -> PatternSpec:
    """Rewrite a pattern to the *independent data spaces* form.

    Every space of shape ``(n, ...)`` becomes ``(programs, n/programs + pad,
    ...)`` (the caller passes the *per-program* ``n`` in env — mirroring the
    paper's ``int N = n/t``); a new outermost iterator ``p`` runs over
    programs and all accesses are prefixed with it. ``pad`` is the paper's
    padding factor (8 doubles -> one 64B line; here pad to the 1024-element
    native tile with ``pad=tile-remainder`` or any nonzero slack).
    """
    p = "p"
    if p in pattern.domain.names:
        raise ValueError("pattern already has a 'p' iterator")

    def pad_shape(shape):
        first = Affine.of(shape[0]) + pad
        return (Affine.of(programs), first) + tuple(shape[1:])

    def pad_init(init):
        if not callable(init):
            return init
        # per-row init: drop the program grid, apply the original to the rest
        return lambda pgrid, *grids: init(*grids)

    spaces = tuple(
        dataclasses.replace(s, shape=pad_shape(s.shape), init=pad_init(s.init))
        for s in pattern.spaces
    )

    def prefix(acc: Access) -> Access:
        return Access(acc.space, (p,) + tuple(acc.index))

    stmt = Statement(
        reads=tuple(prefix(a) for a in pattern.statement.reads),
        write=prefix(pattern.statement.write),
        combine=pattern.statement.combine,
    )
    dom = IterDomain((Dim.of(p, 0, programs),) + pattern.domain.dims)
    return dataclasses.replace(
        pattern,
        name=f"{pattern.name}.indep{programs}" + (f".pad{pad}" if pad else ""),
        spaces=spaces,
        statement=stmt,
        domain=dom,
    )


def unified_program_schedule(
    pattern: PatternSpec, programs: int, env: Mapping[str, int],
    base: Schedule | None = None,
) -> Schedule:
    """Tile the outermost domain dim into ``programs`` chunks — the
    ``schedule(static, n/t)`` work-sharing split of the unified template."""
    sch = base or identity()
    if programs == 1:
        return sch  # no work-sharing split needed
    d0 = pattern.domain.dims[0]
    extent = d0.extent(env)
    if extent % programs != 0:
        raise ValueError(
            f"unified template needs programs | extent ({programs} vs {extent})"
        )
    return sch.tile(d0.name, extent // programs, outer="prog", inner=d0.name)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DriverConfig:
    template: str = "unified"       # unified | independent
    programs: int = 8               # "threads"
    pad: int = 0                    # independent-template row padding (elems)
    backend: str = "jax"            # jax | pallas
    schedule: Schedule | None = None  # extra transforms (applied to the kernel dims)
    ntimes: int = 50                # sweeps per measurement
    sync_every_rep: bool = False    # True = per-sweep barrier (Listing 1)
    reps: int = 5                   # timing repetitions (median)
    measured: bool = False          # attach counter surrogates (template 3)
    grid_bands: tuple[str, ...] | None = None  # pallas grid override
    validate_n: int | None = 64     # oracle-check size (None = skip)


class Driver:
    """Combine a PatternSpec with a driver template and measure it.

    ``pattern_factory(env)`` lets stream-count-style sweeps rebuild the
    pattern per point; for fixed patterns pass ``lambda env: pat``.
    """

    def __init__(self, pattern_factory: Callable[[Mapping[str, int]], PatternSpec],
                 config: DriverConfig):
        self.factory = pattern_factory
        self.cfg = config

    # -- construction -------------------------------------------------------

    def _materialize(self, env: Mapping[str, int]):
        cfg = self.cfg
        base = self.factory(env)
        sch = cfg.schedule or identity()
        if cfg.template == "independent":
            pat = independent_view(base, cfg.programs, cfg.pad)
            # per-program env: the caller's n is global; rows get n/programs
            env = dict(env)
            for k in ("n",):
                if k in env and base.domain.dims[0].hi.symbols == (k,):
                    pass
            grid_bands = ("p",) + tuple(cfg.grid_bands or ())
        elif cfg.template == "unified":
            pat = base
            sch = unified_program_schedule(base, cfg.programs, env, sch)
            grid_bands = ("prog",) + tuple(cfg.grid_bands or ())
        else:
            raise ValueError(cfg.template)

        if cfg.backend == "jax":
            step = lower_jax(pat, sch, env)
        elif cfg.backend == "pallas":
            step = lower_pallas(pat, sch, env, grid_bands=grid_bands)
        else:
            raise ValueError(cfg.backend)
        return pat, sch, env, step

    def build(self, env: Mapping[str, int]):
        """Returns (pattern, schedule, run_fn, arrays0). ``run_fn(arrays)``
        executes ``ntimes`` sweeps under the configured barrier regime."""
        cfg = self.cfg
        pat, sch, env, step = self._materialize(env)
        arrays0 = {k: jnp.asarray(v) for k, v in pat.allocate(env).items()}
        names = sorted(arrays0)

        def step_t(tup):
            d = dict(zip(names, tup))
            d = step(d)
            return tuple(d[k] for k in names)

        if cfg.sync_every_rep:
            one = jax.jit(step_t)

            def run(tup):
                for _ in range(cfg.ntimes):
                    tup = one(tup)
                    jax.block_until_ready(tup)
                return tup

            lowerable = one
        else:
            @jax.jit
            def run(tup):
                return jax.lax.fori_loop(
                    0, cfg.ntimes, lambda _, t: step_t(t), tup
                )

            lowerable = run

        return pat, sch, env, run, lowerable, tuple(arrays0[k] for k in names), names

    # -- validation (the <kernel>_val.in stage) ------------------------------

    def validate(self, env: Mapping[str, int] | None = None) -> None:
        cfg = self.cfg
        n = cfg.validate_n or 64
        env = dict(env or {"n": n})
        pat, sch, env2, step = self._materialize(env)
        arrays = pat.allocate(env2)
        nest = sch.lower(pat.domain, env2)
        want = serial_oracle(pat, nest, arrays, env2, ntimes=2)
        got = {k: jnp.asarray(v) for k, v in arrays.items()}
        for _ in range(2):
            got = step(got)
        for k in want:
            np.testing.assert_allclose(
                np.asarray(got[k]), want[k], rtol=1e-5, atol=1e-5,
                err_msg=f"space {k} diverged under {sch.name}/{cfg.template}",
            )

    # -- measurement ---------------------------------------------------------

    def run(self, working_sets: Sequence[int],
            env_extra: Mapping[str, int] | None = None) -> list[Record]:
        cfg = self.cfg
        records = []
        for n in working_sets:
            env = {"n": int(n), **(env_extra or {})}
            pat, sch, env, run, lowerable, tup, names = self.build(env)
            timing = time_fn(run, tup, reps=cfg.reps)
            pts = pat.domain.point_count(env)
            bpp = pat.bytes_per_point()
            total_bytes = bpp * pts * cfg.ntimes
            ws_bytes = sum(
                int(np.prod(s.concrete_shape(env)))
                * np.dtype(s.dtype).itemsize
                for s in pat.spaces
            )
            rec = Record(
                pattern=pat.name,
                template=cfg.template,
                schedule=sch.name,
                backend=cfg.backend,
                n=int(n),
                working_set_bytes=ws_bytes,
                programs=cfg.programs,
                ntimes=cfg.ntimes,
                seconds=timing.seconds,
                gbs=total_bytes / timing.seconds / 1e9,
                gflops=pat.flops_per_point * pts * cfg.ntimes
                / timing.seconds / 1e9,
                level=classify_level(ws_bytes),
                extra={"barrier": cfg.sync_every_rep},
            )
            if cfg.measured:
                rec.extra.update(hlo_counters(lowerable, tup))
                rec.extra.update(self._traffic(pat, env).as_dict())
            records.append(rec)
        return records

    def _traffic(self, pat: PatternSpec, env: Mapping[str, int]):
        """Analytic tile traffic for the current template split (1D)."""
        cfg = self.cfg
        written = pat.statement.write.space
        slices: list[dict[str, tuple[int, int]]] = []
        if cfg.template == "independent":
            # rows are (n + pad) apart in the flat layout
            row = Affine.of(pat.space(written).shape[1]).eval(env)
            per = pat.domain.dims[1].extent(env)
            lo0 = pat.domain.dims[1].lo.eval(env)
            for p in range(cfg.programs):
                flat0 = p * row + lo0
                slices.append(
                    {s.name: (flat0, flat0 + per) for s in pat.spaces}
                )
        else:
            d0 = pat.domain.dims[0]
            lo, ext = d0.lo.eval(env), d0.extent(env)
            chunk = ext // cfg.programs
            for p in range(cfg.programs):
                a = lo + p * chunk
                slices.append({s.name: (a, a + chunk) for s in pat.spaces})
        return tile_traffic(
            spaces={s.name: s.concrete_shape(env) for s in pat.spaces},
            program_slices=slices,
            written=written,
            itemsize=np.dtype(pat.space(written).dtype).itemsize,
        )
