"""Schedule relations — the transformation layer of the polyhedral-lite engine.

In AdaptMemBench, optimization variants are produced by applying relations
to the iteration domain in ISCC (``{[i,j] -> [j,i]}`` for interchange,
block-decompositions for tiling, split+fuse for the paper's interleaving).
Here a :class:`Schedule` is an explicit chain of such relations. Lowering a
schedule against a domain and a parameter environment yields a
:class:`LoweredNest`:

    bands        — the generated loop nest, outermost first; each band is a
                   counter ``0 <= b < extent`` (extent is concrete: params
                   are resolved, as the drivers instantiate one variant per
                   working-set size);
    instances    — one or more statement instances per innermost body (the
                   paper's interleaving fuses several); each instance maps
                   band counters to domain iterators affinely:
                   ``iter = A @ bands + c``.

The mapping to Pallas is direct: *grid bands* become ``pallas_call`` grid
dimensions and the affine instance maps become ``BlockSpec.index_map``
functions; *vector bands* become the block shape. See codegen.py.

Legality: transforms here are bijections on the iteration set (interchange,
reverse, tiling, interleave/unroll with divisibility, skew), so the
multiset of executed points is preserved — property-tested in
tests/test_schedule.py. Dependence legality (whether reordering is *valid*
for a given statement) is the user's responsibility, exactly as in ISCC;
drivers.validate() catches violations numerically, mirroring the paper's
<kernel>_val.in stage.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from .domain import Affine, IterDomain

__all__ = [
    "Schedule",
    "LoweredNest",
    "LoweredInstance",
    "ParamNest",
    "ParamInstance",
    "SymbolicLowerError",
    "identity",
]


class SymbolicLowerError(Exception):
    """A transform genuinely needs concrete extents (e.g. the product of
    two parameter-dependent quantities); callers fall back to per-size
    specialization."""


# ---------------------------------------------------------------------------
# Lowered (concrete) form
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LoweredInstance:
    """iter[d] = sum_b A[d, b] * band[b] + c[d] for each domain dim d."""

    A: tuple[tuple[int, ...], ...]  # (rank_domain, n_bands)
    c: tuple[int, ...]  # (rank_domain,)

    def apply(self, bands: Sequence[int]) -> tuple[int, ...]:
        return tuple(
            int(np.dot(row, bands)) + off for row, off in zip(self.A, self.c)
        )

    def apply_np(self, band_grids: Sequence[np.ndarray]) -> list[np.ndarray]:
        """Vectorized map over broadcastable band index arrays."""
        out = []
        for row, off in zip(self.A, self.c):
            acc = None
            for coeff, g in zip(row, band_grids):
                if coeff == 0:
                    continue
                term = coeff * g
                acc = term if acc is None else acc + term
            base = np.asarray(off) if acc is None else acc + off
            out.append(base)
        return out


@dataclasses.dataclass(frozen=True)
class LoweredNest:
    band_names: tuple[str, ...]
    band_extents: tuple[int, ...]
    instances: tuple[LoweredInstance, ...]
    domain_lo: tuple[int, ...]
    domain_hi: tuple[int, ...]

    @property
    def n_bands(self) -> int:
        return len(self.band_names)

    @property
    def rank(self) -> int:
        return len(self.domain_lo)

    def in_bounds(self, point: Sequence[int]) -> bool:
        return all(
            lo <= p < hi for p, lo, hi in zip(point, self.domain_lo, self.domain_hi)
        )

    def needs_guard(self) -> bool:
        """True if some instance can map a band point outside the domain.

        Checked by interval arithmetic over the band box — conservative and
        exact for affine maps over boxes.
        """
        for inst in self.instances:
            for d in range(self.rank):
                lo = hi = inst.c[d]
                for b, coeff in enumerate(inst.A[d]):
                    if coeff == 0:
                        continue
                    span = coeff * (self.band_extents[b] - 1)
                    lo += min(0, span)
                    hi += max(0, span)
                if lo < self.domain_lo[d] or hi >= self.domain_hi[d]:
                    return True
        return False

    def executed_points(self):
        """Serial enumeration in generated-code order (tests/oracle only)."""
        def rec(i: int, vals: list[int]):
            if i == self.n_bands:
                for inst in self.instances:
                    p = inst.apply(vals)
                    if self.in_bounds(p):
                        yield p
                return
            for v in range(self.band_extents[i]):
                vals.append(v)
                yield from rec(i + 1, vals)
                vals.pop()

        yield from rec(0, [])


# ---------------------------------------------------------------------------
# Parametric (shape-polymorphic) form
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamInstance:
    """Affine instance map whose entries stay symbolic in the params:
    ``iter[d] = sum_b A[d][b] * band[b] + c[d]`` with Affine entries."""

    A: tuple[tuple[Affine, ...], ...]
    c: tuple[Affine, ...]


def _const_int(aff: Affine) -> int | None:
    """The value of a parameter-free integral Affine, else None."""
    if not aff.is_const:
        return None
    v = aff.const
    if isinstance(v, int):
        return v
    return int(v) if getattr(v, "denominator", 1) == 1 else None


def _provably_nonneg(aff: Affine) -> bool:
    """True when ``aff >= 0`` for every admissible environment.

    Sound under the standing assumption that every parameter is a
    nonnegative size: a nonnegative constant plus nonnegative
    coefficients can never go negative. Conservative — expressions like
    ``n - 4`` (true for all measured ladders) are rejected, and the
    caller falls back to the masked-gather regime.
    """
    return aff.const >= 0 and all(c >= 0 for _, c in aff.coeffs)


@dataclasses.dataclass(frozen=True)
class ParamNest:
    """A lowered nest whose band extents (and instance maps) are affine in
    a set of still-symbolic parameters — the shape-polymorphic analogue of
    :class:`LoweredNest`. One ParamNest serves a whole working-set ladder:
    the parametric codegen path turns the symbolic extents into traced
    operands, so a single executable covers every ladder point that
    satisfies ``constraints`` (the divisibility assumptions the symbolic
    transforms made, e.g. ``programs | extent`` for the unified split).
    """

    params: tuple[str, ...]
    band_names: tuple[str, ...]
    band_extents: tuple[Affine, ...]
    instances: tuple[ParamInstance, ...]
    domain_lo: tuple[Affine, ...]
    domain_hi: tuple[Affine, ...]
    constraints: tuple[tuple[Affine, int], ...]  # (expr, d): require d | expr

    @property
    def n_bands(self) -> int:
        return len(self.band_names)

    @property
    def rank(self) -> int:
        return len(self.domain_lo)

    def admits(self, env: Mapping[str, int]) -> bool:
        """True if every divisibility assumption holds for this env."""
        for expr, div in self.constraints:
            try:
                if expr.eval(env) % div != 0:
                    return False
            except (KeyError, ValueError):
                return False
        return True

    # -- strided-eligibility (the parametric fast-path precondition) ---------

    def strided_bands(self) -> "tuple[tuple[tuple[int, int], ...], ...] | None":
        """Per instance, per domain dim: ``(band, stride)`` — the symbolic
        twin of the specialized path's single-band precondition.

        Non-None only when every instance map reads exactly one band per
        domain dim with a *constant integer* stride (no Fraction chunk
        coefficients — those come from splits, which also break the
        one-band shape) and each band feeds at most one dim. This is the
        nest-level half of the dynamic-slice window regime; the access-
        level half (per-access window strides) lives in codegen.
        """
        out = []
        for inst in self.instances:
            rows = []
            used: dict[int, int] = {}
            for d in range(self.rank):
                nz = [(b, _const_int(c)) for b, c in enumerate(inst.A[d])
                      if c != Affine.of(0)]
                if len(nz) != 1:
                    return None
                b, stride = nz[0]
                if stride is None or stride == 0 or b in used:
                    return None
                used[b] = d
                rows.append((b, stride))
            out.append(tuple(rows))
        return tuple(out)

    def window_spans(self) -> "tuple[tuple[tuple[Affine, Affine], ...], ...] | None":
        """Per instance, per dim: symbolic ``(lo, hi)`` index span over the
        band box (inclusive), as Affines in the params. None when the
        nest is not single-band (see :meth:`strided_bands`)."""
        bands = self.strided_bands()
        if bands is None:
            return None
        spans = []
        for inst, rows in zip(self.instances, bands):
            per_dim = []
            for d, (b, stride) in enumerate(rows):
                span = (self.band_extents[b] - 1) * stride
                lo = inst.c[d] + (span if stride < 0 else 0)
                hi = inst.c[d] + (span if stride > 0 else 0)
                per_dim.append((lo, hi))
            spans.append(tuple(per_dim))
        return tuple(spans)

    def strided_eligible(self) -> bool:
        """True when every instance is single-band with constant integer
        strides AND the nest is *provably* unguarded: each instance's
        symbolic index span stays inside the domain for every admissible
        env (checked with the conservative nonnegativity test — a span
        the test cannot prove in bounds falls back to the gather regime,
        never the other way around)."""
        spans = self.window_spans()
        if spans is None:
            return False
        for per_dim in spans:
            for d, (lo, hi) in enumerate(per_dim):
                if not _provably_nonneg(lo - self.domain_lo[d]):
                    return False
                if not _provably_nonneg(self.domain_hi[d] - 1 - hi):
                    return False
        return True

    def concretize(self, env: Mapping[str, int]) -> LoweredNest:
        """Evaluate at a concrete env — must equal ``schedule.lower``."""
        if not self.admits(env):
            raise ValueError(f"env {dict(env)!r} violates {self.constraints}")
        return LoweredNest(
            band_names=self.band_names,
            band_extents=tuple(max(0, e.eval(env)) for e in self.band_extents),
            instances=tuple(
                LoweredInstance(
                    tuple(tuple(a.eval(env) for a in row) for row in inst.A),
                    tuple(c.eval(env) for c in inst.c),
                )
                for inst in self.instances
            ),
            domain_lo=tuple(lo.eval(env) for lo in self.domain_lo),
            domain_hi=tuple(hi.eval(env) for hi in self.domain_hi),
        )


def _affine_mul(a: Affine, b: Affine) -> Affine:
    """Product of two affine expressions; affine only when one is const."""
    if a.is_const:
        return b * a.const
    if b.is_const:
        return a * b.const
    raise SymbolicLowerError(
        f"product of two parameter-dependent quantities ({a!r} * {b!r})"
    )


# ---------------------------------------------------------------------------
# Transform records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Interchange:
    a: str
    b: str


@dataclasses.dataclass(frozen=True)
class _Tile:
    dim: str
    size: int
    # names for the generated bands; default <dim>_T (outer) / <dim>_t (inner)
    outer: str | None = None
    inner: str | None = None


@dataclasses.dataclass(frozen=True)
class _TileByCount:
    """Split ``dim`` into exactly ``count`` equal chunks (outer extent =
    count, inner extent = E/count). Requires count | extent — the unified
    template's ``schedule(static, n/t)`` work-sharing split. Unlike
    ``_Tile`` the *count* is the static knob, so the split stays affine in
    a symbolic extent (chunk length becomes a rational coefficient)."""

    dim: str
    count: int
    outer: str | None = None
    inner: str | None = None


@dataclasses.dataclass(frozen=True)
class _Interleave:
    """The paper's triad optimization: split ``dim`` into ``factor``
    equal blocks and *fuse* them into one body — instance k touches
    ``lo + k*(E/factor) + b``. Requires extent % factor == 0."""

    dim: str
    factor: int


@dataclasses.dataclass(frozen=True)
class _Unroll:
    """Cyclic split-and-fuse: instance k touches ``lo + factor*b + k``."""

    dim: str
    factor: int


@dataclasses.dataclass(frozen=True)
class _Reverse:
    dim: str


@dataclasses.dataclass(frozen=True)
class _Skew:
    target: str
    source: str
    factor: int


_Transform = (_Interchange | _Tile | _TileByCount | _Interleave | _Unroll
              | _Reverse | _Skew)


# ---------------------------------------------------------------------------
# Schedule builder
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Schedule:
    """An immutable chain of schedule relations. Fluent builders return new
    schedules, so variants fork cheaply::

        s = identity().tile("i", 32).interchange("i_T", "j_T")
    """

    transforms: tuple[_Transform, ...] = ()
    name: str = "identity"

    def _push(self, t: _Transform, tag: str) -> "Schedule":
        nm = tag if self.name == "identity" else f"{self.name}.{tag}"
        return Schedule(self.transforms + (t,), nm)

    def interchange(self, a: str, b: str) -> "Schedule":
        return self._push(_Interchange(a, b), f"interchange({a},{b})")

    def tile(self, dim: str, size: int, outer: str | None = None,
             inner: str | None = None) -> "Schedule":
        if size < 1:
            raise ValueError("tile size must be >= 1")
        return self._push(_Tile(dim, size, outer, inner), f"tile({dim},{size})")

    def tile_by_count(self, dim: str, count: int, outer: str | None = None,
                      inner: str | None = None) -> "Schedule":
        if count < 1:
            raise ValueError("tile count must be >= 1")
        return self._push(_TileByCount(dim, count, outer, inner),
                          f"tile_by_count({dim},{count})")

    def interleave(self, dim: str, factor: int) -> "Schedule":
        if factor < 1:
            raise ValueError("interleave factor must be >= 1")
        return self._push(_Interleave(dim, factor), f"interleave({dim},{factor})")

    def unroll(self, dim: str, factor: int) -> "Schedule":
        if factor < 1:
            raise ValueError("unroll factor must be >= 1")
        return self._push(_Unroll(dim, factor), f"unroll({dim},{factor})")

    def reverse(self, dim: str) -> "Schedule":
        return self._push(_Reverse(dim), f"reverse({dim})")

    def skew(self, target: str, source: str, factor: int) -> "Schedule":
        return self._push(_Skew(target, source, factor), f"skew({target},{source},{factor})")

    # -- lowering ----------------------------------------------------------

    @property
    def cache_key(self) -> tuple:
        """Hashable identity used by the staging translation cache."""
        return (self.name, self.transforms)

    def lower(self, dom: IterDomain, env: Mapping[str, int]) -> LoweredNest:
        """Resolve parameters and apply the transform chain (memoized).

        Lowering is pure: (schedule, domain, env) fully determine the
        nest, and every participant is immutable — so repeated lowering
        across driver working-set loops, validation, and sweeps hits a
        process-wide memo instead of re-running the transform chain.
        """
        try:
            key = (self.cache_key, dom, tuple(sorted(env.items())))
            hit = _LOWER_MEMO.get(key)
        except TypeError:
            key = None
            hit = None
        if hit is not None:
            return hit
        nest = self._lower(dom, env)
        if key is not None:
            if len(_LOWER_MEMO) >= _LOWER_MEMO_CAP:
                _LOWER_MEMO.clear()
            _LOWER_MEMO[key] = nest
        return nest

    def _lower(self, dom: IterDomain, env: Mapping[str, int]) -> LoweredNest:
        """Uncached lowering.

        Internal state during lowering: a list of bands
        ``(name, extent:int)`` and a list of instances, each a dict
        ``dim_name -> (coeffs: dict[band_name, int], const: int)``.
        """
        lo = tuple(d.lo.eval(env) for d in dom.dims)
        hi = tuple(d.hi.eval(env) for d in dom.dims)

        bands: list[tuple[str, int]] = []
        inst0: dict[str, tuple[dict[str, int], int]] = {}
        for d, l, h in zip(dom.dims, lo, hi):
            bands.append((d.name, max(0, h - l)))
            inst0[d.name] = ({d.name: 1}, l)
        instances = [inst0]

        def band_index(name: str) -> int:
            for i, (n, _) in enumerate(bands):
                if n == name:
                    return i
            raise KeyError(f"no band named {name!r}; have {[n for n, _ in bands]}")

        for t in self.transforms:
            if isinstance(t, _Interchange):
                ia, ib = band_index(t.a), band_index(t.b)
                bands[ia], bands[ib] = bands[ib], bands[ia]

            elif isinstance(t, _Tile):
                i = band_index(t.dim)
                name, extent = bands[i]
                n_outer = -(-extent // t.size)  # ceil
                outer = t.outer or f"{name}_T"
                inner = t.inner or f"{name}_t"
                bands[i : i + 1] = [(outer, n_outer), (inner, t.size)]
                for inst in instances:
                    for dim, (coeffs, const) in inst.items():
                        c = coeffs.pop(name, 0)
                        if c:
                            coeffs[outer] = coeffs.get(outer, 0) + c * t.size
                            coeffs[inner] = coeffs.get(inner, 0) + c

            elif isinstance(t, _TileByCount):
                i = band_index(t.dim)
                name, extent = bands[i]
                if extent % t.count != 0:
                    raise ValueError(
                        f"tile_by_count({name},{t.count}): extent {extent} "
                        "not divisible (pick a divisible working set)"
                    )
                size = extent // t.count
                outer = t.outer or f"{name}_T"
                inner = t.inner or f"{name}_t"
                bands[i : i + 1] = [(outer, t.count), (inner, size)]
                for inst in instances:
                    for dim, (coeffs, const) in inst.items():
                        c = coeffs.pop(name, 0)
                        if c:
                            coeffs[outer] = coeffs.get(outer, 0) + c * size
                            coeffs[inner] = coeffs.get(inner, 0) + c

            elif isinstance(t, (_Interleave, _Unroll)):
                i = band_index(t.dim)
                name, extent = bands[i]
                f = t.factor
                if extent % f != 0:
                    raise ValueError(
                        f"{type(t).__name__.lstrip('_').lower()}({name},{f}): "
                        f"extent {extent} not divisible"
                    )
                new_extent = extent // f
                bands[i] = (name, new_extent)
                new_instances = []
                for inst in instances:
                    for k in range(f):
                        clone: dict[str, tuple[dict[str, int], int]] = {}
                        for dim, (coeffs, const) in inst.items():
                            c = coeffs.get(name, 0)
                            cf = dict(coeffs)
                            if c:
                                if isinstance(t, _Interleave):
                                    # i -> k*(E/f) + b  (blocked split)
                                    const2 = const + c * k * new_extent
                                else:
                                    # i -> f*b + k      (cyclic split)
                                    cf[name] = c * f
                                    const2 = const + c * k
                            else:
                                const2 = const
                            clone[dim] = (cf, const2)
                        new_instances.append(clone)
                instances = new_instances

            elif isinstance(t, _Reverse):
                i = band_index(t.dim)
                name, extent = bands[i]
                for inst in instances:
                    for dim, (coeffs, const) in inst.items():
                        c = coeffs.get(name, 0)
                        if c:
                            coeffs[name] = -c
                            inst[dim] = (coeffs, const + c * (extent - 1))

            elif isinstance(t, _Skew):
                band_index(t.source)  # existence check
                for inst in instances:
                    coeffs, const = inst[t.target] if t.target in inst else (None, None)
                    if coeffs is None:
                        raise KeyError(f"skew target {t.target!r} is not a domain dim")
                    coeffs[t.source] = coeffs.get(t.source, 0) + t.factor
            else:  # pragma: no cover
                raise TypeError(t)

        band_names = tuple(n for n, _ in bands)
        band_extents = tuple(e for _, e in bands)
        pos = {n: i for i, n in enumerate(band_names)}
        lowered = []
        for inst in instances:
            A = []
            c = []
            for d in dom.dims:
                coeffs, const = inst[d.name]
                row = [0] * len(bands)
                for bn, cf in coeffs.items():
                    if bn in pos:
                        row[pos[bn]] = cf
                    elif cf != 0:
                        raise AssertionError(f"dangling band {bn}")
                A.append(tuple(row))
                c.append(const)
            lowered.append(LoweredInstance(tuple(A), tuple(c)))

        return LoweredNest(band_names, band_extents, tuple(lowered), lo, hi)

    # -- symbolic lowering (shape-polymorphic path) -------------------------

    def lower_symbolic(self, dom: IterDomain,
                       params: tuple[str, ...] = ("n",)) -> ParamNest:
        """Lower keeping ``params`` symbolic: band extents, instance maps,
        and domain bounds stay :class:`Affine` in the params.

        Transforms that split a parameter-dependent extent assume exact
        divisibility and record it in ``ParamNest.constraints`` (checked
        per concrete env by ``admits``); a transform whose result is not
        affine at all (e.g. reversing a band whose extent *and*
        coefficient both depend on a param) raises
        :class:`SymbolicLowerError` and the caller specializes instead.
        Memoized like :meth:`lower`.
        """
        try:
            key = (self.cache_key, dom, tuple(params))
            hit = _SYMBOLIC_MEMO.get(key)
        except TypeError:
            key, hit = None, None
        if hit is not None:
            if isinstance(hit, SymbolicLowerError):
                raise hit
            return hit
        try:
            nest = self._lower_symbolic(dom, tuple(params))
        except SymbolicLowerError as e:
            if key is not None:
                _SYMBOLIC_MEMO[key] = e
            raise
        if key is not None:
            if len(_SYMBOLIC_MEMO) >= _LOWER_MEMO_CAP:
                _SYMBOLIC_MEMO.clear()
            _SYMBOLIC_MEMO[key] = nest
        return nest

    def _lower_symbolic(self, dom: IterDomain,
                        params: tuple[str, ...]) -> ParamNest:
        aff = Affine.of
        lo = tuple(d.lo for d in dom.dims)
        hi = tuple(d.hi for d in dom.dims)

        bands: list[tuple[str, Affine]] = []
        inst0: dict[str, tuple[dict[str, Affine], Affine]] = {}
        for d, l, h in zip(dom.dims, lo, hi):
            bands.append((d.name, h - l))
            inst0[d.name] = ({d.name: aff(1)}, l)
        instances = [inst0]
        constraints: list[tuple[Affine, int]] = []

        def band_index(name: str) -> int:
            for i, (n, _) in enumerate(bands):
                if n == name:
                    return i
            raise KeyError(f"no band named {name!r}; have {[n for n, _ in bands]}")

        def split(i: int, outer_name: str, inner_name: str,
                  count: "Affine", size: "Affine") -> None:
            """Replace band i by (outer: count, inner: size); rewrite
            every instance's use of it as ``outer*size + inner``."""
            name, _ = bands[i]
            bands[i : i + 1] = [(outer_name, count), (inner_name, size)]
            for inst in instances:
                for dim, (coeffs, const) in inst.items():
                    c = coeffs.pop(name, None)
                    if c is not None and c != aff(0):
                        coeffs[outer_name] = (
                            coeffs.get(outer_name, aff(0)) + _affine_mul(c, size)
                        )
                        coeffs[inner_name] = coeffs.get(inner_name, aff(0)) + c

        for t in self.transforms:
            if isinstance(t, _Interchange):
                ia, ib = band_index(t.a), band_index(t.b)
                bands[ia], bands[ib] = bands[ib], bands[ia]

            elif isinstance(t, _Tile):
                i = band_index(t.dim)
                name, extent = bands[i]
                if extent.is_const:
                    n_outer = aff(-(-int(extent.const) // t.size))
                else:
                    # symbolic extent: ceil is not affine — assume (and
                    # record) exact divisibility; indivisible ladder
                    # points fall back to specialization via admits().
                    constraints.append((extent, t.size))
                    n_outer = extent / t.size
                split(i, t.outer or f"{name}_T", t.inner or f"{name}_t",
                      n_outer, aff(t.size))

            elif isinstance(t, _TileByCount):
                i = band_index(t.dim)
                name, extent = bands[i]
                if extent.is_const:
                    if int(extent.const) % t.count != 0:
                        raise ValueError(
                            f"tile_by_count({name},{t.count}): extent "
                            f"{extent.const} not divisible"
                        )
                else:
                    constraints.append((extent, t.count))
                size = extent / t.count
                split(i, t.outer or f"{name}_T", t.inner or f"{name}_t",
                      aff(t.count), size)

            elif isinstance(t, (_Interleave, _Unroll)):
                i = band_index(t.dim)
                name, extent = bands[i]
                f = t.factor
                if extent.is_const:
                    if int(extent.const) % f != 0:
                        raise ValueError(
                            f"{type(t).__name__.lstrip('_').lower()}"
                            f"({name},{f}): extent {extent.const} not divisible"
                        )
                else:
                    constraints.append((extent, f))
                new_extent = extent / f
                bands[i] = (name, new_extent)
                new_instances = []
                for inst in instances:
                    for k in range(f):
                        clone: dict[str, tuple[dict[str, Affine], Affine]] = {}
                        for dim, (coeffs, const) in inst.items():
                            c = coeffs.get(name, aff(0))
                            cf = dict(coeffs)
                            if c != aff(0):
                                if isinstance(t, _Interleave):
                                    const2 = const + _affine_mul(c, new_extent) * k
                                else:
                                    cf[name] = c * f
                                    const2 = const + c * k
                            else:
                                const2 = const
                            clone[dim] = (cf, const2)
                        new_instances.append(clone)
                instances = new_instances

            elif isinstance(t, _Reverse):
                i = band_index(t.dim)
                name, extent = bands[i]
                for inst in instances:
                    for dim, (coeffs, const) in inst.items():
                        c = coeffs.get(name, aff(0))
                        if c != aff(0):
                            coeffs[name] = c * -1
                            inst[dim] = (coeffs,
                                         const + _affine_mul(c, extent - 1))

            elif isinstance(t, _Skew):
                band_index(t.source)
                for inst in instances:
                    if t.target not in inst:
                        raise KeyError(f"skew target {t.target!r} is not a domain dim")
                    coeffs, const = inst[t.target]
                    coeffs[t.source] = coeffs.get(t.source, aff(0)) + t.factor
            else:  # pragma: no cover
                raise TypeError(t)

        band_names = tuple(n for n, _ in bands)
        band_extents = tuple(e for _, e in bands)
        pos = {n: i for i, n in enumerate(band_names)}
        lowered = []
        for inst in instances:
            A = []
            c = []
            for d in dom.dims:
                coeffs, const = inst[d.name]
                row = [aff(0)] * len(bands)
                for bn, cf in coeffs.items():
                    if bn in pos:
                        row[pos[bn]] = cf
                    elif cf != aff(0):
                        raise AssertionError(f"dangling band {bn}")
                A.append(tuple(row))
                c.append(const)
            lowered.append(ParamInstance(tuple(A), tuple(c)))

        exprs = list(band_extents) + list(lo) + list(hi) + [
            a for inst in lowered for row in inst.A for a in row
        ] + [cc for inst in lowered for cc in inst.c]
        stray = {s for e in exprs for s in e.symbols if s not in params}
        if stray:
            raise SymbolicLowerError(
                f"non-parameter symbols {sorted(stray)} survive lowering "
                "(iterator-dependent bounds are not shape-polymorphic)"
            )

        return ParamNest(
            params=params,
            band_names=band_names,
            band_extents=band_extents,
            instances=tuple(lowered),
            domain_lo=lo,
            domain_hi=hi,
            constraints=tuple(constraints),
        )


_LOWER_MEMO: dict = {}
_SYMBOLIC_MEMO: dict = {}
_LOWER_MEMO_CAP = 4096


def identity() -> Schedule:
    return Schedule()
