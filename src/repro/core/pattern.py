"""Pattern specifications — the analogue of the paper's header + ISCC files.

A :class:`PatternSpec` bundles exactly what AdaptMemBench's pattern
specification bundles:

    header (<kernel>.h)       -> DataSpace (allocation) + Access (memory
                                 mapping) + Statement (statement macro)
    <kernel>_init.in          -> DataSpace.init (init schedule is the
                                 identity scan of each space)
    <kernel>_run.in           -> PatternSpec.domain + a Schedule chosen at
                                 driver build time
    <kernel>_val.in           -> drivers.validate() replays the run
                                 schedule serially (numpy oracle) and
                                 compares

Statements are structured (reads/write/combine) rather than free-form C so
that one spec lowers to *both* backends (vectorized JAX and Pallas) and so
bandwidth accounting (bytes per point) is derived, not hand-entered.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

from .domain import Affine, IterDomain, domain

__all__ = [
    "DataSpace",
    "Access",
    "Statement",
    "PatternSpec",
    "mix_patterns",
    "mix_space",
    "triad",
    "stream_copy",
    "stream_scale",
    "stream_sum",
    "nstream",
    "jacobi1d",
    "jacobi2d",
    "jacobi3d",
    "gather",
    "scatter",
    "gather_scatter",
    "pointer_chase",
]


@dataclasses.dataclass(frozen=True)
class DataSpace:
    """One allocated array. ``shape`` entries are params or ints (affine ok)."""

    name: str
    shape: tuple[Affine | int | str, ...]
    dtype: str = "float32"
    init: float | Callable[..., np.ndarray] = 0.0  # scalar or f(*index_grids)

    def concrete_shape(self, env: Mapping[str, int]) -> tuple[int, ...]:
        return tuple(Affine.of(s).eval(env) for s in self.shape)


@dataclasses.dataclass(frozen=True)
class Access:
    """space[index...] where each index is affine in domain iterators."""

    space: str
    index: tuple[Affine | int | str, ...]

    def resolved(self) -> tuple[Affine, ...]:
        return tuple(Affine.of(ix) for ix in self.index)


@dataclasses.dataclass(frozen=True)
class Statement:
    """``write = combine(*reads)`` executed at every domain point.

    ``combine`` receives one jnp/np array per read (already gathered for
    the current set of points) plus the param env as a keyword-free dict
    argument, and must be built from jax.numpy ops so it traces on both
    backends.
    """

    reads: tuple[Access, ...]
    write: Access
    combine: Callable[..., "np.ndarray"]  # combine(vals: list, env: dict)


@dataclasses.dataclass(frozen=True)
class PatternSpec:
    name: str
    spaces: tuple[DataSpace, ...]
    statement: Statement
    domain: IterDomain
    # flops executed per iteration point (for arithmetic-intensity reports)
    flops_per_point: int = 1
    # Serial-dependent patterns (pointer chase) cannot be expressed as an
    # affine statement over a data-parallel domain. ``kernel(pattern,
    # env) -> step(arrays) -> arrays`` replaces the generated jax step
    # wholesale (the schedule must stay the identity — drivers enforce
    # it), and ``oracle(pattern, arrays, env, ntimes) -> arrays`` is its
    # numpy ground truth for the validation stage. The affine
    # ``statement`` remains the accounting source (bytes per point).
    kernel: Callable | None = None
    oracle: Callable | None = None
    # Provenance of application-derived patterns (``repro.suite.derived``):
    # ``{source_model, source_op, feature_vector}``. Drivers merge it into
    # every record's ``extra["derived"]`` so hand-written and
    # application-derived records classify across origins.
    derived: Mapping[str, object] | None = None
    # Provenance of trace-driven patterns (``repro.suite.spatter_io``):
    # ``{source, pattern_hash, form}``. Drivers merge it into every
    # record's ``extra["trace"]`` so replayed traces stay attributable to
    # the JSON file (and pattern) they came from.
    trace: Mapping[str, object] | None = None
    # Multi-pattern mix accounting (``mix_patterns``): ``{primary,
    # components: ({label, pattern, points, bytes, fraction}, ...)}``
    # where ``bytes`` is per sweep. When set, drivers total the
    # components' bytes (the statement accounts the primary only) and
    # stamp the split into ``extra["mix"]``.
    mix: Mapping[str, object] | None = None

    def space(self, name: str) -> DataSpace:
        for s in self.spaces:
            if s.name == name:
                return s
        raise KeyError(name)

    # -- accounting (drivers use these for GB/s) ---------------------------

    def bytes_per_point(self) -> int:
        import numpy as _np

        total = 0
        for acc in (*self.statement.reads, self.statement.write):
            total += _np.dtype(self.space(acc.space).dtype).itemsize
        return total

    def allocate(self, env: Mapping[str, int]) -> dict[str, np.ndarray]:
        """Materialize + initialize all data spaces (the init schedule)."""
        out = {}
        for s in self.spaces:
            shape = s.concrete_shape(env)
            if callable(s.init):
                grids = np.meshgrid(
                    *[np.arange(n, dtype=np.int64) for n in shape], indexing="ij"
                ) if shape else []
                out[s.name] = np.asarray(s.init(*grids), dtype=s.dtype)
                if out[s.name].shape != shape:
                    out[s.name] = np.broadcast_to(out[s.name], shape).astype(s.dtype)
            else:
                out[s.name] = np.full(shape, s.init, dtype=s.dtype)
        return out


# ---------------------------------------------------------------------------
# Built-in pattern specs (the paper's case studies)
# ---------------------------------------------------------------------------


def triad(scalar: float = 3.0) -> PatternSpec:
    """STREAM triad: A[i] = B[i] + scalar * C[i]  (paper Listing 3/4)."""
    stmt = Statement(
        reads=(Access("B", ("i",)), Access("C", ("i",))),
        write=Access("A", ("i",)),
        combine=lambda vals, env: vals[0] + scalar * vals[1],
    )
    return PatternSpec(
        name="triad",
        spaces=(
            DataSpace("A", ("n",), "float32", 1.0),
            DataSpace("B", ("n",), "float32", 3.0),
            DataSpace("C", ("n",), "float32", 4.0),
        ),
        statement=stmt,
        domain=domain(("i", 0, "n")),
        flops_per_point=2,
    )


def stream_copy() -> PatternSpec:
    stmt = Statement(
        reads=(Access("B", ("i",)),),
        write=Access("A", ("i",)),
        combine=lambda vals, env: vals[0],
    )
    return PatternSpec(
        "copy",
        (DataSpace("A", ("n",), "float32", 0.0), DataSpace("B", ("n",), "float32", 2.0)),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=0,
    )


def stream_scale(scalar: float = 3.0) -> PatternSpec:
    stmt = Statement(
        reads=(Access("B", ("i",)),),
        write=Access("A", ("i",)),
        combine=lambda vals, env: scalar * vals[0],
    )
    return PatternSpec(
        "scale",
        (DataSpace("A", ("n",), "float32", 0.0), DataSpace("B", ("n",), "float32", 2.0)),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=1,
    )


def stream_sum() -> PatternSpec:
    stmt = Statement(
        reads=(Access("B", ("i",)), Access("C", ("i",))),
        write=Access("A", ("i",)),
        combine=lambda vals, env: vals[0] + vals[1],
    )
    return PatternSpec(
        "sum",
        (
            DataSpace("A", ("n",), "float32", 0.0),
            DataSpace("B", ("n",), "float32", 2.0),
            DataSpace("C", ("n",), "float32", 3.0),
        ),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=1,
    )


def nstream(k: int, scalar: float = 3.0) -> PatternSpec:
    """Paper Fig. 7: A[i] = sum of ``k`` read streams (k=2 reproduces sum,
    k=20 is the paper's maximum). One write stream + k read streams."""
    names = [f"S{j}" for j in range(k)]
    stmt = Statement(
        reads=tuple(Access(nm, ("i",)) for nm in names),
        write=Access("A", ("i",)),
        combine=lambda vals, env: sum(vals[1:], vals[0] * scalar),
    )
    spaces = (DataSpace("A", ("n",), "float32", 0.0),) + tuple(
        DataSpace(nm, ("n",), "float32", 1.0 + j) for j, nm in enumerate(names)
    )
    return PatternSpec(
        f"nstream{k}", spaces, stmt, domain(("i", 0, "n")), flops_per_point=k
    )


# -- Spatter-style gather/scatter (Lavin et al.) ----------------------------
#
# Spatter expresses memory benchmarks as pattern vectors driven through a
# fixed gather/scatter kernel; its UNIFORM:stride mode is affine, so the
# same specs drive this engine's schedule/template machinery. ``stride``
# plays Spatter's pattern-stride role: the sparse side touches one element
# per ``stride`` while the dense side streams contiguously.


def gather(stride: int = 8) -> PatternSpec:
    """Spatter gather: dense[i] = sparse[stride*i] (UNIFORM:stride)."""
    if stride < 1:
        raise ValueError("stride must be >= 1")
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("S", (i * stride,)),),
        write=Access("D", (i,)),
        combine=lambda vals, env: vals[0],
    )
    return PatternSpec(
        f"gather{stride}",
        (
            DataSpace("D", ("n",), "float32", 0.0),
            DataSpace("S", (Affine.of("n") * stride,), "float32",
                      lambda i: (i % 23).astype(np.float32)),
        ),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=0,
    )


def scatter(stride: int = 8) -> PatternSpec:
    """Spatter scatter: sparse[stride*i] = dense[i] (UNIFORM:stride)."""
    if stride < 1:
        raise ValueError("stride must be >= 1")
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("D", (i,)),),
        write=Access("S", (i * stride,)),
        combine=lambda vals, env: vals[0],
    )
    return PatternSpec(
        f"scatter{stride}",
        (
            DataSpace("D", ("n",), "float32",
                      lambda i: (i % 19).astype(np.float32)),
            DataSpace("S", (Affine.of("n") * stride,), "float32", 0.0),
        ),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=0,
    )


def gather_scatter(stride: int = 8) -> PatternSpec:
    """Spatter GS: sparse_out[stride*i] = sparse_in[stride*i] — both sides
    strided, the round-trip pattern Spatter uses to expose TLB/prefetch
    limits that one-sided gather or scatter alone hides."""
    if stride < 1:
        raise ValueError("stride must be >= 1")
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("S", (i * stride,)),),
        write=Access("T", (i * stride,)),
        combine=lambda vals, env: vals[0],
    )
    return PatternSpec(
        f"gather_scatter{stride}",
        (
            DataSpace("S", (Affine.of("n") * stride,), "float32",
                      lambda i: (i % 29).astype(np.float32)),
            DataSpace("T", (Affine.of("n") * stride,), "float32", 0.0),
        ),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=0,
    )


# -- concurrent multi-pattern mixes (the Mess contention primitive) ----------
#
# Mess (arXiv 2405.10170) argues that *contended* curves — a measured
# kernel sharing the memory system with generator traffic — predict
# application behavior where isolated kernels do not. ``mix_patterns``
# composes >= 2 PatternSpecs into ONE executable: each component keeps
# its own (namespaced) data spaces, every fused sweep runs every
# component's step, and the whole mix is timed as a unit, so the access
# streams contend for the same caches and memory channels for the full
# measurement. Per-component byte accounting rides ``PatternSpec.mix``
# into every record's ``extra["mix"]``.


def mix_space(k: int, name: str) -> str:
    """The namespaced array name of component ``k``'s space ``name``."""
    return f"m{k}_{name}"


def _concrete_component(spec: PatternSpec, env: Mapping[str, int]) -> PatternSpec:
    """Bake a component's symbolic shapes/bounds to ints under its own
    env, so components with *different* working sets coexist under the
    mix's single driver env."""
    from .domain import Dim

    try:
        spaces = tuple(
            dataclasses.replace(s, shape=s.concrete_shape(env))
            for s in spec.spaces
        )
        dims = tuple(
            Dim.of(d.name, d.lo.eval(env), d.hi.eval(env))
            for d in spec.domain.dims
        )
    except KeyError as e:
        raise ValueError(
            f"mix component {spec.name!r} is not rectangular under "
            f"{dict(env)!r} (unbound symbol {e}); mixes need "
            "parameter-bound rectangular domains"
        ) from None
    return dataclasses.replace(spec, spaces=spaces, domain=IterDomain(dims))


def _mix_kernel(components: tuple) -> Callable:
    def kernel(pattern, env):
        from .codegen import lower_mix

        return lower_mix(pattern, components)

    return kernel


def _mix_oracle(components: tuple) -> Callable:
    def oracle(pattern, arrays, env, ntimes):
        from .codegen import replay_component

        out = {k: np.array(v) for k, v in arrays.items()}
        for k, (_label, comp, cenv) in enumerate(components):
            sub = {s.name: out[mix_space(k, s.name)] for s in comp.spaces}
            sub = replay_component(comp, sub, cenv, int(ntimes))
            for s in comp.spaces:
                out[mix_space(k, s.name)] = np.asarray(sub[s.name])
        return out

    return oracle


def mix_patterns(
    components: Sequence[tuple],
    name: str = "mix",
    primary: str | None = None,
    trace: Mapping[str, object] | None = None,
) -> PatternSpec:
    """Compose patterns into one executable contending for memory.

    ``components`` is a sequence of ``(label, PatternSpec, env)`` tuples;
    each component is concretized under its *own* env (so a traffic
    generator can run a different working set than the measured kernel)
    and its spaces are renamed ``m{k}_<space>`` to keep the namespaces
    disjoint. The composed spec carries a custom kernel that runs every
    component's lowered step once per sweep (components alternate inside
    the fused ``ntimes`` loop — fine-grained temporal interleaving) and a
    numpy oracle replaying each component independently (disjoint spaces
    make the replay order immaterial).

    ``primary`` names the measured component (default: the first); its
    statement/domain provide the mix's nominal statement, and drivers
    report both the aggregate GB/s (all components' bytes over the
    shared wall time) and the per-component byte split
    (``extra["mix"]``) from which primary-bandwidth-under-load derives.
    Custom kernels must run ``template="unified"``/``programs=1``.
    """
    comps = tuple(
        (str(label), _concrete_component(spec, dict(env)), dict(env))
        for label, spec, env in components
    )
    if not comps:
        raise ValueError("mix_patterns needs at least one component")
    labels = [label for label, _, _ in comps]
    if len(set(labels)) != len(labels):
        raise ValueError(f"duplicate component labels: {labels}")
    primary = primary if primary is not None else labels[0]
    if primary not in labels:
        raise ValueError(f"primary {primary!r} not among {labels}")
    entries = []
    for label, comp, cenv in comps:
        pts = comp.domain.point_count(cenv)
        entries.append({
            "label": label,
            "pattern": comp.name,
            "points": int(pts),
            "bytes": int(comp.bytes_per_point() * pts),
        })
    total = sum(e["bytes"] for e in entries)
    for e in entries:
        e["fraction"] = (e["bytes"] / total) if total else 0.0
    pk = labels.index(primary)
    prim = comps[pk][1]
    spaces = tuple(
        dataclasses.replace(s, name=mix_space(k, s.name))
        for k, (_, comp, _) in enumerate(comps)
        for s in comp.spaces
    )
    stmt = Statement(
        reads=tuple(
            Access(mix_space(pk, a.space), a.index)
            for a in prim.statement.reads
        ),
        write=Access(mix_space(pk, prim.statement.write.space),
                     prim.statement.write.index),
        combine=prim.statement.combine,
    )
    return PatternSpec(
        name=name,
        spaces=spaces,
        statement=stmt,
        domain=prim.domain,
        flops_per_point=prim.flops_per_point,
        kernel=_mix_kernel(comps),
        oracle=_mix_oracle(comps),
        trace=trace,
        mix={"primary": primary, "components": tuple(entries)},
    )


# -- pointer chase (latency, not bandwidth) ----------------------------------
#
# The load-to-use latency probe every memory characterization needs (the
# lat_mem_rd lineage; Mess pairs exactly this with bandwidth under load):
# H = P[H] repeated n times per sweep, where P is a single-cycle
# pseudorandom permutation of [0, n). Every load's address depends on the
# previous load's *value*, so the chain cannot be overlapped or
# prefetched — per-step time is the load-to-use latency of whatever
# level the working set sits in. Serial dependence is inexpressible as
# an affine statement, so this spec carries a custom ``kernel``/
# ``oracle`` pair; the affine statement remains for accounting only.


def _chase_cycle(i: np.ndarray) -> np.ndarray:
    """Single-cycle pseudorandom permutation of [0, n): visit elements in
    a shuffled order and link each to the next. One cycle guarantees the
    chase touches the whole working set; the shuffle defeats stride
    prefetchers. Deterministic per size (seeded by n)."""
    n = int(i.shape[0])
    order = np.random.default_rng(0xC4A5E ^ n).permutation(n)
    p = np.empty(n, dtype=np.int32)
    p[order] = np.roll(order, -1).astype(np.int32)
    return p


def _chase_kernel(pattern: PatternSpec, env: Mapping[str, int]) -> Callable:
    """``step(arrays)``: chase ``n`` serially-dependent loads through P,
    parking the running index in the one-element head space H."""
    steps = int(env["n"])

    def step(arrays):
        import jax

        arrays = dict(arrays)
        P = arrays["P"]
        h = jax.lax.fori_loop(0, steps, lambda _, h: P[h], arrays["H"][0])
        arrays["H"] = arrays["H"].at[0].set(h)
        return arrays

    return step


def _chase_oracle(pattern: PatternSpec, arrays: Mapping[str, np.ndarray],
                  env: Mapping[str, int], ntimes: int) -> dict:
    out = {k: np.array(v) for k, v in arrays.items()}
    P = out["P"]
    h = int(out["H"][0])
    for _ in range(int(ntimes) * int(env["n"])):
        h = int(P[h])
    out["H"][0] = h
    return out


def pointer_chase() -> PatternSpec:
    """Serial pointer chase: H = P[H], n dependent loads per sweep.

    A latency pattern: the derived metric is seconds / (ntimes * n) —
    load-to-use ns per access — not GB/s (the statement's 8 bytes/point
    accounting is nominal). Use with ``template="unified"`` and
    ``programs=1``; the chain is inherently serial.
    """
    stmt = Statement(
        reads=(Access("P", ("i",)),),
        write=Access("H", (0,)),
        combine=lambda vals, env: vals[0],
    )
    return PatternSpec(
        "pointer_chase",
        (
            DataSpace("P", ("n",), "int32", _chase_cycle),
            DataSpace("H", (1,), "int32", 0),
        ),
        stmt,
        domain(("i", 0, "n")),
        flops_per_point=0,
        kernel=_chase_kernel,
        oracle=_chase_oracle,
    )


def jacobi1d() -> PatternSpec:
    """3-pt Jacobi 1D: A[i] = (B[i-1] + B[i] + B[i+1]) / 3 on 1 <= i < n-1."""
    third = np.float32(1.0 / 3.0)
    stmt = Statement(
        reads=(
            Access("B", (Affine.of("i") - 1,)),
            Access("B", ("i",)),
            Access("B", (Affine.of("i") + 1,)),
        ),
        write=Access("A", ("i",)),
        combine=lambda vals, env: (vals[0] + vals[1] + vals[2]) * third,
    )
    return PatternSpec(
        "jacobi1d",
        (
            DataSpace("A", ("n",), "float32", 0.0),
            DataSpace("B", ("n",), "float32", lambda i: (i % 17).astype(np.float32)),
        ),
        stmt,
        domain(("i", 1, Affine.of("n") - 1)),
        flops_per_point=3,
    )


def jacobi2d() -> PatternSpec:
    """5-pt star (the paper's '9-pt Jacobi 2D' figure uses the standard
    star/box family; we implement the 5-pt star and the 9-pt box — this
    constructor is the 5-pt star; see jacobi2d9 for the box)."""
    fifth = np.float32(1.0 / 5.0)
    i, j = Affine.of("i"), Affine.of("j")
    stmt = Statement(
        reads=(
            Access("B", (i - 1, j)),
            Access("B", (i + 1, j)),
            Access("B", (i, j - 1)),
            Access("B", (i, j + 1)),
            Access("B", (i, j)),
        ),
        write=Access("A", (i, j)),
        combine=lambda vals, env: (vals[0] + vals[1] + vals[2] + vals[3] + vals[4])
        * fifth,
    )
    return PatternSpec(
        "jacobi2d",
        (
            DataSpace("A", ("n", "n"), "float32", 0.0),
            DataSpace(
                "B",
                ("n", "n"),
                "float32",
                lambda i, j: ((i + 2 * j) % 13).astype(np.float32),
            ),
        ),
        stmt,
        domain(("i", 1, Affine.of("n") - 1), ("j", 1, Affine.of("n") - 1)),
        flops_per_point=5,
    )


def jacobi2d9() -> PatternSpec:
    """9-pt box Jacobi 2D (paper Fig. 13)."""
    ninth = np.float32(1.0 / 9.0)
    i, j = Affine.of("i"), Affine.of("j")
    reads = tuple(
        Access("B", (i + di, j + dj)) for di in (-1, 0, 1) for dj in (-1, 0, 1)
    )
    stmt = Statement(
        reads=reads,
        write=Access("A", (i, j)),
        combine=lambda vals, env: sum(vals[1:], vals[0]) * ninth,
    )
    return PatternSpec(
        "jacobi2d9",
        (
            DataSpace("A", ("n", "n"), "float32", 0.0),
            DataSpace(
                "B",
                ("n", "n"),
                "float32",
                lambda i, j: ((3 * i + j) % 11).astype(np.float32),
            ),
        ),
        stmt,
        domain(("i", 1, Affine.of("n") - 1), ("j", 1, Affine.of("n") - 1)),
        flops_per_point=9,
    )


def jacobi3d() -> PatternSpec:
    """7-pt Jacobi 3D (paper §III-B / Listing 9)."""
    seventh = np.float32(1.0 / 7.0)
    i, j, k = Affine.of("i"), Affine.of("j"), Affine.of("k")
    stmt = Statement(
        reads=(
            Access("B", (i - 1, j, k)),
            Access("B", (i + 1, j, k)),
            Access("B", (i, j - 1, k)),
            Access("B", (i, j + 1, k)),
            Access("B", (i, j, k - 1)),
            Access("B", (i, j, k + 1)),
            Access("B", (i, j, k)),
        ),
        write=Access("A", (i, j, k)),
        combine=lambda vals, env: sum(vals[1:], vals[0]) * seventh,
    )
    n1 = Affine.of("n") - 1
    return PatternSpec(
        "jacobi3d",
        (
            DataSpace("A", ("n", "n", "n"), "float32", 0.0),
            DataSpace(
                "B",
                ("n", "n", "n"),
                "float32",
                lambda i, j, k: ((i + j + k) % 7).astype(np.float32),
            ),
        ),
        stmt,
        domain(("i", 1, n1), ("j", 1, n1), ("k", 1, n1)),
        flops_per_point=7,
    )
