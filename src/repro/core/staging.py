"""Staged lower -> compile -> execute pipeline with a translation cache.

AdaptMemBench's value is cheap exploration: express a pattern once, fork
many (schedule, template, working-set) variants, measure each. The naive
pipeline re-resolves access plans, re-traces, and re-jits for every
variant, so sweep wall time is dominated by Python lowering and XLA
compilation instead of the kernels being measured. This module makes the
stages explicit (the JaCe ``lower().compile()`` discipline):

``Lowered``
    Access plans resolved against a concrete environment; the backend
    ``step(arrays) -> arrays`` function is built but nothing is traced.

``Compiled``
    The repetition loop is traced and AOT-compiled into an XLA
    executable (``jax.jit(...).lower(avals).compile()``). Compile time
    and cost analysis come from this stage for free — measurement never
    pays a hidden recompile.

``TranslationCache``
    Both stages are memoized behind a keyed cache. Keys are structural
    fingerprints of (pattern, schedule, env, backend, template knobs),
    so identical tuples never lower or compile twice across
    ``Driver.run`` working-set loops, ``sweep`` variants, and repeated
    validation. A shared ``GLOBAL_CACHE`` is the default so independent
    drivers in one process pool their work.

``precompile``
    Compiles many staged variants concurrently. XLA's backend compile
    releases the GIL, so a small thread pool overlaps the compiles of a
    sweep's variants even though tracing stays serial.

Donation invariant: every *measurement* executable — ``ParamCompiled``
always, ``Compiled`` when built with ``donate=True`` (what
``Driver.prepare`` requests) — donates its array operands, so a call
consumes its input tuple instead of paying a buffer copy; the ``bind``
methods thread outputs into subsequent calls, and donated compiles
carry process-unique module names so jax's persistent cache can never
hand back a deserialized donated executable (which segfaults on this
jaxlib — see ``_compile_donated``).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Mapping, Sequence

import numpy as np

import jax

from .pattern import PatternSpec
from .schedule import Schedule

__all__ = [
    "Lowered",
    "Compiled",
    "ParamLowered",
    "ParamCompiled",
    "TranslationCache",
    "GLOBAL_CACHE",
    "stage_lower",
    "stage_lower_parametric",
    "precompile",
    "fingerprint_pattern",
    "fingerprint_schedule",
    "disk_cache_stats",
]


# ---------------------------------------------------------------------------
# Structural fingerprints (cache keys)
# ---------------------------------------------------------------------------


def _freeze_callable(fn: Callable) -> tuple:
    """Fingerprint a function by code identity + closure contents.

    Pattern factories rebuild specs per call, so ``combine``/``init``
    lambdas are fresh objects every time; what identifies them is their
    bytecode and the values they close over (``triad(scalar=2.0)`` and
    ``triad(scalar=3.0)`` must not collide).
    """
    if hasattr(fn, "func"):  # functools.partial
        return ("partial", _freeze(fn.func), _freeze(fn.args),
                _freeze(tuple(sorted(fn.keywords.items()))))
    code = getattr(fn, "__code__", None)
    if code is None:
        return ("obj", repr(fn))
    cells: tuple = ()
    if getattr(fn, "__closure__", None):
        cells = tuple(_freeze(c.cell_contents) for c in fn.__closure__)
    defaults = _freeze(fn.__defaults__) if fn.__defaults__ else ()
    return ("fn", fn.__module__, fn.__qualname__,
            hash(code.co_code), _freeze(code.co_consts), defaults, cells)


def _freeze(obj: Any) -> Any:
    """Recursively convert ``obj`` into a hashable structural key."""
    if obj is None or isinstance(obj, (bool, int, float, str, bytes)):
        return obj
    if isinstance(obj, (np.integer, np.floating)):
        return ("np", str(obj.dtype), obj.item())
    if isinstance(obj, np.ndarray):
        return ("nd", obj.shape, str(obj.dtype), hash(obj.tobytes()))
    if isinstance(obj, (tuple, list)):
        return tuple(_freeze(x) for x in obj)
    if isinstance(obj, dict):
        return tuple(sorted((str(k), _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, PatternSpec):
        # specs captured in closures (mix components) must freeze
        # *structurally* — the frozen-dataclass hash below would compare
        # their lambdas by identity, splitting equal factory rebuilds
        return fingerprint_pattern(obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        try:
            hash(obj)
            return obj  # frozen dataclass (Affine, Dim, ...) — already a key
        except TypeError:
            return tuple(
                (f.name, _freeze(getattr(obj, f.name)))
                for f in dataclasses.fields(obj)
            )
    if callable(obj):
        return _freeze_callable(obj)
    return ("repr", repr(obj))


def fingerprint_pattern(pattern: PatternSpec) -> tuple:
    """Hashable structural identity of a PatternSpec.

    Two factory-built specs with equal structure (spaces, accesses,
    combine code + captured constants, domain) get equal fingerprints
    even though every Python object in them is fresh.
    """
    stmt = pattern.statement
    return (
        "pat",
        pattern.name,
        tuple(
            (s.name, _freeze(s.shape), s.dtype, _freeze(s.init))
            for s in pattern.spaces
        ),
        tuple((a.space, _freeze(a.resolved())) for a in stmt.reads),
        (stmt.write.space, _freeze(stmt.write.resolved())),
        _freeze(stmt.combine),
        pattern.domain.dims,
        pattern.flops_per_point,
        _freeze(pattern.kernel),
        _freeze(pattern.oracle),
        _freeze(pattern.derived),
        _freeze(pattern.trace),
        _freeze(pattern.mix),
    )


def fingerprint_schedule(schedule: Schedule) -> tuple:
    return ("sch", schedule.name, schedule.transforms)


def _env_key(env: Mapping[str, int]) -> tuple:
    return tuple(sorted((str(k), int(v)) for k, v in env.items()))


# ---------------------------------------------------------------------------
# jax disk compilation cache accounting (the cross-process leg)
# ---------------------------------------------------------------------------
#
# jax's persistent compilation cache reports activity only through
# monitoring events; a process-wide listener folds them into counters so
# ``TranslationCache.stats()`` can report disk hits/misses alongside the
# in-process lower/compile accounting (and the smoke ledger records both).

_DISK_EVENTS = {
    "/jax/compilation_cache/cache_hits": "hits",
    "/jax/compilation_cache/cache_misses": "misses",
}
_disk_counters = {"hits": 0, "misses": 0}
# jax fires monitoring events from whichever thread ran the compile —
# under the engine's ThreadPoolBackend that is many threads at once, and
# an unlocked `+=` on the shared dict would drop counts
_disk_lock = threading.Lock()
_disk_listener_installed = False


def _install_disk_listener() -> None:
    global _disk_listener_installed
    if _disk_listener_installed:
        return
    _disk_listener_installed = True
    try:
        def _on_event(event, **kwargs):
            key = _DISK_EVENTS.get(event)
            if key is not None:
                with _disk_lock:
                    _disk_counters[key] += 1

        jax.monitoring.register_event_listener(_on_event)
    except (AttributeError, TypeError):  # pragma: no cover - monitoring
        # API drift (jax.monitoring moved/renamed): counters stay 0/0.
        # Deliberately narrow — any *other* fault here is a real bug and
        # must surface, per the failure-taxonomy policy in core.errors.
        pass


def disk_cache_stats() -> dict:
    """jax persistent-cache counters for this process (0/0 when the disk
    cache is disabled — events never fire)."""
    try:
        from jax._src import compilation_cache as _cc

        enabled = bool(_cc.is_persistent_cache_enabled())
    except (ImportError, AttributeError):  # pragma: no cover - private
        # jax API drift; narrow so real faults are not misreported as
        # "disk cache disabled"
        enabled = False
    with _disk_lock:
        return {
            "enabled": enabled,
            "hits": _disk_counters["hits"],
            "misses": _disk_counters["misses"],
        }


_install_disk_listener()


# ---------------------------------------------------------------------------
# Staged artifacts
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Lowered:
    """Stage 1: access plans resolved, backend step built (nothing traced)."""

    pattern: PatternSpec
    schedule: Schedule
    env: dict
    backend: str
    step: Callable[[dict], dict]
    nest: Any                       # LoweredNest
    key: tuple | None               # None = uncacheable (fingerprint failed)
    lower_seconds: float
    cache: "TranslationCache | None" = None
    pallas_mode: str = ""           # "compiled"/"interpret" (pallas backend)

    @property
    def space_names(self) -> tuple[str, ...]:
        return tuple(sorted(s.name for s in self.pattern.spaces))

    def avals(self) -> tuple[jax.ShapeDtypeStruct, ...]:
        by_name = {s.name: s for s in self.pattern.spaces}
        return tuple(
            jax.ShapeDtypeStruct(
                by_name[nm].concrete_shape(self.env), np.dtype(by_name[nm].dtype)
            )
            for nm in self.space_names
        )

    def compile(self, *, ntimes: int, sync_every_rep: bool = False,
                donate: bool = False,
                cache: "TranslationCache | None" = None) -> "Compiled":
        """Stage 2: trace + AOT-compile the ``ntimes``-sweep repetition loop.

        ``donate=True`` donates the array operands (no per-call buffer
        copy — the measurement-loop mode ``Driver.prepare`` requests);
        donated executables consume their input tuple, so callers must
        go through :meth:`Compiled.bind` to thread outputs into
        subsequent calls. The flag is part of the cache key: a donated
        executable never masquerades as the re-callable one.
        """
        cache = cache or self.cache
        key = None
        if self.key is not None:
            key = ("exec", self.key, int(ntimes), bool(sync_every_rep),
                   bool(donate))
        builder = lambda: _build_compiled(self, ntimes, sync_every_rep,
                                          donate)
        if cache is None or key is None:
            return builder()
        out, hit = cache._compiled_get_or_build(key, builder)
        # per-caller view: never mutate the shared cached object (racy
        # under precompile threads and wrong for duplicate points)
        return dataclasses.replace(out, from_cache=hit) if hit else out


@dataclasses.dataclass
class Compiled:
    """Stage 3 handle: an executable repetition loop + its cost metadata.

    When ``donated`` is True the array operands are donated: a call
    consumes its input tuple in place of paying a working-set-sized
    buffer copy (the same economics as the parametric executables —
    copy-free on both sides of a strided-vs-specialized comparison).
    Donated handles must be driven through :meth:`bind`, which threads
    each call's output tuple into the next; calling ``run`` twice with
    the same tuple raises inside jax (the buffers are gone)."""

    lowered: Lowered
    names: tuple[str, ...]
    run: Callable                   # run(tup) -> tup, ntimes sweeps
    executable: Any                 # jax AOT executable (cost_analysis source)
    ntimes: int
    sync_every_rep: bool
    compile_seconds: float
    from_cache: bool = False
    donated: bool = False

    def __call__(self, tup):
        return self.run(tup)

    def bind(self) -> Callable:
        """A ``fn(tup) -> tup`` for the measurement loop.

        Undonated executables are re-callable as-is. Donated ones get
        the same buffer-threading wrapper as
        :meth:`ParamCompiled.bind`: repeated calls (the timing loop)
        feed each call's output tuple into the next, so the caller's
        seed tuple is only consumed once — and a *different* tuple
        passed later raises instead of being silently ignored."""
        if not self.donated:
            return self.run
        state: dict = {}

        def fn(tup):
            if "tup" in state:
                if tup is not state["seed"] and tup is not state["tup"]:
                    raise ValueError(
                        "donated executable already threads its buffers; "
                        "a new input tuple would be ignored — call bind() "
                        "again for a fresh stream"
                    )
                tup = state["tup"]
            else:
                state["seed"] = tup
            out = self.run(tup)
            state["tup"] = out
            return out

        return fn

    def cost_analysis(self) -> dict:
        ca = self.executable.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        return ca


def _build_compiled(lowered: Lowered, ntimes: int,
                    sync_every_rep: bool, donate: bool = False) -> Compiled:
    names = lowered.space_names
    step = lowered.step

    def step_t(tup):
        d = dict(zip(names, tup))
        d = step(d)
        return tuple(d[k] for k in names)

    avals = lowered.avals()
    compile_one = (_compile_donated if donate
                   else lambda fn, *a: jax.jit(fn).lower(*a).compile())
    t0 = time.perf_counter()
    if sync_every_rep:
        exe = compile_one(step_t, avals)

        def run(tup):
            for _ in range(ntimes):
                tup = exe(tup)
                jax.block_until_ready(tup)
            return tup
    else:
        def fused(tup):
            return jax.lax.fori_loop(0, ntimes, lambda _, t: step_t(t), tup)

        exe = compile_one(fused, avals)
        run = exe
    compile_seconds = time.perf_counter() - t0
    return Compiled(
        lowered=lowered, names=names, run=run, executable=exe,
        ntimes=ntimes, sync_every_rep=sync_every_rep,
        compile_seconds=compile_seconds, donated=donate,
    )


# ---------------------------------------------------------------------------
# Parametric staged artifacts (one executable per ladder)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ParamLowered:
    """Stage 1, shape-polymorphic: the working-set parameter(s) stay
    symbolic. ``step(arrays, pvals)`` takes capacity-shaped arrays plus
    one traced int32 scalar per parameter; a whole ladder shares this
    artifact (and the one executable compiled from it)."""

    pattern: PatternSpec
    schedule: Schedule
    cap_env: dict                   # capacity env (arrays allocated here)
    params: tuple[str, ...]
    backend: str
    step: Callable[[dict, tuple], dict]
    pnest: Any                      # ParamNest
    key: tuple | None
    lower_seconds: float
    # which lowering regime the step was built with: "strided" (dynamic-
    # slice windows, per-call cost matching the specialized path) or
    # "gather" (masked gather/scatter fallback)
    param_path: str = "gather"
    # how many dynamic bands the strided windows span (1 = lane windows,
    # 2/3 = the stencil (i x j[, k]) boxes; 0 on the gather path)
    param_window_rank: int = 0
    cache: "TranslationCache | None" = None
    pallas_mode: str = ""           # "compiled"/"interpret" (pallas backend)

    # Driver.run treats lowered.env as the allocation env; for the
    # parametric artifact that is the capacity env.
    @property
    def env(self) -> dict:
        return self.cap_env

    @property
    def param_names(self) -> tuple[str, ...]:
        return self.params

    @property
    def space_names(self) -> tuple[str, ...]:
        return tuple(sorted(s.name for s in self.pattern.spaces))

    def avals(self) -> tuple:
        by_name = {s.name: s for s in self.pattern.spaces}
        arr = tuple(
            jax.ShapeDtypeStruct(
                by_name[nm].concrete_shape(self.cap_env),
                np.dtype(by_name[nm].dtype),
            )
            for nm in self.space_names
        )
        pv = tuple(
            jax.ShapeDtypeStruct((), np.dtype(np.int32)) for _ in self.params
        )
        return arr, pv

    def compile(self, *, ntimes: int, sync_every_rep: bool = False,
                cache: "TranslationCache | None" = None) -> "ParamCompiled":
        cache = cache or self.cache
        key = None
        if self.key is not None:
            key = ("pexec", self.key, int(ntimes), bool(sync_every_rep))
        builder = lambda: _build_param_compiled(self, ntimes, sync_every_rep)
        if cache is None or key is None:
            return builder()
        out, hit = cache._compiled_get_or_build(key, builder)
        return dataclasses.replace(out, from_cache=hit) if hit else out


@dataclasses.dataclass
class ParamCompiled:
    """One executable repetition loop shared by a whole working-set
    ladder: ``run(tup, pvals)`` executes ``ntimes`` sweeps at the working
    set named by the ``pvals`` scalars.

    The array operands are **donated**: without donation every call pays
    a capacity-sized buffer copy (the executable's shapes are the
    ladder's capacity, not the rung), which is exactly the
    pattern-independent overhead the strided regime exists to avoid.
    Consequence: a ``tup`` passed to ``run`` is consumed — reuse the
    *returned* tuple instead (:meth:`bind` does this threading for the
    measurement loop automatically)."""

    lowered: ParamLowered
    names: tuple[str, ...]
    run: Callable
    executable: Any
    ntimes: int
    sync_every_rep: bool
    compile_seconds: float
    from_cache: bool = False

    @property
    def param_names(self) -> tuple[str, ...]:
        return self.lowered.params

    @property
    def param_path(self) -> str:
        """Lowering regime of the shared executable ("strided"/"gather")."""
        return self.lowered.param_path

    @property
    def param_window_rank(self) -> int:
        """Window dimensionality of the strided regime (0 on gather)."""
        return self.lowered.param_window_rank

    def __call__(self, tup, pvals):
        return self.run(tup, pvals)

    def bind(self, env: Mapping[str, int]) -> Callable:
        """Close over one ladder point: returns ``fn(tup) -> tup``.

        The wrapper threads the donated buffers: repeated calls (the
        timing loop) feed each call's output tuple into the next, so the
        caller's original ``tup`` is only consumed once — which means a
        *different* tuple passed to a later call would be silently
        ignored. That is a measurement-loop contract (the loop re-passes
        the same seed tuple every rep), so passing anything else raises
        instead of computing on stale state."""
        pvals = tuple(np.int32(env[p]) for p in self.param_names)
        state: dict = {}

        def fn(tup):
            if "tup" in state:
                if tup is not state["seed"] and tup is not state["tup"]:
                    raise ValueError(
                        "bound parametric executable already threads its "
                        "donated buffers; a new input tuple would be "
                        "ignored — call bind() again for a fresh stream"
                    )
                tup = state["tup"]
            else:
                state["seed"] = tup
            out = self.run(tup, pvals)
            state["tup"] = out
            return out

        return fn

    def cost_analysis(self) -> dict:
        ca = self.executable.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        return ca


# Donated executables and jax's persistent compilation cache do not mix
# on this jaxlib: a donated executable *deserialized* from the disk
# cache segfaults at call time. The cache cannot be suspended per
# compile either — jax latches its use-the-cache decision once per
# process (``compilation_cache.is_cache_used``), so toggling the config
# around one compile either does nothing or kills the cache for every
# compile that follows (observed: the smoke suite's disk traffic
# dropped to zero). Instead, each donated compile — parametric AND the
# donated specialized measurement executables — gets a process-unique
# module name: the name is part of the cache key, so a donated
# executable can never be *retrieved* from disk (no deserialization, no
# segfault) while undonated compiles keep their cross-run cache hits.
# Cost: donated compiles write never-reused entries (one per distinct
# measurement executable); the in-process TranslationCache still
# deduplicates them within a run.
_donated_serial = itertools.count()


def _compile_donated(fn, *aval_groups):
    fn.__name__ = (
        f"{fn.__name__}_donated_{os.getpid()}_{next(_donated_serial)}"
    )
    return jax.jit(fn, donate_argnums=(0,)).lower(*aval_groups).compile()


def _build_param_compiled(lowered: ParamLowered, ntimes: int,
                          sync_every_rep: bool) -> ParamCompiled:
    names = lowered.space_names
    step = lowered.step

    def step_t(tup, pvals):
        d = dict(zip(names, tup))
        d = step(d, pvals)
        return tuple(d[k] for k in names)

    avals, pavals = lowered.avals()
    t0 = time.perf_counter()
    # donate the array operands: undonated calls copy the full
    # capacity-shaped buffers on every invocation, a cost proportional to
    # the ladder *capacity* rather than the rung being measured
    if sync_every_rep:
        exe = _compile_donated(step_t, avals, pavals)

        def run(tup, pvals):
            for _ in range(ntimes):
                tup = exe(tup, pvals)
                jax.block_until_ready(tup)
            return tup
    else:
        def fused(tup, pvals):
            return jax.lax.fori_loop(
                0, ntimes, lambda _, t: step_t(t, pvals), tup
            )

        exe = _compile_donated(fused, avals, pavals)
        run = exe
    compile_seconds = time.perf_counter() - t0
    return ParamCompiled(
        lowered=lowered, names=names, run=run, executable=exe,
        ntimes=ntimes, sync_every_rep=sync_every_rep,
        compile_seconds=compile_seconds,
    )


# ---------------------------------------------------------------------------
# Translation cache
# ---------------------------------------------------------------------------


class TranslationCache:
    """Keyed LRU memo for both pipeline stages, with hit/miss accounting.

    Thread-safe for concurrent ``precompile`` workers: lookups and
    insertions are locked; builders run outside the lock, and
    concurrent requests for one key deduplicate onto a single in-
    flight build (waiters count as hits — they paid a wait, not a
    compile).

    ``capacity`` bounds each stage's store: multi-axis plan grids
    (config × pattern × env points) would otherwise pin executables
    without limit in a long-lived exploration process. The least
    recently *used* entry is evicted (a grid re-run in plan order keeps
    its warm tail); evictions are counted in :meth:`stats`. Default:
    :data:`DEFAULT_CAPACITY` per stage, overridable per instance or —
    for the process-wide ``GLOBAL_CACHE`` — via ``REPRO_CACHE_CAPACITY``.
    """

    DEFAULT_CAPACITY = 1024

    def __init__(self, capacity: int | None = None) -> None:
        if capacity is None:
            capacity = self.DEFAULT_CAPACITY
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._lowered: "OrderedDict[tuple, Lowered]" = OrderedDict()
        self._compiled: "OrderedDict[tuple, Compiled]" = OrderedDict()
        self._inflight: dict[tuple, Future] = {}
        self._validated: set[tuple] = set()
        self.lower_hits = 0
        self.lower_misses = 0
        self.compile_hits = 0
        self.compile_misses = 0
        self.evictions = 0        # LRU executable/lowering evictions
        self.validated_drops = 0  # validated-memo clears (separate event)

    def _get_or_build(self, store: "OrderedDict", key, builder,
                      kind: str) -> tuple[Any, bool]:
        with self._lock:
            hit = store.get(key)
            if hit is not None:
                store.move_to_end(key)
                setattr(self, f"{kind}_hits", getattr(self, f"{kind}_hits") + 1)
                return hit, True
            fut = self._inflight.get(key)
            if fut is None:
                fut = Future()
                self._inflight[key] = fut
                owner = True
                setattr(self, f"{kind}_misses",
                        getattr(self, f"{kind}_misses") + 1)
            else:
                owner = False
                setattr(self, f"{kind}_hits", getattr(self, f"{kind}_hits") + 1)
        if not owner:
            return fut.result(), True
        try:
            out = builder()
        except BaseException as e:
            with self._lock:
                self._inflight.pop(key, None)
            fut.set_exception(e)
            raise
        with self._lock:
            store[key] = out
            store.move_to_end(key)
            while len(store) > self.capacity:
                store.popitem(last=False)
                self.evictions += 1
            self._inflight.pop(key, None)
        fut.set_result(out)
        return out, False

    def _lowered_get_or_build(self, key, builder) -> tuple[Lowered, bool]:
        return self._get_or_build(self._lowered, key, builder, "lower")

    def _compiled_get_or_build(self, key, builder) -> tuple[Compiled, bool]:
        return self._get_or_build(self._compiled, key, builder, "compile")

    # -- validation memo (sweeps validate a variant once, not per set) ------

    def was_validated(self, key: tuple) -> bool:
        with self._lock:
            return key in self._validated

    def mark_validated(self, key: tuple) -> None:
        with self._lock:
            # bound the memo like the stage stores: re-validation is much
            # cheaper than a compile, so crossing the cap just drops the
            # set (no LRU bookkeeping on this path)
            if len(self._validated) >= 4 * self.capacity:
                self._validated.clear()
                self.validated_drops += 1
            self._validated.add(key)

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            total = (self.lower_hits + self.lower_misses
                     + self.compile_hits + self.compile_misses)
            hits = self.lower_hits + self.compile_hits
            return {
                "lower_hits": self.lower_hits,
                "lower_misses": self.lower_misses,
                "compile_hits": self.compile_hits,
                "compile_misses": self.compile_misses,
                "entries": len(self._lowered) + len(self._compiled),
                "capacity": self.capacity,
                "evictions": self.evictions,
                "validated_drops": self.validated_drops,
                "hit_rate": (hits / total) if total else 0.0,
                "disk": disk_cache_stats(),
            }

    def clear(self) -> None:
        with self._lock:
            self._lowered.clear()
            self._compiled.clear()
            self._validated.clear()
            self.lower_hits = self.lower_misses = 0
            self.compile_hits = self.compile_misses = 0
            self.evictions = 0
            self.validated_drops = 0


def _device_cache_key(device: int | None) -> int | None:
    """Cache-key form of a device-axis pin: the index wraps modulo the
    visible device count, mirroring how ``Driver._device`` resolves it,
    so collapsed plan indices (device 0 vs device 4 on a 4-device box)
    share one executable instead of compiling duplicates. ``None`` (no
    pin) stays a distinct key: an unpinned compile runs under the
    ambient default device, which a ``jax.default_device`` scope can
    point anywhere."""
    if device is None:
        return None
    return device % len(jax.devices())


def _global_capacity() -> int | None:
    raw = os.environ.get("REPRO_CACHE_CAPACITY", "")
    try:
        return int(raw) if raw else None
    except ValueError:  # pragma: no cover - operator typo
        return None


GLOBAL_CACHE = TranslationCache(capacity=_global_capacity())


# ---------------------------------------------------------------------------
# Stage 1 entry point
# ---------------------------------------------------------------------------


def stage_lower(
    pattern: PatternSpec, schedule: Schedule, env: Mapping[str, int],
    backend: str = "jax", *, grid_bands: tuple[str, ...] | None = None,
    force_gather: bool = False, device: int | None = None,
    cache: TranslationCache | None = None,
) -> Lowered:
    """Resolve access plans and build the backend step, through the cache.

    ``device`` is the caller's device-axis pin (an index into
    ``jax.devices()``); it is part of the cache key because an AOT
    executable is bound to the device it compiled on — an artifact built
    for device 0 must never be replayed as device 3's. The key holds
    the *wrapped* index (modulo the visible device count, exactly how
    the driver resolves the pin), so plan indices that collapse onto
    one physical device share one executable.
    """
    from . import codegen  # deferred: codegen imports nothing from here

    env = dict(env)
    # the resolved execution mode is part of a pallas artifact's identity:
    # a cache entry (or journal record) built under interpret must never
    # be mistaken for a natively compiled one on another platform
    pallas_mode = codegen.pallas_platform_mode() if backend == "pallas" else ""
    try:
        key = (
            "lower", fingerprint_pattern(pattern),
            fingerprint_schedule(schedule), backend, pallas_mode or None,
            tuple(grid_bands) if grid_bands else None,
            bool(force_gather), _device_cache_key(device), _env_key(env),
        )
    except (TypeError, ValueError, AttributeError):
        key = None  # unhashable pattern piece: bypass the cache

    def builder() -> Lowered:
        t0 = time.perf_counter()
        plan = codegen.plan_nest(pattern, schedule, env)
        if backend == "jax":
            step = codegen.lower_jax(
                pattern, schedule, env, force_gather=force_gather, plan=plan
            )
        elif backend == "pallas":
            step = codegen.lower_pallas(
                pattern, schedule, env, mode=pallas_mode,
                grid_bands=grid_bands, plan=plan,
            )
        else:
            raise ValueError(backend)
        return Lowered(
            pattern=pattern, schedule=schedule, env=env, backend=backend,
            step=step, nest=plan.nest, key=key,
            lower_seconds=time.perf_counter() - t0, cache=cache,
            pallas_mode=pallas_mode,
        )

    if cache is None or key is None:
        return builder()
    out, _hit = cache._lowered_get_or_build(key, builder)
    if out.cache is None:
        out.cache = cache
    return out


def stage_lower_parametric(
    pattern: PatternSpec, schedule: Schedule, cap_env: Mapping[str, int],
    params: tuple[str, ...] = ("n",), backend: str = "jax", *,
    param_path: str = "auto", chunk: "int | tuple | None" = None,
    assume_full: bool = False, device: int | None = None,
    cache: TranslationCache | None = None,
) -> ParamLowered:
    """Shape-polymorphic stage 1: keep ``params`` symbolic, through the
    cache. The key deliberately omits the per-point env — every ladder
    point maps onto one entry, which is the whole point — but it *does*
    fingerprint the requested ``param_path`` regime, so a forced-gather
    artifact never masquerades as the strided one (and vice versa).

    Raises :class:`~repro.core.schedule.SymbolicLowerError` when a
    transform genuinely needs concrete extents (or ``param_path=
    "strided"`` is requested for an ineligible nest); callers fall back
    to per-size :func:`stage_lower` specialization. The pallas backend
    supports the strided regime only (grid-mapped N-D windows); nests
    that would need the gather fallback raise ``SymbolicLowerError``
    the same way.
    """
    from . import codegen

    if backend not in ("jax", "pallas"):
        from .schedule import SymbolicLowerError

        raise SymbolicLowerError(
            f"parametric lowering targets the jax/pallas backends, "
            f"not {backend!r}"
        )
    cap_env = dict(cap_env)
    params = tuple(params)
    pallas_mode = codegen.pallas_platform_mode() if backend == "pallas" else ""
    if backend == "pallas" and param_path == "gather":
        from .schedule import SymbolicLowerError

        raise SymbolicLowerError(
            "the pallas parametric path has no gather regime; use "
            "param_path='strided' (or the jax backend)"
        )
    # chunk is either a lane-chunk int or an N-D ((band, C), ...) window
    # spec resolved by the ladder policy; both fingerprint into the key
    if chunk is not None and not isinstance(chunk, int):
        chunk = tuple((int(b), int(c)) for b, c in chunk)
    try:
        key = (
            "plower", fingerprint_pattern(pattern),
            fingerprint_schedule(schedule), backend, pallas_mode or None,
            params, str(param_path), chunk, bool(assume_full),
            _device_cache_key(device), _env_key(cap_env),
        )
    except (TypeError, ValueError, AttributeError):
        key = None  # unhashable pattern piece: bypass the cache

    def builder() -> ParamLowered:
        t0 = time.perf_counter()
        pnest = schedule.lower_symbolic(pattern.domain, params)
        kw = {} if chunk is None else {"chunk": chunk}
        if backend == "pallas":
            step = codegen.lower_pallas_parametric(
                pattern, schedule, cap_env, params=params, pnest=pnest,
                assume_full=assume_full, mode=pallas_mode, **kw,
            )
        else:
            step = codegen.lower_jax_parametric(
                pattern, schedule, cap_env, params=params, pnest=pnest,
                param_path=param_path, assume_full=assume_full, **kw,
            )
        return ParamLowered(
            pattern=pattern, schedule=schedule, cap_env=cap_env,
            params=params, backend=backend, step=step, pnest=pnest,
            key=key, lower_seconds=time.perf_counter() - t0,
            param_path=getattr(step, "param_path", "gather"),
            param_window_rank=getattr(step, "param_window_rank", 0),
            cache=cache, pallas_mode=pallas_mode,
        )

    if cache is None or key is None:
        return builder()
    out, _hit = cache._lowered_get_or_build(key, builder)
    if out.cache is None:
        out.cache = cache
    return out


# ---------------------------------------------------------------------------
# Concurrent compile
# ---------------------------------------------------------------------------


def precompile(thunks: Sequence[Callable[[], Any]],
               max_workers: int | None = None) -> list:
    """Run compile thunks concurrently; returns their results in order.

    XLA's ``backend_compile`` releases the GIL, so a small pool overlaps
    the per-variant compiles of a sweep. Tracing inside each thunk stays
    correct (JAX trace state is thread-local) but serializes on the GIL;
    the win is the backend compile, which dominates.
    """
    thunks = list(thunks)
    if len(thunks) <= 1:
        return [t() for t in thunks]
    if max_workers is None:
        max_workers = min(4, len(thunks), os.cpu_count() or 1)
    if max_workers <= 1:
        return [t() for t in thunks]
    with ThreadPoolExecutor(max_workers=max_workers) as ex:
        return list(ex.map(lambda t: t(), thunks))


def pipeline_compile(lower_thunks: Sequence[Callable[[], Any]],
                     compile_fn: Callable[[Any], Any] | None = None,
                     max_workers: int | None = None) -> list:
    """Overlap serial lowering with concurrent compilation.

    Each ``lower_thunks[i]()`` runs on the calling thread (JAX tracing
    is GIL-bound, so serializing it costs nothing) and its result is
    immediately handed to a worker that runs ``compile_fn`` (default:
    ``lowered.compile()``), which spends its time in XLA with the GIL
    released. Total wall time approaches ``max(sum(lower), sum(compile)
    / workers)`` instead of their sum. Returns compiled results in
    order.
    """
    if compile_fn is None:
        compile_fn = lambda lowered: lowered.compile()
    lower_thunks = list(lower_thunks)
    if len(lower_thunks) <= 1:
        return [compile_fn(t()) for t in lower_thunks]
    if max_workers is None:
        max_workers = min(4, len(lower_thunks), os.cpu_count() or 1)
    if max_workers <= 1:
        return [compile_fn(t()) for t in lower_thunks]
    with ThreadPoolExecutor(max_workers=max_workers) as ex:
        futures = [ex.submit(compile_fn, t()) for t in lower_thunks]
        return [f.result() for f in futures]
