"""Failure taxonomy for fault-isolated sweep execution.

Every fault a sweep can hit is classified by *pipeline stage* so the
engine can decide what to do with it: demote the group's config (a
``CompileFailure`` on the parametric path often vanishes per-size
specialized), retry with backoff (``MeasureFailure`` under transient
load), or refuse up front (``CapacityRefused`` instead of an OOM kill).
``FailureRecord`` is the counterpart to :class:`repro.core.measure.Record`
— a failed plan point produces one, carrying enough pattern/schedule/env
context to diagnose the fault from the record alone.

The retry/backoff + straggler-watchdog policy shapes mirror
``runtime/fault_tolerance.py`` (the seed's training-loop harness); here
they guard individual measurements and driver groups instead of steps.
"""
from __future__ import annotations

import dataclasses
import json
import os

__all__ = [
    "BenchFailure",
    "LowerFailure",
    "CompileFailure",
    "ValidateFailure",
    "MeasureFailure",
    "BudgetExceeded",
    "CapacityRefused",
    "SweepFailures",
    "FailureRecord",
    "Demotion",
    "ResiliencePolicy",
    "classify_failure",
    "available_memory_bytes",
    "default_capacity_budget",
]


class BenchFailure(RuntimeError):
    """Base of the taxonomy.

    ``stage`` names the pipeline stage that faulted (lower / compile /
    validate / measure / capacity); ``transient`` marks faults worth a
    bounded retry before demotion; ``context`` holds the diagnosable
    payload (pattern, schedule, backend, env, ...); ``cause`` is the
    original exception when this wraps one.
    """

    stage = "unknown"
    transient = False

    def __init__(self, message: str, *, context: dict | None = None,
                 cause: BaseException | None = None):
        super().__init__(message)
        self.context: dict = dict(context or {})
        self.cause = cause


class LowerFailure(BenchFailure):
    """Pattern construction or jaxpr/StableHLO lowering faulted."""

    stage = "lower"


class CompileFailure(BenchFailure):
    """XLA refused or crashed compiling a lowered program."""

    stage = "compile"


class ValidateFailure(BenchFailure):
    """Executable output disagreed with the serial oracle."""

    stage = "validate"


class MeasureFailure(BenchFailure):
    """The timed run itself faulted; often transient (load spikes)."""

    stage = "measure"
    transient = True


class BudgetExceeded(MeasureFailure):
    """The straggler watchdog aborted a measurement over its wall-clock
    budget. Transient by inheritance: a retry under calmer load may fit."""

    stage = "measure"


class CapacityRefused(BenchFailure):
    """Working-set pre-flight refused an allocation exceeding the
    available-memory budget — a structured refusal instead of an OOM
    kill. Not transient (the point is simply too big), but demotion
    parametric→specialized shrinks the allocation env for the *other*
    rungs sharing the executable."""

    stage = "capacity"


class SweepFailures(BenchFailure):
    """Aggregate raised by strict callers of a fault-isolated report
    (``RunReport.raise_if_failed``). Carries the individual
    ``FailureRecord`` entries on ``.failures``."""

    stage = "sweep"

    def __init__(self, failures):
        self.failures = tuple(failures)
        brief = ", ".join(
            f"{f.variant}/{f.label} [{f.stage}:{f.error}]" for f in self.failures[:4])
        more = "" if len(self.failures) <= 4 else f" (+{len(self.failures) - 4} more)"
        super().__init__(
            f"{len(self.failures)} plan point(s) failed: {brief}{more}")


def classify_failure(exc: BaseException, stage: str, **context) -> BenchFailure:
    """Wrap ``exc`` into the taxonomy. An existing ``BenchFailure``
    passes through (its own stage wins) with ``context`` merged in;
    anything else becomes the class matching ``stage``."""
    if isinstance(exc, BenchFailure):
        for k, v in context.items():
            exc.context.setdefault(k, v)
        return exc
    cls = {
        "lower": LowerFailure,
        "compile": CompileFailure,
        "validate": ValidateFailure,
        "measure": MeasureFailure,
        "capacity": CapacityRefused,
    }.get(stage, MeasureFailure)
    return cls(f"{type(exc).__name__}: {exc}", context=context, cause=exc)


@dataclasses.dataclass
class FailureRecord:
    """One failed plan point — the ``Record`` counterpart.

    ``error`` is the taxonomy class name; the original exception class
    lands in ``context["cause"]``. ``demotions`` lists the ladder steps
    that were attempted before the point was marked failed."""

    variant: str
    label: str
    stage: str
    error: str
    message: str
    pattern: str = ""
    template: str = ""
    schedule: str = ""
    backend: str = ""
    env: dict = dataclasses.field(default_factory=dict)
    axis_point: dict = dataclasses.field(default_factory=dict)
    context: dict = dataclasses.field(default_factory=dict)
    attempts: int = 1
    demotions: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.demotions = list(self.demotions)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # Context can hold arbitrary objects (envs, exceptions); keep the
        # record JSON-serializable no matter what landed in there.
        d["context"] = json.loads(json.dumps(d["context"], default=str))
        d["env"] = json.loads(json.dumps(d["env"], default=str))
        return d

    def json(self) -> str:
        return json.dumps(self.as_dict())


@dataclasses.dataclass(frozen=True)
class Demotion:
    """One demotion-ladder step taken for a driver group."""

    variant: str
    labels: tuple
    step: str       # e.g. "strided->gather", "parametric->specialized"
    stage: str      # stage of the failure that triggered the step
    error: str


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Bounded retry/backoff + demotion policy for ``run_plan``.

    Same shape as ``runtime.fault_tolerance.FTConfig``: transient faults
    get ``max_retries`` retries with exponential backoff before the
    group walks one demotion-ladder step."""

    max_retries: int = 1
    backoff_s: float = 0.05
    demote: bool = True


def available_memory_bytes() -> int | None:
    """``MemAvailable`` from /proc/meminfo, or None where unreadable."""
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) * 1024
    except (OSError, ValueError, IndexError):
        pass
    return None


def default_capacity_budget() -> int | None:
    """Capacity budget for the working-set pre-flight, in bytes.

    ``REPRO_CAPACITY_BUDGET`` overrides (empty/0 disables the check);
    otherwise 80% of MemAvailable; None when neither is knowable."""
    raw = os.environ.get("REPRO_CAPACITY_BUDGET")
    if raw is not None:
        raw = raw.strip()
        if not raw or raw == "0":
            return None
        try:
            return int(raw)
        except ValueError:
            return None
    avail = available_memory_bytes()
    return int(avail * 0.8) if avail else None
