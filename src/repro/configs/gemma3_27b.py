"""Gemma 3 27B — 5:1 local(1024-window):global attention, 128k context.

62 layers, d_model=5376, 32 heads / 16 KV heads, huge 262k vocab.
long_500k RUNS for this arch: 5/6 of layers are sliding-window
(sub-quadratic); the periodic global layers attend over the full cache.
"""
from repro.config import ArchConfig, register


@register("gemma3-27b")
def config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab_size=262144,
        head_dim=128,
        act="geglu",  # GeGLU (gated)
        rope_theta=1e6,              # global layers; local use 1e4 (dual base)
        window=1024,
        global_every=6,              # layers 5, 11, ... are global
        tie_embeddings=True,
    )
