"""DeepSeek-V2-Lite (15.7B total / 2.4B active) — arXiv:2405.04434.

MLA attention (kv_lora_rank=512, no q compression in the Lite variant),
MoE with 2 shared + 64 routed experts top-6 per the assignment table
(the HF checkpoint uses 64 routed; d_ff_expert=1408), first layer dense.
"""
from repro.config import ArchConfig, MLAConfig, MoEConfig, register


@register("deepseek-v2-lite-16b")
def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,                  # dense-layer FFN width
        vocab_size=102400,
        head_dim=128,
        rope_theta=1e4,
        mla=MLAConfig(
            q_lora_rank=0,           # V2-Lite: full-rank q
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            n_routed=64,
            n_shared=2,
            top_k=6,
            d_ff_expert=1408,
            first_k_dense=1,
        ),
    )
