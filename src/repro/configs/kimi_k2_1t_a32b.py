"""Kimi K2 (1T total / 32B active) — Kimi K2 tech report (paper table).

DeepSeek-V3-style MLA + MoE scaled to 384 routed experts top-8, one
shared expert, 61 layers at d_model=7168. The assignment marks this
paper-table config [unverified]; we implement the published table.
"""
from repro.config import ArchConfig, MLAConfig, MoEConfig, register


@register("kimi-k2-1t-a32b")
def config() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,                # assignment: GQA kv=8 (MLA cache below)
        d_ff=18432,                  # dense-layer FFN width (DSv3 family)
        vocab_size=163840,
        head_dim=128,
        rope_theta=5e4,
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            n_routed=384,
            n_shared=1,
            top_k=8,
            d_ff_expert=2048,
            first_k_dense=1,
        ),
    )
