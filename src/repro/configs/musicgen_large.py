"""MusicGen-Large — arXiv:2306.05284. Decoder-only over EnCodec tokens.

The transformer backbone only: 48L d2048, full attention, GELU. The
EnCodec frontend is a stub — input_specs() provides precomputed
4-codebook frame embeddings (delay-pattern summed), and the LM head
predicts each codebook's 2048-way vocabulary (we model one codebook head,
vocab=2048, matching the assignment's backbone spec).
"""
from repro.config import ArchConfig, register


@register("musicgen-large")
def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        act="gelu",
        frontend="audio",
        n_codebooks=4,
    )
