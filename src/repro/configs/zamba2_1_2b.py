"""Zamba2-1.2B — arXiv:2411.15242. Mamba2 backbone + shared attn blocks.

38 Mamba2 blocks at d_model=2048, one *shared* (weight-tied) attention+MLP
block applied every 6 mamba blocks (per-application LoRA deltas omitted;
noted in DESIGN.md). ssm_state=64. long_500k RUNS (O(1) mamba state; the
shared attention uses the assignment's GQA over a bounded window cache).
"""
from repro.config import ArchConfig, SSMConfig, register


@register("zamba2-1.2b")
def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm=SSMConfig(
            kind="mamba2",
            d_state=64,
            head_dim=64,
            expand=2,
            conv_width=4,
            chunk=128,  # SSD block: Q^2 f32 intra-chunk buffers x64 heads must fit
        ),
        hybrid_attn_every=6,
        window=4096,                 # shared-attn KV window for long decode
    )
