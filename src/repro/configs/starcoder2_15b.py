"""StarCoder2-15B — arXiv:2402.19173. GQA kv=4, RoPE, GELU MLP."""
from repro.config import ArchConfig, register


@register("starcoder2-15b")
def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        act="gelu",
        rope_theta=1e5,
    )
