"""Phi-3-Vision (4.2B) — hf:microsoft/Phi-3-vision-128k-instruct.

phi3-mini backbone (32L d3072 GQA-32, SwiGLU, 128k RoPE-scaled) + CLIP
ViT-L/14 frontend. The vision tower is a STUB: input_specs() provides
``vision_tokens`` precomputed patch embeddings that are concatenated
before the text tokens, exactly as the projector output would be.
"""
from repro.config import ArchConfig, register


@register("phi-3-vision-4.2b")
def config() -> ArchConfig:
    return ArchConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        rope_theta=1e4,
        frontend="vision",
        vision_tokens=1024,
    )
