"""xLSTM-1.3B — arXiv:2405.04517. sLSTM + mLSTM blocks, attention-free.

48 blocks at d_model=2048 with 4 heads. Block mix: every 8th block is an
sLSTM (scalar memory, sequential scan); the rest are mLSTM (matrix
memory, chunked-parallel). d_ff=0 in the assignment: the up/down
projections live inside the (m/s)LSTM blocks (expand=2), no separate MLP.
long_500k RUNS (recurrent state is O(1) in sequence length).
"""
from repro.config import ArchConfig, SSMConfig, register


@register("xlstm-1.3b")
def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        ssm=SSMConfig(
            kind="xlstm",
            d_state=0,               # mLSTM state = (heads, hd, hd)
            head_dim=512,            # 2048 / 4 heads
            expand=2,
            conv_width=4,
            chunk=256,
            slstm_every=8,
        ),
    )
