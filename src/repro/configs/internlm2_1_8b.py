"""InternLM2-1.8B — arXiv:2403.17297. Plain GQA decoder, SwiGLU."""
from repro.config import ArchConfig, register


@register("internlm2-1.8b")
def config() -> ArchConfig:
    return ArchConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        rope_theta=1e6,
    )
