"""Per-architecture configs (one module per assigned architecture).

Every module registers its arch id with repro.config.registry and exposes
``config()``. Numbers follow the assignment table (public literature);
deviations are commented inline and in DESIGN.md §Arch-applicability.
"""
