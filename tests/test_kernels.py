"""Per-kernel allclose vs ref.py oracles, swept over shapes and dtypes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def rand(shape, dtype=jnp.float32, key=KEY):
    return jax.random.normal(key, shape, dtype)


def tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("n", [1024, 4096, 12288])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_triad(n, dtype):
    b, c = rand((n,), dtype), rand((n,), dtype)
    np.testing.assert_allclose(
        np.asarray(ops.triad(b, c, block=1024), np.float32),
        np.asarray(ref.triad_ref(b, c), np.float32), **tol(dtype))


@pytest.mark.parametrize("k", [1, 3, 11, 20])
def test_nstream(k):
    ss = tuple(rand((2048,), key=jax.random.PRNGKey(i)) for i in range(k))
    np.testing.assert_allclose(
        np.asarray(ops.nstream(ss, block=512)),
        np.asarray(ref.nstream_ref(ss)), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("factor,block", [(2, 512), (4, 256), (8, 128)])
def test_triad_interleaved(factor, block):
    b, c = rand((4096,)), rand((4096,))
    np.testing.assert_allclose(
        np.asarray(ops.triad_interleaved(b, c, factor=factor, block=block)),
        np.asarray(ref.triad_ref(b, c)), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("n,block", [(258, 64), (1026, 256), (4098, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_jacobi1d(n, block, dtype):
    x = rand((n,), dtype)
    np.testing.assert_allclose(
        np.asarray(ops.jacobi1d(x, block=block), np.float32),
        np.asarray(ref.jacobi1d_ref(x), np.float32), **tol(dtype))


@pytest.mark.parametrize("shape,block", [
    ((34, 66), (16, 32)), ((66, 130), (32, 64)), ((130, 130), (64, 128)),
])
def test_jacobi2d(shape, block):
    x = rand(shape)
    np.testing.assert_allclose(
        np.asarray(ops.jacobi2d(x, block=block)),
        np.asarray(ref.jacobi2d_ref(x)), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape,block", [
    ((18, 18, 34), (8, 8, 16)), ((34, 18, 66), (16, 16, 32)),
])
def test_jacobi3d_blocked(shape, block):
    x = rand(shape)
    np.testing.assert_allclose(
        np.asarray(ops.jacobi3d(x, block=block)),
        np.asarray(ref.jacobi3d_ref(x)), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape,block", [
    ((18, 18, 34), (8, 16)), ((34, 18, 66), (16, 32)),
])
def test_jacobi3d_streaming(shape, block):
    x = rand(shape)
    np.testing.assert_allclose(
        np.asarray(ops.jacobi3d_streaming(x, block=block)),
        np.asarray(ref.jacobi3d_ref(x)), rtol=3e-5, atol=3e-5)


def test_block_divisibility_errors():
    with pytest.raises(ValueError):
        ops.triad(rand((100,)), rand((100,)), block=64)
    with pytest.raises(ValueError):
        ops.jacobi1d(rand((100,)), block=64)  # interior 98 not divisible
