"""Plan-engine tests (the PR-3 acceptance contract).

Covers: axis/plan expansion (product vs zip, label round-trip,
validation errors), ladder-compat equivalence (a Ladder workload
produces identical labels/records through the plan engine as through
the pre-engine per-variant loop), the pointer-chase latency oracle and
its custom-kernel guards, the mess load-sweep record schema
(``extra.axis_point`` self-description), per-stride specialization +
per-env parametric sharing in the Spatter stride ladder, the LRU
translation cache, and ``--tag`` registry filtering.
"""
from __future__ import annotations

import dataclasses
import pathlib
import sys

import numpy as np
import pytest

from repro.core import (
    Driver,
    DriverConfig,
    SymbolicLowerError,
    TranslationCache,
    identity,
    latency_ns,
    pointer_chase,
    stage_lower,
    triad,
)
from repro.core.drivers import independent_view
from repro import suite
from repro.suite import (
    Ladder,
    SweepPlan,
    VariantSpec,
    Workload,
    collect_records,
    config_axis,
    env_axis,
    load_builtins,
    pattern_axis,
)

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))  # make the benchmarks package importable


# ---------------------------------------------------------------------------
# axis / plan expansion
# ---------------------------------------------------------------------------


def _halo(p):
    return p + 2


def test_product_plan_expansion_order_and_split():
    plan = SweepPlan.product(
        config_axis("programs", (1, 2)),
        pattern_axis("stride", (4,)),
        env_axis((256, 512), transform=_halo),
    )
    pts = plan.points(quick=True)
    assert len(pts) == 4  # 2 x 1 x 2, last axis fastest
    assert [p.label for p in pts] == [
        "programs1/stride4/n256", "programs1/stride4/n512",
        "programs2/stride4/n256", "programs2/stride4/n512",
    ]
    p0 = pts[0]
    assert p0.axis_point() == {"programs": 1, "stride": 4, "n": 256}
    assert dict(p0.config) == {"programs": 1}
    assert dict(p0.pattern_kwargs) == {"stride": 4}
    assert dict(p0.env) == {"n": 258}  # transformed; label keeps 256
    # group key ignores env: pts 0/1 share a driver, 2/3 share another
    assert p0.group_key == pts[1].group_key
    assert p0.group_key != pts[2].group_key


def test_zip_plan_lockstep_and_mismatch():
    plan = SweepPlan.zip(
        config_axis("programs", (1, 2, 4)),
        env_axis((256, 512, 1024)),
    )
    pts = plan.points(quick=True)
    assert [p.label for p in pts] == ["programs1/n256", "programs2/n512",
                                     "programs4/n1024"]
    bad = SweepPlan.zip(config_axis("programs", (1, 2)), env_axis((256,)))
    with pytest.raises(ValueError, match="disagree"):
        bad.points(quick=True)


def test_axis_quick_full_and_validation():
    ax = env_axis((256,), (256, 512))
    assert ax.points(True) == (256,) and ax.points(False) == (256, 512)
    assert env_axis((8,)).full == (8,)  # full defaults to quick
    with pytest.raises(ValueError, match="kind"):
        suite.Axis("x", "nope", (1,))
    with pytest.raises(ValueError, match="no points"):
        env_axis(())
    with pytest.raises(ValueError, match="duplicate"):
        SweepPlan.product(env_axis((1,)), env_axis((2,)))
    with pytest.raises(ValueError, match="at least one axis"):
        SweepPlan.product()


def test_ladder_is_a_one_axis_plan():
    lad = Ladder("t", (256, 512), (256, 512, 1024), transform=_halo)
    pts = lad.plan().points(quick=False)
    assert [p.label for p in pts] == ["n256", "n512", "n1024"]
    assert [dict(p.env)["n"] for p in pts] == [258, 514, 1026]


def test_workload_requires_exactly_one_of_ladder_and_plan():
    lad = Ladder("t", (256,), (256,))
    plan = lad.plan()
    kw = dict(name="w", pattern=lambda env: triad(),
              variants=(VariantSpec("v", DriverConfig()),))
    with pytest.raises(ValueError, match="exactly one"):
        Workload(**kw)
    with pytest.raises(ValueError, match="exactly one"):
        Workload(**kw, ladder=lad, plan=plan)
    assert Workload(**kw, ladder=lad).sweep_plan().points(True) \
        == plan.points(True)


# ---------------------------------------------------------------------------
# ladder-compat equivalence: plan engine vs the pre-engine loop
# ---------------------------------------------------------------------------

_IDENTITY_FIELDS = ("pattern", "template", "schedule", "backend", "n",
                    "working_set_bytes", "programs", "ntimes", "level")


def _legacy_collect(w, quick, cache, parametric):
    """The pre-engine runner loop: one Driver per variant over the
    ladder's env points, labels ``{figure}/{variant}/n{point}``."""
    pts = list(w.ladder.points(quick))
    ns = [w.ladder.env_n(p) for p in pts]
    out = []
    for v in w.variant_list(quick):
        cfg = v.config
        if cfg.parametric is None:
            cfg = dataclasses.replace(cfg, parametric=parametric)
        d = Driver(v.pattern or w.pattern, cfg, cache=cache)
        if w.validate and d.cfg.validate_n:
            d.validate()
        for p, rec in zip(pts, d.run(ns)):
            out.append((f"{w.figure}/{v.label}/n{p}", rec))
    return out


def test_ladder_workload_matches_legacy_loop_through_engine():
    # halo'd env sizes (p + 2) stay divisible by both program counts
    lad = Ladder("t", (254, 510), (254, 510), transform=_halo)
    w = Workload(
        name="compat", figure="figX",
        pattern=lambda env: triad(),
        variants=(
            VariantSpec("unified", DriverConfig(
                template="unified", programs=4, ntimes=2, reps=1)),
            VariantSpec("independent", DriverConfig(
                template="independent", programs=2, ntimes=2, reps=1)),
        ),
        ladder=lad,
    )
    legacy = _legacy_collect(w, True, TranslationCache(), "auto")
    new = collect_records(w, quick=True, cache=TranslationCache())
    assert [l for l, _ in legacy] == [l for l, _ in new]
    for (_, a), (lbl, b) in zip(legacy, new):
        for f in _IDENTITY_FIELDS:
            assert getattr(a, f) == getattr(b, f), (lbl, f)
        assert a.extra["parametric"] == b.extra["parametric"], lbl
    # the engine additionally self-describes each point
    assert [r.extra["axis_point"] for _, r in new] == [
        {"n": 254}, {"n": 510}, {"n": 254}, {"n": 510}]


# ---------------------------------------------------------------------------
# pointer chase: oracle + guards
# ---------------------------------------------------------------------------


def test_chase_permutation_is_a_single_cycle():
    pat = pointer_chase()
    P = pat.allocate({"n": 64})["P"]
    seen, h = [], 0
    for _ in range(64):
        seen.append(h)
        h = int(P[h])
    assert h == 0 and sorted(seen) == list(range(64))


def test_pointer_chase_kernel_matches_oracle():
    d = Driver(lambda env: pointer_chase(),
               DriverConfig(template="unified", programs=1, ntimes=2,
                            reps=1, validate_n=96),
               cache=TranslationCache())
    d.validate()  # custom oracle replay
    recs = d.run([128, 256])
    assert [r.n for r in recs] == [128, 256]
    for r in recs:
        assert not r.extra["parametric"]
        assert r.extra["points"] == r.n
        assert latency_ns(r) > 0.0
    # the chase head after one step call is the n-fold image of 0
    pat = pointer_chase()
    arrays = pat.allocate({"n": 128})
    want = pat.oracle(pat, arrays, {"n": 128}, ntimes=1)
    lowered = d.lower({"n": 128})
    import jax.numpy as jnp

    got = {k: jnp.asarray(v) for k, v in arrays.items()}
    got = lowered.step(got)
    assert int(got["H"][0]) == int(want["H"][0])


def test_pointer_chase_guards():
    pat = pointer_chase()
    with pytest.raises(ValueError, match="custom kernel"):
        independent_view(pat, programs=4)
    d = Driver(lambda env: pointer_chase(),
               DriverConfig(template="unified", programs=1, ntimes=2,
                            reps=1, parametric=True),
               cache=TranslationCache())
    with pytest.raises(SymbolicLowerError):
        d.run([128, 256])
    with pytest.raises(ValueError, match="schedule"):
        stage_lower(pat, identity().tile("i", 8), {"n": 64})


# ---------------------------------------------------------------------------
# load sweep: record schema
# ---------------------------------------------------------------------------


def test_mess_load_sweep_record_schema():
    load_builtins()
    w = suite.workload("mess_load_sweep")
    assert set(w.tags) == {"mess"}
    rows = collect_records(w, quick=True, cache=TranslationCache())
    axes = [a.name for a in w.sweep_plan().axes]
    assert axes == ["programs", "ntimes", "n"]
    n_expected = 1
    for a in w.sweep_plan().axes:
        n_expected *= len(a.points(True))
    assert len(rows) == n_expected
    for lbl, rec in rows:
        ap = rec.extra["axis_point"]
        assert set(ap) == {"programs", "ntimes", "n"}
        # the config axes actually landed in the measured config
        assert rec.programs == ap["programs"]
        assert rec.ntimes == ap["ntimes"]
        assert lbl == (f"mess/triad/programs{ap['programs']}"
                       f"/ntimes{ap['ntimes']}/n{ap['n']}")
        derived = w.derived(rec)
        assert "GB/s" in derived and "us/access" in derived


def test_mess_calibrated_zip_pairs_latency_with_bandwidth():
    """The zip-mode calibration scenario: each zipped pressure point
    (working set, burst length) must yield exactly one latency record
    and one bandwidth record with IDENTICAL axis_point coordinates, so
    downstream pairing is a dict join — the Mess calibration contract."""
    load_builtins()
    w = suite.workload("mess_calibrated")
    assert set(w.tags) == {"mess", "latency"}
    # zip-length validation: quick and full modes must stay in lockstep
    for quick in (True, False):
        counts = {len(a.points(quick)) for a in w.sweep_plan().axes}
        assert len(counts) == 1, (quick, counts)
    rows = collect_records(w, quick=True, cache=TranslationCache())
    pts = w.sweep_plan().points(True)
    assert len(rows) == 2 * len(pts)
    by_point: dict = {}
    for lbl, rec in rows:
        ap = rec.extra["axis_point"]
        assert set(ap) == {"n", "ntimes"}
        assert rec.ntimes == ap["ntimes"]     # config axis landed
        assert rec.n == ap["n"]
        variant = lbl.split("/")[1]
        by_point.setdefault(tuple(sorted(ap.items())), {})[variant] = rec
        derived = w.derived(rec)
        if variant == "latency":
            assert rec.pattern == "pointer_chase"
            assert "ns/access" in derived
            assert rec.extra["param_path"] == "specialized"
        else:
            assert rec.pattern.startswith("triad")  # triad.indep4
            assert "GB/s" in derived and "us/access" in derived
    for key, pair in by_point.items():
        assert set(pair) == {"latency", "bandwidth"}, key
        # matched pressure: both variants measured the same point
        assert pair["latency"].n == pair["bandwidth"].n
        assert pair["latency"].ntimes == pair["bandwidth"].ntimes
        assert latency_ns(pair["latency"]) > 0.0, key


def test_mess_calibrated_zip_mismatch_is_loud():
    """A zip plan whose axes disagree on point counts must fail at
    expansion, not mid-measurement."""
    load_builtins()
    w = suite.workload("mess_calibrated")
    bad = dataclasses.replace(
        w, plan=SweepPlan.zip(env_axis((256, 512)),
                              config_axis("ntimes", (2,))))
    with pytest.raises(ValueError, match="disagree"):
        collect_records(bad, quick=True, cache=TranslationCache())


def test_spatter_nonuniform_specializes_strides_shares_envs():
    load_builtins()
    w = suite.workload("spatter_nonuniform")
    one = dataclasses.replace(
        w, variants=(w.variant_list(True)[0],),
        plan=SweepPlan.product(
            pattern_axis("stride", (2, 8)),
            env_axis((256, 512, 1024)),
        ),
    )
    cache = TranslationCache()
    rows = collect_records(one, quick=True, cache=cache)
    assert [r.extra["axis_point"] for _, r in rows] == [
        {"stride": s, "n": n} for s in (2, 8) for n in (256, 512, 1024)]
    # each stride is its own pattern (specialized), but its env ladder
    # shares one parametric executable
    assert all(r.extra["parametric"] for _, r in rows)
    assert {r.extra["capacity"] for _, r in rows} == {1024}
    per_stride = {r.pattern for _, r in rows}
    assert per_stride == {"gather2", "gather8"}


def test_grouping_is_axis_order_independent():
    """An env axis ordered *before* a config axis must still share one
    parametric executable per config value, and rows stay in plan order."""
    w = Workload(
        name="order", figure="ord",
        pattern=lambda env: triad(),
        variants=(VariantSpec("t", DriverConfig(
            template="unified", ntimes=2, reps=1)),),
        plan=SweepPlan.product(
            env_axis((256, 512, 1024)),          # env FIRST (fastest = config)
            config_axis("programs", (2, 4)),
        ),
    )
    cache = TranslationCache()
    rows = collect_records(w, quick=True, cache=cache)
    assert [lbl for lbl, _ in rows] == [
        f"ord/t/n{n}/programs{p}" for n in (256, 512, 1024) for p in (2, 4)]
    assert all(r.extra["parametric"] for _, r in rows)
    # one compile per program count, not per (program, n) point
    assert cache.stats()["compile_misses"] == 2


def _mcopy(env):
    """copy with an independently-sized source: A[i] = B[i], |B| = m."""
    from repro.core import Access, DataSpace, PatternSpec, Statement, domain

    stmt = Statement(reads=(Access("B", ("i",)),), write=Access("A", ("i",)),
                     combine=lambda vals, env: vals[0])
    return PatternSpec(
        "mcopy",
        (DataSpace("A", ("n",), "float32", 0.0),
         DataSpace("B", ("m",), "float32", 2.0)),
        stmt, domain(("i", 0, "n")), flops_per_point=0)


def test_extra_env_axes_reach_validation():
    """A second env axis ('m') must be threaded into the oracle env —
    validation would otherwise fail with unbound symbols."""
    w = Workload(
        name="two_env", figure="m",
        pattern=_mcopy,
        variants=(VariantSpec("copy", DriverConfig(
            template="unified", programs=4, ntimes=2, reps=1)),),
        plan=SweepPlan.zip(
            env_axis((256, 512)),
            env_axis((512, 1024), name="m"),
        ),
    )
    rows = collect_records(w, quick=True, cache=TranslationCache())
    assert [r.extra["axis_point"] for _, r in rows] == [
        {"n": 256, "m": 512}, {"n": 512, "m": 1024}]
    # points disagree on m, so they cannot share one parametric executable
    assert not any(r.extra["parametric"] for _, r in rows)


def test_plan_without_n_env_axis_is_rejected():
    w = Workload(
        name="no_n", figure="x",
        pattern=lambda env: triad(),
        variants=(VariantSpec("t", DriverConfig()),),
        plan=SweepPlan.product(config_axis("programs", (2,)),
                               env_axis((64,), name="m")),
    )
    with pytest.raises(ValueError, match="env axis targeting"):
        collect_records(w, quick=True, cache=TranslationCache())


# ---------------------------------------------------------------------------
# LRU translation cache
# ---------------------------------------------------------------------------


def test_translation_cache_lru_eviction_and_stats():
    cache = TranslationCache(capacity=2)
    pat = triad()
    sch = identity()

    def lower(n):
        return stage_lower(pat, sch, {"n": n}, cache=cache)

    lower(64), lower(128)
    assert cache.stats()["evictions"] == 0
    lower(64)                      # refresh 64 -> 128 is now LRU
    lower(256)                     # evicts 128
    s = cache.stats()
    assert s["evictions"] == 1 and s["capacity"] == 2
    assert s["validated_drops"] == 0  # memo clears are a separate counter
    base = cache.stats()["lower_misses"]
    lower(64)                      # survived (recently used)
    assert cache.stats()["lower_misses"] == base
    lower(128)                     # was evicted: rebuilt
    assert cache.stats()["lower_misses"] == base + 1
    with pytest.raises(ValueError, match="capacity"):
        TranslationCache(capacity=0)


# ---------------------------------------------------------------------------
# tags
# ---------------------------------------------------------------------------


def test_registry_tags_and_tag_filtering():
    load_builtins()
    assert set(suite.all_tags()) >= {"paper-figs", "spatter", "mess",
                                     "latency"}
    assert "latency" in suite.workload("pointer_chase").tags
    assert "spatter" in suite.workload("spatter_nonuniform").tags
    assert "paper-figs" in suite.workload("fig05_barriers").tags


def test_run_list_tag_filter(capsys):
    from benchmarks.run import main

    main(["--list", "--tag", "spatter"])
    out = capsys.readouterr().out
    listed = {ln.split()[0] for ln in out.strip().splitlines()}
    assert listed == {"spatter_uniform", "spatter_nonuniform", "spatter_ms1"}
    main(["--list", "--tag", "latency,mess"])
    out = capsys.readouterr().out
    listed = {ln.split()[0] for ln in out.strip().splitlines()}
    assert listed == {"mess_load_sweep", "pointer_chase", "mess_calibrated",
                      "mess_contended"}
    main(["--list", "--tag", "trace"])
    out = capsys.readouterr().out
    listed = {ln.split()[0] for ln in out.strip().splitlines()}
    assert listed == {"spatter_ms1", "mess_contended"}
    # the custom paper-figure runners belong to the family too
    main(["--list", "--tag", "paper-figs"])
    out = capsys.readouterr().out
    listed = {ln.split()[0] for ln in out.strip().splitlines()}
    assert {"fig16_tile_sweep", "roofline", "fig05_barriers"} <= listed
    assert "spatter_uniform" not in listed


# ---------------------------------------------------------------------------
# PR-8: execution backends + device axis
# ---------------------------------------------------------------------------

# timing-only payload: the fields/keys allowed to differ across backends
_TIMING_REC_FIELDS = {"seconds", "gbs", "gflops"}
_TIMING_EXTRA_KEYS = {"timing_quality", "compile_seconds", "lower_seconds",
                      "cache_hit"}


def _normalized_rows(report):
    """Record content modulo timing — everything the execution backend
    must keep identical to serial order."""
    out = []
    for row in report.rows:
        rec = row.record
        fields = tuple(
            (f.name, getattr(rec, f.name))
            for f in dataclasses.fields(rec)
            if f.name not in _TIMING_REC_FIELDS and f.name != "extra")
        extra = tuple(sorted(
            ((k, v) for k, v in rec.extra.items()
             if k not in _TIMING_EXTRA_KEYS), key=str))
        out.append((row.variant, row.point.label, fields, extra))
    return out


_EXEC_CFG = DriverConfig(template="unified", ntimes=2, reps=1)


def _backend_report(backend):
    plan = SweepPlan.product(config_axis("programs", (1, 2)),
                             env_axis((256, 512)))
    return suite.run_plan(
        lambda env: triad(), [VariantSpec("t", _EXEC_CFG)], plan,
        quick=True, cache=TranslationCache(), backend=backend)


def test_backend_equivalence_and_executor_stats():
    ser = _backend_report(suite.SerialBackend())
    tp = _backend_report(suite.ThreadPoolBackend(4))
    assert _normalized_rows(ser) == _normalized_rows(tp)
    assert ser.executor["backend"] == "serial"
    assert ser.executor["workers"] == 1
    # serial stages everything before the first measurement: no overlap
    assert ser.executor["staging_overlap_seconds"] == 0.0
    assert tp.executor["backend"] == "threadpool"
    assert tp.executor["workers"] == 4
    for key in ("groups", "stage_seconds", "measure_seconds",
                "stage_wall_seconds", "first_measure_seconds",
                "staging_overlap_seconds", "wall_seconds"):
        assert key in ser.executor and key in tp.executor, key


def test_threadpool_backend_rejects_nonpositive_workers():
    with pytest.raises(ValueError, match="worker"):
        suite.ThreadPoolBackend(0)


def _exec_poisoned(env, stride=2):
    from repro.core import gather

    if stride == 13:
        raise RuntimeError("injected poison")
    return gather(stride=stride)


def test_threadpool_fault_isolation_per_worker():
    plan = SweepPlan.product(pattern_axis("stride", (2, 13, 8)),
                             env_axis((256,)))
    report = suite.run_plan(_exec_poisoned, [VariantSpec("g", _EXEC_CFG)],
                            plan, quick=True, cache=TranslationCache(),
                            backend=suite.ThreadPoolBackend(3))
    # the poisoned group fails inside its worker; the survivors' records
    # arrive complete and in plan order
    assert [r.point.label for r in report.rows] == ["stride2/n256",
                                                    "stride8/n256"]
    assert [f.label for f in report.failures] == ["stride13/n256"]
    assert report.failures[0].stage == "lower"
    assert report.failures[0].attempts >= 2  # the demotion ladder ran
    assert not report.ok


def _exec_all_poisoned(env, stride=2):
    raise RuntimeError(f"poison {stride}")


def test_threadpool_strict_raises_first_error_in_plan_order():
    plan = SweepPlan.product(pattern_axis("stride", (13, 17)),
                             env_axis((256,)))
    with pytest.raises(RuntimeError, match="poison 13"):
        suite.run_plan(_exec_all_poisoned, [VariantSpec("g", _EXEC_CFG)],
                       plan, quick=True, cache=TranslationCache(),
                       on_error="raise",
                       backend=suite.ThreadPoolBackend(2))


def test_device_axis_expansion_labels_and_stamp():
    import jax

    plan = SweepPlan.product(suite.device_axis((0, 1)),
                             env_axis((256, 512)))
    pts = plan.points(quick=True)
    assert [p.label for p in pts] == ["dev0/n256", "dev0/n512",
                                     "dev1/n256", "dev1/n512"]
    assert dict(pts[0].config) == {"device": 0}
    assert pts[0].axis_point() == {"device": 0, "n": 256}
    # distinct device values are distinct driver groups (one executable
    # pinned per device), while env points within a device share one
    assert pts[0].group_key == pts[1].group_key
    assert pts[0].group_key != pts[2].group_key

    report = suite.run_plan(lambda env: triad(),
                            [VariantSpec("t", _EXEC_CFG)], plan, quick=True,
                            cache=TranslationCache(),
                            backend=suite.ThreadPoolBackend(2))
    assert report.ok
    ndev = len(jax.devices())
    for row in report.rows:
        d = row.record.extra["device"]
        axis = row.point.axis_point()["device"]
        # the axis value survives verbatim; the resolved device wraps
        # modulo the visible device count (dev1 -> device 0 on a
        # 1-device host), so plans port across mesh sizes
        assert d["axis"] == axis
        assert d["id"] == axis % ndev
        assert d["platform"] == jax.devices()[0].platform


def test_measure_lock_key_resolves_physical_device():
    """The ThreadPoolBackend measure lock keys on the *resolved*
    physical device, not the raw ``cfg.device`` index: indices that
    wrap onto one device (dev0/dev1 on a 1-device host) and the
    unpinned default must share one lock, or such groups would time
    concurrently on shared hardware."""
    import types

    import jax

    from repro.suite import engine as engine_mod

    def key(device):
        drv = Driver(lambda env: triad(), DriverConfig(device=device))
        unit = engine_mod._GroupRun(
            variant=None, group=types.SimpleNamespace(driver=drv),
            validate=False, max_check_n=0, policy=None, strict=False,
            jr=None, keys=None)
        return unit.device_key

    ndev = len(jax.devices())
    assert key(0) == key(ndev)      # wrapped index -> same hardware
    assert key(None) == key(0)      # unpinned runs on the default device
    assert key(1) == key(1 + ndev)
    if ndev > 1:
        assert key(0) != key(1)     # distinct devices keep distinct locks


@pytest.mark.slow
def test_backend_equivalence_every_declarative_workload():
    """ThreadPoolBackend must reproduce SerialBackend's records (modulo
    timing) for every registered declarative workload — the PR-8
    acceptance contract, registry-wide."""
    load_builtins()
    for w in suite.workloads():
        if w.runner is not None:
            continue
        ser = suite.collect_report(w, quick=True, cache=TranslationCache(),
                                   backend=suite.SerialBackend())
        tp = suite.collect_report(w, quick=True, cache=TranslationCache(),
                                  backend=suite.ThreadPoolBackend(4))
        assert _normalized_rows(ser) == _normalized_rows(tp), w.name
