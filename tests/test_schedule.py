"""Property tests for the polyhedral-lite schedule engine.

Invariants (the legality contract of AdaptMemBench's transformations):
every Schedule built from the fluent API is a *bijection on the iteration
set* — the multiset of executed points equals the domain's point set —
for arbitrary compositions of interchange/tile/interleave/unroll/reverse.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Affine, domain, identity
from repro.core.schedule import Schedule


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


@st.composite
def schedules_1d(draw, extent: int):
    sch = identity()
    dim = "i"
    n_t = draw(st.integers(0, 3))
    cur_extent = extent
    for _ in range(n_t):
        kind = draw(st.sampled_from(["interleave", "unroll", "reverse", "tile"]))
        if kind == "reverse":
            sch = sch.reverse(dim)
        elif kind == "tile":
            size = draw(st.sampled_from(_divisors(cur_extent)))
            if size in (0, cur_extent):
                continue
            sch = sch.tile(dim, size)
            dim = f"{dim}_t"   # keep transforming the inner band
            cur_extent = size
        else:
            f = draw(st.sampled_from(_divisors(cur_extent)))
            if f in (0,):
                continue
            sch = getattr(sch, kind)(dim, f)
            cur_extent //= f
    return sch


@settings(max_examples=60, deadline=None)
@given(st.integers(4, 48), st.data())
def test_1d_schedules_preserve_iteration_set(n, data):
    dom = domain(("i", 0, "n"))
    env = {"n": n}
    sch = data.draw(schedules_1d(n))
    nest = sch.lower(dom, env)
    pts = sorted(nest.executed_points())
    assert pts == [(i,) for i in range(n)], sch.name


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 12), st.integers(2, 12), st.booleans(), st.booleans())
def test_2d_interchange_tile(n0, n1, interchange, rev):
    dom = domain(("i", 0, "n0"), ("j", 1, Affine.of("n1") + 1))
    env = {"n0": n0, "n1": n1}
    sch = identity()
    if interchange:
        sch = sch.interchange("i", "j")
    if rev:
        sch = sch.reverse("j")
    for size in (2, 3):
        if n0 % size == 0:
            sch = sch.tile("i", size)
            break
    nest = sch.lower(dom, env)
    got = sorted(nest.executed_points())
    want = sorted((i, j) for i in range(n0) for j in range(1, n1 + 1))
    assert got == want


def test_interchange_changes_order_not_set():
    dom = domain(("i", 0, "n"), ("j", 0, "n"))
    env = {"n": 3}
    base = list(identity().lower(dom, env).executed_points())
    swapped = list(identity().interchange("i", "j").lower(dom, env)
                   .executed_points())
    assert base != swapped
    assert sorted(base) == sorted(swapped)
    # lexicographic in j-major order after interchange
    assert swapped == [(i, j) for j in range(3) for i in range(3)]


def test_interleave_matches_paper_listing7():
    """interleave(i, 2) must execute body(i), body(i + n/2) per iteration."""
    dom = domain(("i", 0, "n"))
    nest = identity().interleave("i", 2).lower(dom, {"n": 8})
    pts = list(nest.executed_points())
    assert pts == [(0,), (4,), (1,), (5,), (2,), (6,), (3,), (7,)]


def test_tile_guard_detection():
    dom = domain(("i", 0, "n"))
    nest = identity().tile("i", 4).lower(dom, {"n": 10})  # 10 % 4 != 0
    assert nest.needs_guard()
    pts = sorted(nest.executed_points())
    assert pts == [(i,) for i in range(10)]  # guards drop the overrun
    nest2 = identity().tile("i", 5).lower(dom, {"n": 10})
    assert not nest2.needs_guard()


def test_interleave_requires_divisibility():
    dom = domain(("i", 0, "n"))
    with pytest.raises(ValueError):
        identity().interleave("i", 3).lower(dom, {"n": 8})


def test_skew_preserves_set_with_guards():
    dom = domain(("i", 0, "n"), ("j", 0, "n"))
    env = {"n": 4}
    nest = identity().skew("j", "i", 1).lower(dom, env)
    # skewed j runs out of domain for some band points; guards drop them
    pts = sorted(set(nest.executed_points()))
    inside = [(i, j) for i in range(4) for j in range(4)]
    assert set(pts).issubset(set(inside))
