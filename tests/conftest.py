import pathlib
import sys

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# hypothesis is an optional dependency: fall back to the deterministic
# stub so the property tests still collect and run without it.
try:
    import hypothesis  # noqa: F401
except ImportError:
    TESTS = pathlib.Path(__file__).resolve().parent
    if str(TESTS) not in sys.path:
        sys.path.insert(0, str(TESTS))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
