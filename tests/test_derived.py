"""Application-derived workloads (the PR-9 acceptance contract).

The ``suite/derived.py`` pipeline mines the compiled HLO of the repo's
real applications (attention / MoE / LM forwards, the train step) and
synthesizes registry workloads replaying the mined shapes. These tests
pin the contract end-to-end: extraction finds the ops the classifier
needs (the MoE and LM gathers, attention's strided reads), the feature
vector is deterministic and non-degenerate, the affine derived patterns
are bit-exact across every eligible lowering regime, the kernel-hook
ones match their numpy oracles, and every measured record carries the
``extra["derived"]`` provenance stamp.
"""
from __future__ import annotations

import math

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Driver, DriverConfig, TranslationCache, identity
from repro.suite.derived import (
    DERIVED_MODELS,
    attention_kv_pattern,
    derive_spec,
    derived_report,
    feature_vector,
    lm_embed_pattern,
    model_traffic,
    moe_dispatch_pattern,
    train_update_pattern,
)
from test_parametric_paths import _check_all_regimes

_FEATURES = ("stride_entropy", "reuse_distance", "gather_fraction")


# ---------------------------------------------------------------------------
# extraction + classification
# ---------------------------------------------------------------------------


def test_extraction_mines_real_ops():
    """The compiled applications expose the ops the classifier keys on:
    the MoE dispatch and LM embedding lookups both lower to ``gather``
    (the scatter-add may fuse on CPU — no standalone op is required),
    and attention's KV streaming shows up as dot/slice traffic."""
    moe = model_traffic("moe")
    assert "gather" in moe.ops and moe.ops["gather"].result_bytes > 0
    lm = model_traffic("lm")
    assert "gather" in lm.ops
    attn = model_traffic("attention")
    assert any(op in attn.ops for op in ("dot", "dynamic-slice", "slice"))
    for t in (moe, lm, attn):
        assert t.flops > 0 and t.bytes_accessed > 0
        for op, traffic in t.ops.items():
            assert traffic.count >= 1, (t.model, op)
            assert traffic.unknown_dtypes == (), (t.model, op)


def test_derive_spec_classifies_every_model():
    for name, (model, access_class) in DERIVED_MODELS.items():
        spec = derive_spec(model, access_class)
        assert spec.model == model and spec.access_class == access_class
        assert spec.source_op and spec.source_op != "unknown", name
        stamp = spec.stamp()
        assert set(stamp) == {"source_model", "source_op", "access_class",
                              "feature_vector"}
        fv = stamp["feature_vector"]
        assert set(fv) == set(_FEATURES), name
        vals = [fv[k] for k in _FEATURES]
        assert all(math.isfinite(v) for v in vals), (name, fv)
        assert any(abs(v) > 1e-9 for v in vals), (name, fv)
    # the mined provenance is model-specific, not one blob repeated
    stamps = {derive_spec(m, c).feature_vector
              for m, c in DERIVED_MODELS.values()}
    assert len(stamps) == len(DERIVED_MODELS)


def test_feature_vector_is_deterministic():
    """Same (model, config) -> bit-identical feature vector: the trace
    synthesis seeds its rng from the working-set size, never the clock."""
    for model, access_class in DERIVED_MODELS.values():
        traffic = model_traffic(model)
        a = feature_vector(model, access_class, traffic)
        b = feature_vector(model, access_class, traffic)
        assert a == b, model


def test_moe_route_is_a_permutation():
    """Expert-major dispatch order must be a permutation of the tokens —
    duplicate indices would make the scatter-add float-order sensitive
    and break the bit-exact oracle comparison."""
    pat = moe_dispatch_pattern()
    for n in (64, 257):
        r = pat.allocate({"n": n})["R"]
        assert sorted(int(x) for x in r) == list(range(n))


# ---------------------------------------------------------------------------
# conformance: derived == oracle across eligible regimes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("factory", [attention_kv_pattern,
                                     train_update_pattern])
def test_affine_derived_all_regimes_bit_exact(factory):
    """The affine derived patterns (attention KV stream, optimizer
    update) must agree across specialized / parametric-strided /
    parametric-gather / serial oracle / numpy mirror — the same
    five-way check the hand-written patterns pass."""
    pat = factory()
    _check_all_regimes(pat, identity(), {"n": 40}, {"n": 64}, 16)
    _check_all_regimes(pat, identity(), {"n": 64}, {"n": 64}, 16)


@pytest.mark.parametrize("factory", [moe_dispatch_pattern,
                                     lm_embed_pattern])
def test_kernel_derived_matches_numpy_oracle(factory):
    """The value-dependent derived patterns ride the kernel/oracle hook:
    the staged jax step must reproduce the numpy oracle exactly."""
    d = Driver(lambda env: factory(),
               DriverConfig(template="unified", programs=1, ntimes=2,
                            reps=1, validate_n=96),
               cache=TranslationCache())
    d.validate()
    pat = factory()
    env = {"n": 128}
    arrays = pat.allocate(env)
    want = pat.oracle(pat, arrays, env, ntimes=1)
    got = {k: jnp.asarray(v) for k, v in arrays.items()}
    got = d.lower(env).step(got)
    np.testing.assert_allclose(np.asarray(got["O"]), want["O"],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# record stamping + ledger report
# ---------------------------------------------------------------------------


def test_records_carry_derived_provenance():
    d = Driver(lambda env: attention_kv_pattern(),
               DriverConfig(template="unified", programs=4, ntimes=2,
                            reps=1, validate_n=64),
               cache=TranslationCache())
    spec = derive_spec("attention", "strided")
    for r in d.run([256, 512]):
        stamp = r.extra["derived"]
        assert stamp == spec.stamp()
        assert stamp["source_model"] == "attention"
        assert set(stamp["feature_vector"]) == set(_FEATURES)


def test_derived_report_filters_to_ran_workloads():
    full = derived_report()
    assert set(full) == set(DERIVED_MODELS)
    only = derived_report(names={"derived_moe_dispatch"})
    assert set(only) == {"derived_moe_dispatch"}
    entry = only["derived_moe_dispatch"]
    assert entry["source_model"] == "moe"
    assert entry["source_op"] == "gather"
    assert set(entry["feature_vector"]) == set(_FEATURES)
