"""Driver-template tests: unified/independent semantics, measurement,
tile-traffic counters (the PAPI surrogate), and the autotune sweep."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    Driver, DriverConfig, Variant, identity, jacobi1d, sweep, tile_traffic,
    triad,
)
from repro.core.measure import NATIVE_TILE_BYTES


@pytest.mark.parametrize("template", ["unified", "independent"])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_triad_templates_validate(template, backend):
    d = Driver(lambda env: triad(), DriverConfig(
        template=template, programs=4, backend=backend, ntimes=2, reps=1))
    d.validate()


@pytest.mark.parametrize("template", ["unified", "independent"])
def test_jacobi1d_templates_validate(template):
    d = Driver(lambda env: jacobi1d(), DriverConfig(
        template=template, programs=4, backend="jax", ntimes=2, reps=1,
        validate_n=66))
    d.validate()


def test_interleave_schedule_validates_under_independent():
    d = Driver(lambda env: triad(), DriverConfig(
        template="independent", programs=2, ntimes=2, reps=1,
        schedule=identity().interleave("i", 2)))
    d.validate()


def test_records_have_bandwidth_and_metadata():
    d = Driver(lambda env: triad(), DriverConfig(
        template="unified", programs=4, ntimes=3, reps=1, measured=True))
    recs = d.run([2048])
    (r,) = recs
    assert r.gbs > 0 and r.seconds > 0
    assert r.working_set_bytes == 3 * 2048 * 4
    assert r.level in ("vreg", "vmem", "hbm")
    assert "hlo_flops" in r.extra and "fetches" in r.extra
    assert "triad" in r.csv()


def test_barrier_mode_slower_or_equal_bytes_same():
    fused = Driver(lambda env: triad(), DriverConfig(
        template="unified", programs=2, ntimes=8, reps=2)).run([4096])[0]
    barrier = Driver(lambda env: triad(), DriverConfig(
        template="unified", programs=2, ntimes=8, reps=2,
        sync_every_rep=True)).run([4096])[0]
    # same accounted bytes; the barrier variant includes dispatch overhead
    assert fused.ntimes == barrier.ntimes
    assert barrier.seconds >= 0.3 * fused.seconds  # sanity, not strict perf


def test_tile_traffic_false_sharing_signal():
    """Unaligned program rows share native tiles; padding to the tile
    boundary eliminates shared-write tiles — paper Fig. 10 in miniature."""
    tile_elems = NATIVE_TILE_BYTES // 4
    rows_unpadded = {
        "A": (0, 1000), "B": (0, 1000)}, {"A": (1000, 2000), "B": (1000, 2000)}
    t_unpadded = tile_traffic(
        spaces={"A": (2000,), "B": (2000,)},
        program_slices=list(rows_unpadded), written="A")
    assert t_unpadded.shared_write_tiles >= 1

    rows_padded = ({"A": (0, 1000)}, {"A": (tile_elems, tile_elems + 1000)})
    t_padded = tile_traffic(
        spaces={"A": (2 * tile_elems,)},
        program_slices=list(rows_padded), written="A")
    assert t_padded.shared_write_tiles == 0


def test_sweep_returns_best():
    res = sweep(
        lambda env: triad(),
        [Variant("a", DriverConfig(template="independent", programs=2,
                                   ntimes=2, reps=1)),
         Variant("b", DriverConfig(template="independent", programs=2,
                                   ntimes=2, reps=1,
                                   schedule=identity().interleave("i", 2)))],
        [2048], validate=False)
    assert res.best[0] in ("a", "b")
    assert "variant,n,GB/s" in res.table()


def test_independent_padding_changes_row_stride():
    from repro.core.drivers import independent_view
    pat = independent_view(triad(), programs=4, pad=32)
    shapes = {s.name: s.concrete_shape({"n": 256}) for s in pat.spaces}
    assert shapes["A"] == (4, 288)
    # statement rewired through the program dim
    assert pat.statement.write.index[0] == "p"
