"""Suite-layer + parametric-lowering tests (the PR-2 acceptance contract).

Covers: symbolic lowering equivalence with concrete lowering, the
parametric executable's value correctness against the serial oracle,
the one-compile-per-ladder cache property, parametric-vs-specialized
record equivalence for every registered declarative workload in quick
mode, registry round-trip against the harness executor, the ladder/CSV
re-export shim, the Spatter pattern specs, and the disk-cache keying of
``TranslationCache.stats()``.
"""
from __future__ import annotations

import dataclasses
import pathlib
import sys

import numpy as np
import pytest

from repro.core import (
    Affine,
    Driver,
    DriverConfig,
    SymbolicLowerError,
    TranslationCache,
    domain,
    gather,
    gather_scatter,
    identity,
    jacobi1d,
    scatter,
    triad,
)
from repro import suite
from repro.suite import collect_records, load_builtins

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))  # make the benchmarks package importable


# ---------------------------------------------------------------------------
# symbolic lowering
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sch", [
    identity(),
    identity().tile_by_count("i", 4, outer="prog", inner="i"),
    identity().interleave("i", 2),
    identity().reverse("i"),
    identity().tile("i", 8),
    identity().tile_by_count("i", 4).interchange("i_T", "i_t"),
])
def test_symbolic_lowering_concretizes_to_concrete(sch):
    dom = domain(("i", 1, Affine.of("n") - 1))
    pnest = sch.lower_symbolic(dom, ("n",))
    for n in (10, 18, 66, 130):
        env = {"n": n}
        if not pnest.admits(env):
            continue
        assert pnest.concretize(env) == sch.lower(dom, env), (sch.name, n)


def test_symbolic_lowering_records_divisibility_constraints():
    dom = domain(("i", 0, "n"))
    pnest = identity().tile_by_count("i", 4).lower_symbolic(dom, ("n",))
    assert pnest.constraints == ((Affine.of("n"), 4),)
    assert pnest.admits({"n": 128}) and not pnest.admits({"n": 130})


def test_symbolic_lowering_rejects_triangular_domains():
    dom = domain(("i", 0, "n"), ("j", 0, "i"))
    with pytest.raises(SymbolicLowerError):
        identity().lower_symbolic(dom, ("n",))


def test_tile_by_count_matches_old_unified_tile():
    """The unified template's new split must generate the same nest as
    the old tile(extent // programs) form."""
    dom = domain(("i", 0, "n"))
    env = {"n": 64}
    new = identity().tile_by_count("i", 4, outer="prog", inner="i")
    old = identity().tile("i", 16, outer="prog", inner="i")
    assert (list(new.lower(dom, env).executed_points())
            == list(old.lower(dom, env).executed_points()))


# ---------------------------------------------------------------------------
# parametric pipeline: values + cache economics
# ---------------------------------------------------------------------------


def test_parametric_values_match_oracle_across_templates():
    for tmpl, factory, ns in [
        ("unified", triad, [256, 512, 1024]),
        ("independent", triad, [256, 512]),
        ("unified", jacobi1d, [258, 514]),
    ]:
        d = Driver(
            lambda env, f=factory: f(),
            DriverConfig(template=tmpl, programs=4, ntimes=2, reps=1,
                         parametric="auto"),
            cache=TranslationCache(),
        )
        d.validate_parametric(ns)


def test_parametric_ladder_compiles_exactly_once():
    """A 4-point ladder produces exactly 1 compile (and lower) miss on
    the parametric path — the whole ladder shares one executable."""
    cache = TranslationCache()
    d = Driver(lambda env: triad(),
               DriverConfig(template="unified", programs=4, ntimes=2,
                            reps=1, parametric="auto"), cache=cache)
    recs = d.run([256, 512, 1024, 2048])
    s = cache.stats()
    assert s["compile_misses"] == 1 and s["lower_misses"] == 1
    assert s["compile_hits"] == 3 and s["lower_hits"] == 3
    assert all(r.extra["parametric"] for r in recs)
    assert {r.extra["capacity"] for r in recs} == {2048}
    assert [r.n for r in recs] == [256, 512, 1024, 2048]


def test_parametric_falls_back_when_constraints_fail():
    """auto mode: a ladder whose points violate a symbolic divisibility
    assumption (here tile(48) with 48 ∤ n — the concrete path handles it
    with guards) silently specializes instead of sharing an executable."""
    cache = TranslationCache()
    d = Driver(lambda env: triad(),
               DriverConfig(template="independent", programs=2, ntimes=2,
                            reps=1, schedule=identity().tile("i", 48),
                            parametric="auto"), cache=cache)
    recs = d.run([256, 128])
    assert not any(r.extra["parametric"] for r in recs)
    assert cache.stats()["compile_misses"] == 2


def test_parametric_true_raises_when_unsupported():
    d = Driver(lambda env: triad(),
               DriverConfig(template="unified", programs=4, ntimes=2,
                            reps=1, backend="pallas", parametric=True),
               cache=TranslationCache())
    with pytest.raises(SymbolicLowerError):
        d.run([256])


# ---------------------------------------------------------------------------
# registered workloads: parametric-vs-specialized record equivalence
# ---------------------------------------------------------------------------

_IDENTITY_FIELDS = ("pattern", "template", "schedule", "backend", "n",
                    "working_set_bytes", "programs", "ntimes", "level")


def _shrunk(w):
    """Same workload with a cheap measurement budget (records stay
    comparable across modes because both use the same configs)."""
    variants = tuple(
        dataclasses.replace(
            v, config=dataclasses.replace(
                v.config, ntimes=min(v.config.ntimes, 4), reps=1))
        for v in w.variant_list(True)
    )
    return dataclasses.replace(w, variants=variants, post=None)


def test_every_registered_workload_parametric_equals_specialized():
    load_builtins()
    declarative = [w for w in suite.workloads() if w.runner is None]
    assert len(declarative) >= 9
    for w in declarative:
        ws = _shrunk(w)
        spec = collect_records(ws, quick=True, cache=TranslationCache(),
                               parametric=False)
        par = collect_records(ws, quick=True, cache=TranslationCache(),
                              parametric="auto")
        assert [lbl for lbl, _ in spec] == [lbl for lbl, _ in par], w.name
        for (lbl, rs), (_, rp) in zip(spec, par):
            for f in _IDENTITY_FIELDS:
                assert getattr(rs, f) == getattr(rp, f), (w.name, lbl, f)


def test_at_least_one_workload_shares_a_single_executable():
    load_builtins()
    w = _shrunk(suite.workload("fig05_barriers"))
    cache = TranslationCache()
    recs = collect_records(w, quick=True, cache=cache, parametric="auto")
    n_points = len(w.ladder.points(True))
    assert n_points >= 4
    for label, rec in recs:
        assert rec.extra["parametric"], label
    # one compile per (variant), not per (variant, point)
    assert cache.stats()["compile_misses"] == len(w.variant_list(True))


# ---------------------------------------------------------------------------
# registry round-trip + shims
# ---------------------------------------------------------------------------


def test_registry_round_trip_with_harness_executor():
    from benchmarks.run import registered_names

    names = registered_names()
    assert names == list(suite.names())
    for expected in ("fig05_barriers", "fig06_dataspaces", "fig07_streams",
                     "fig09_interleave", "fig10_counters", "fig12_jacobi1d",
                     "fig14_jacobi2d", "fig15_jacobi3d", "spatter_uniform",
                     "mess_load_sweep", "pointer_chase", "spatter_nonuniform",
                     "fig16_tile_sweep", "roofline"):
        assert expected in names
    # lookups resolve and are well-formed (declarative entries carry a
    # sweep plan — a multi-axis one or a ladder's one-axis equivalent)
    for name in names:
        w = suite.workload(name)
        assert w.name == name
        if w.runner is None:
            assert w.sweep_plan().points(True)
        else:
            assert w.ladder is None and w.plan is None


def test_common_shim_reexports_suite_ladders():
    from benchmarks import common
    from repro.suite import FULL_SETS, QUICK_GRID, QUICK_SETS, WORKING_SETS

    assert common.QUICK_SETS == QUICK_SETS
    assert common.sets(True) == QUICK_SETS and common.sets(False) == FULL_SETS
    assert common.grids(True) == QUICK_GRID
    assert tuple(QUICK_SETS) == WORKING_SETS.quick


# ---------------------------------------------------------------------------
# Spatter patterns + disk-cache stats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("factory", [gather, scatter, gather_scatter])
@pytest.mark.parametrize("template", ["unified", "independent"])
def test_spatter_patterns_validate(factory, template):
    d = Driver(lambda env: factory(stride=4),
               DriverConfig(template=template, programs=4, ntimes=2,
                            reps=1), cache=TranslationCache())
    d.validate()


def test_spatter_accounting():
    pat = gather(stride=8)
    assert pat.bytes_per_point() == 8  # one read + one write, f32
    shapes = {s.name: s.concrete_shape({"n": 64}) for s in pat.spaces}
    assert shapes == {"D": (64,), "S": (512,)}


def test_stats_report_disk_cache_counters():
    s = TranslationCache().stats()
    assert set(s["disk"]) == {"enabled", "hits", "misses"}
    assert s["disk"]["hits"] >= 0 and s["disk"]["misses"] >= 0
