"""Suite-layer + parametric-lowering tests (the PR-2/PR-4 contracts).

Covers: symbolic lowering equivalence with concrete lowering, the
parametric executable's value correctness against the serial oracle,
the one-compile-per-ladder cache property, the registry-wide lowering-
regime conformance sweep (every declarative workload record-equivalent
under parametric=False/"auto"/True, with the regime each one selects —
``extra.param_path`` — pinned by an explicit policy table), the
param_path override lever, registry round-trip against the harness
executor, the ladder/CSV re-export shim, the Spatter pattern specs, and
the disk-cache keying of ``TranslationCache.stats()``.
"""
from __future__ import annotations

import dataclasses
import pathlib
import sys

import numpy as np
import pytest

from repro.core import (
    Affine,
    Driver,
    DriverConfig,
    SymbolicLowerError,
    TranslationCache,
    domain,
    gather,
    gather_scatter,
    identity,
    jacobi1d,
    scatter,
    triad,
)
from repro import suite
from repro.suite import collect_records, load_builtins

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))  # make the benchmarks package importable


# ---------------------------------------------------------------------------
# symbolic lowering
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sch", [
    identity(),
    identity().tile_by_count("i", 4, outer="prog", inner="i"),
    identity().interleave("i", 2),
    identity().reverse("i"),
    identity().tile("i", 8),
    identity().tile_by_count("i", 4).interchange("i_T", "i_t"),
])
def test_symbolic_lowering_concretizes_to_concrete(sch):
    dom = domain(("i", 1, Affine.of("n") - 1))
    pnest = sch.lower_symbolic(dom, ("n",))
    for n in (10, 18, 66, 130):
        env = {"n": n}
        if not pnest.admits(env):
            continue
        assert pnest.concretize(env) == sch.lower(dom, env), (sch.name, n)


def test_symbolic_lowering_records_divisibility_constraints():
    dom = domain(("i", 0, "n"))
    pnest = identity().tile_by_count("i", 4).lower_symbolic(dom, ("n",))
    assert pnest.constraints == ((Affine.of("n"), 4),)
    assert pnest.admits({"n": 128}) and not pnest.admits({"n": 130})


def test_symbolic_lowering_rejects_triangular_domains():
    dom = domain(("i", 0, "n"), ("j", 0, "i"))
    with pytest.raises(SymbolicLowerError):
        identity().lower_symbolic(dom, ("n",))


def test_tile_by_count_matches_old_unified_tile():
    """The unified template's new split must generate the same nest as
    the old tile(extent // programs) form."""
    dom = domain(("i", 0, "n"))
    env = {"n": 64}
    new = identity().tile_by_count("i", 4, outer="prog", inner="i")
    old = identity().tile("i", 16, outer="prog", inner="i")
    assert (list(new.lower(dom, env).executed_points())
            == list(old.lower(dom, env).executed_points()))


# ---------------------------------------------------------------------------
# parametric pipeline: values + cache economics
# ---------------------------------------------------------------------------


def test_parametric_values_match_oracle_across_templates():
    for tmpl, factory, ns in [
        ("unified", triad, [256, 512, 1024]),
        ("independent", triad, [256, 512]),
        ("unified", jacobi1d, [258, 514]),
    ]:
        d = Driver(
            lambda env, f=factory: f(),
            DriverConfig(template=tmpl, programs=4, ntimes=2, reps=1,
                         parametric="auto"),
            cache=TranslationCache(),
        )
        d.validate_parametric(ns)


def test_parametric_ladder_compiles_exactly_once():
    """A 4-point ladder produces exactly 1 compile (and lower) miss on
    the parametric path — the whole ladder shares one executable."""
    cache = TranslationCache()
    d = Driver(lambda env: triad(),
               DriverConfig(template="unified", programs=4, ntimes=2,
                            reps=1, parametric="auto"), cache=cache)
    recs = d.run([256, 512, 1024, 2048])
    s = cache.stats()
    assert s["compile_misses"] == 1 and s["lower_misses"] == 1
    assert s["compile_hits"] == 3 and s["lower_hits"] == 3
    assert all(r.extra["parametric"] for r in recs)
    assert {r.extra["capacity"] for r in recs} == {2048}
    assert [r.n for r in recs] == [256, 512, 1024, 2048]


def test_parametric_falls_back_when_constraints_fail():
    """auto mode: a ladder whose points violate a symbolic divisibility
    assumption (here tile(48) with 48 ∤ n — the concrete path handles it
    with guards) silently specializes instead of sharing an executable."""
    cache = TranslationCache()
    d = Driver(lambda env: triad(),
               DriverConfig(template="independent", programs=2, ntimes=2,
                            reps=1, schedule=identity().tile("i", 48),
                            parametric="auto"), cache=cache)
    recs = d.run([256, 128])
    assert not any(r.extra["parametric"] for r in recs)
    assert cache.stats()["compile_misses"] == 2


def test_parametric_true_raises_when_unsupported():
    d = Driver(lambda env: triad(),
               DriverConfig(template="unified", programs=4, ntimes=2,
                            reps=1, backend="pallas", parametric=True),
               cache=TranslationCache())
    with pytest.raises(SymbolicLowerError):
        d.run([256])


# ---------------------------------------------------------------------------
# registered workloads: regime conformance (False / "auto" / True)
# ---------------------------------------------------------------------------

_IDENTITY_FIELDS = ("pattern", "template", "schedule", "backend", "n",
                    "working_set_bytes", "programs", "ntimes", "level")

# Which lowering regime every (workload, variant) is expected to select
# under parametric="auto" in quick mode. This is the auto policy's
# contract: unified programs>1 splits the outer band (multi-band nest ->
# gather), the independent template is single-band (-> strided), custom
# kernels and single-env-point groups cannot share an executable at all
# (-> specialized). A regression in the policy shows up here by name.
_EXPECTED_PATHS = {
    "fig05_barriers": {"barrier": "gather", "nowait": "gather"},
    "fig06_dataspaces": {"unified": "gather", "independent": "strided"},
    "fig07_streams": {None: "specialized"},        # single-point ladder
    "fig09_interleave": {None: "strided"},         # independent + interleave
    "fig10_counters": {None: "specialized"},       # single-point ladder
    "fig12_jacobi1d": {"unified": "gather", "independent": "strided",
                       "indep_padded": "strided"},
    "fig14_jacobi2d": {"unified": "gather", "independent": "strided"},
    "fig15_jacobi3d": {"unified": "gather", "independent": "strided"},
    "spatter_uniform": {None: "gather"},           # unified programs=4
    "mess_load_sweep": {None: "specialized"},      # one env point per group
    "pointer_chase": {None: "specialized"},        # custom kernel
    "spatter_nonuniform": {None: "gather"},        # unified programs=4
    "mess_calibrated": {None: "specialized"},      # zip: one env point/group
    "mess_contended": {None: "specialized"},       # mix kernel
    "device_sweep": {None: "strided"},             # independent template
    "derived_attention_kv": {None: "strided"},     # independent template
    "derived_moe_dispatch": {None: "specialized"},  # custom kernel
    "derived_lm_embed": {None: "specialized"},     # custom kernel
    "derived_train_update": {None: "strided"},     # independent template
    "spatter_ms1": {"ms1": "specialized",          # bound-index kernel
                    "uniform": "gather"},          # affine trace, programs=4
}

# parametric=True must raise for these (custom kernel with no
# variant-level parametric pin)
_TRUE_RAISES = {"pointer_chase", "derived_moe_dispatch", "derived_lm_embed",
                "spatter_ms1", "mess_contended"}

# Window dimensionality the strided regime must resolve per (workload,
# variant): 1-D nests window the lane band alone; the stencil nests
# window an (i x j[, k]) box per step (extra.param_window_rank). Only
# strided-regime variants appear here.
_EXPECTED_WINDOW_RANK = {
    ("fig06_dataspaces", "independent"): 1,
    ("fig09_interleave", None): 1,
    ("fig12_jacobi1d", "independent"): 1,
    ("fig12_jacobi1d", "indep_padded"): 1,
    ("fig14_jacobi2d", "independent"): 2,
    ("fig15_jacobi3d", "independent"): 3,
    ("device_sweep", None): 1,
    ("derived_attention_kv", None): 1,
    ("derived_train_update", None): 1,
}


def _shrunk(w):
    """Same workload with a cheap measurement budget (records stay
    comparable across modes because both use the same configs)."""
    variants = tuple(
        dataclasses.replace(
            v, config=dataclasses.replace(
                v.config, ntimes=min(v.config.ntimes, 4), reps=1))
        for v in w.variant_list(True)
    )
    return dataclasses.replace(w, variants=variants, post=None)


def _variant_of(label: str) -> str:
    return label.split("/")[1]


@pytest.mark.slow
def test_registry_conformance_across_lowering_regimes():
    """Every cataloged workload must produce record-equivalent results
    (same CSV labels, same identity fields) under parametric=False,
    "auto", and True — and auto must select exactly the regime the
    policy table above promises, reported via extra.param_path."""
    load_builtins()
    declarative = [w for w in suite.workloads() if w.runner is None]
    assert len(declarative) >= 10
    assert {w.name for w in declarative} == set(_EXPECTED_PATHS)
    for w in declarative:
        ws = _shrunk(w)
        # one shared cache: the specialized executables the False pass
        # builds are exactly what auto's fallback groups re-use
        cache = TranslationCache()
        spec = collect_records(ws, quick=True, cache=cache,
                               parametric=False)
        auto = collect_records(ws, quick=True, cache=cache,
                               parametric="auto")
        assert [lbl for lbl, _ in spec] == [lbl for lbl, _ in auto], w.name
        for (lbl, rs), (_, rp) in zip(spec, auto):
            for f in _IDENTITY_FIELDS:
                assert getattr(rs, f) == getattr(rp, f), (w.name, lbl, f)
            assert rs.extra["param_path"] == "specialized", (w.name, lbl)
        expect = _EXPECTED_PATHS[w.name]
        for lbl, rp in auto:
            want = expect.get(_variant_of(lbl), expect.get(None))
            assert rp.extra["param_path"] == want, (w.name, lbl)
            if want == "strided":
                rank = _EXPECTED_WINDOW_RANK.get(
                    (w.name, _variant_of(lbl)),
                    _EXPECTED_WINDOW_RANK.get((w.name, None)))
                assert rp.extra["param_window_rank"] == rank, (w.name, lbl)
        if w.name in _TRUE_RAISES:
            with pytest.raises(SymbolicLowerError):
                collect_records(ws, quick=True, cache=cache,
                                parametric=True)
            continue
        true = collect_records(ws, quick=True, cache=cache,
                               parametric=True)
        assert [lbl for lbl, _ in spec] == [lbl for lbl, _ in true], w.name
        for (lbl, rs), (_, rt) in zip(spec, true):
            for f in _IDENTITY_FIELDS:
                assert getattr(rs, f) == getattr(rt, f), (w.name, lbl, f)
            # True forces sharing wherever a variant leaves the policy
            # unset — including single-point groups
            if rt.extra["parametric"]:
                assert rt.extra["param_path"] in ("strided", "gather")


def test_workloads_share_single_executables_per_regime():
    load_builtins()
    # unified programs=4: the whole ladder shares one GATHER executable
    w = _shrunk(suite.workload("fig05_barriers"))
    cache = TranslationCache()
    recs = collect_records(w, quick=True, cache=cache, parametric="auto")
    n_points = len(w.ladder.points(True))
    assert n_points >= 4
    for label, rec in recs:
        assert rec.extra["parametric"], label
        assert rec.extra["param_path"] == "gather", label
    # one compile per (variant), not per (variant, point)
    assert cache.stats()["compile_misses"] == len(w.variant_list(True))
    # the independent template shares one STRIDED executable: 1 compile
    # miss for its whole ladder
    w6 = _shrunk(suite.workload("fig06_dataspaces"))
    indep = dataclasses.replace(
        w6, variants=tuple(v for v in w6.variant_list(True)
                           if v.label == "independent"))
    cache6 = TranslationCache()
    recs6 = collect_records(indep, quick=True, cache=cache6,
                            parametric="auto")
    assert [r.extra["param_path"] for _, r in recs6] \
        == ["strided"] * n_points
    assert cache6.stats()["compile_misses"] == 1


def test_stencil_ladders_run_nd_windows():
    """fig14/fig15 independent ladders — the paper's headline stencils —
    share one strided executable with multi-dimensional windows, and
    every record names the window rank."""
    load_builtins()
    for name, want_rank in (("fig14_jacobi2d", 2), ("fig15_jacobi3d", 3)):
        w = _shrunk(suite.workload(name))
        indep = dataclasses.replace(
            w, variants=tuple(v for v in w.variant_list(True)
                              if v.label == "independent"))
        cache = TranslationCache()
        recs = collect_records(indep, quick=True, cache=cache,
                               parametric="auto")
        assert [r.extra["param_path"] for _, r in recs] \
            == ["strided"] * len(recs), name
        assert [r.extra["param_window_rank"] for _, r in recs] \
            == [want_rank] * len(recs), name
        assert cache.stats()["compile_misses"] == 1, name


def test_param_path_override_pins_the_regime():
    """collect_records(param_path=...) pins the regime on auto configs —
    the conformance lever: forcing fig06's independent ladder onto
    gather must reproduce the strided records' identity fields."""
    load_builtins()
    w6 = _shrunk(suite.workload("fig06_dataspaces"))
    indep = dataclasses.replace(
        w6, variants=tuple(v for v in w6.variant_list(True)
                           if v.label == "independent"))
    cache = TranslationCache()
    strided = collect_records(indep, quick=True, cache=cache,
                              parametric="auto", param_path="strided")
    gathered = collect_records(indep, quick=True, cache=cache,
                               parametric="auto", param_path="gather")
    assert [r.extra["param_path"] for _, r in strided] \
        == ["strided"] * len(strided)
    assert [r.extra["param_path"] for _, r in gathered] \
        == ["gather"] * len(gathered)
    for (lbl, rs), (_, rg) in zip(strided, gathered):
        for f in _IDENTITY_FIELDS:
            assert getattr(rs, f) == getattr(rg, f), (lbl, f)


# ---------------------------------------------------------------------------
# backend axis: pallas records must mirror jax records (and the oracle)
# ---------------------------------------------------------------------------

_BACKEND_IDENTITY = tuple(f for f in _IDENTITY_FIELDS if f != "backend")

# Fast lane keeps the sweep cheap: the rank-1 stream workloads plus the
# 2-D stencil cover every pallas regime (strided parametric, specialized
# fallback for gather-only groups, single-point specialization). The 3-D
# stencil rides the slow lane.
_BACKEND_SWEEP_FAST = ("fig06_dataspaces", "fig07_streams",
                       "fig09_interleave", "fig14_jacobi2d")
_BACKEND_SWEEP_SLOW = ("fig15_jacobi3d",)


def _retargeted(w, backend):
    """The VariantSpec.backend override ``benchmarks.run --backend``
    applies, exercised through the library surface."""
    return dataclasses.replace(w, variants=tuple(
        dataclasses.replace(v, backend=backend)
        for v in w.variant_list(True)))


def _assert_backend_conformance(name):
    load_builtins()
    w = _shrunk(suite.workload(name))
    cache = TranslationCache()
    jax_recs = collect_records(w, quick=True, cache=cache, parametric="auto")
    pal_recs = collect_records(_retargeted(w, "pallas"), quick=True,
                               cache=cache, parametric="auto")
    # oracle agreement is enforced inside collect_records (workload
    # validation runs per group on the lowered step); here we pin the
    # record-level contract between the backends
    assert [lbl for lbl, _ in jax_recs] == [lbl for lbl, _ in pal_recs], name
    for (lbl, rj), (_, rp) in zip(jax_recs, pal_recs):
        assert rj.backend == "jax" and rp.backend == "pallas", (name, lbl)
        for f in _BACKEND_IDENTITY:
            assert getattr(rj, f) == getattr(rp, f), (name, lbl, f)
        assert rp.extra["pallas_mode"] in ("compiled", "interpret"), lbl
        assert rp.extra["donated"] is True, (name, lbl)
        # regime policy on the pallas backend: strided groups share the
        # grid-mapped executable at the same window rank; gather-only
        # groups specialize (pallas has no parametric gather emitter)
        pj, pp = rj.extra["param_path"], rp.extra["param_path"]
        if pj == "strided":
            assert pp == "strided", (name, lbl)
            assert rp.extra["param_window_rank"] \
                == rj.extra["param_window_rank"], (name, lbl)
        else:
            assert pp == "specialized", (name, lbl, pj, pp)


@pytest.mark.parametrize("name", _BACKEND_SWEEP_FAST)
def test_backend_conformance_fast(name):
    _assert_backend_conformance(name)


@pytest.mark.slow
@pytest.mark.parametrize("name", _BACKEND_SWEEP_SLOW)
def test_backend_conformance_slow(name):
    _assert_backend_conformance(name)


# ---------------------------------------------------------------------------
# registry round-trip + shims
# ---------------------------------------------------------------------------


def test_registry_round_trip_with_harness_executor():
    from benchmarks.run import registered_names

    names = registered_names()
    assert names == list(suite.names())
    for expected in ("fig05_barriers", "fig06_dataspaces", "fig07_streams",
                     "fig09_interleave", "fig10_counters", "fig12_jacobi1d",
                     "fig14_jacobi2d", "fig15_jacobi3d", "spatter_uniform",
                     "mess_load_sweep", "pointer_chase", "spatter_nonuniform",
                     "mess_calibrated", "fig16_tile_sweep", "roofline"):
        assert expected in names
    # lookups resolve and are well-formed (declarative entries carry a
    # sweep plan — a multi-axis one or a ladder's one-axis equivalent)
    for name in names:
        w = suite.workload(name)
        assert w.name == name
        if w.runner is None:
            assert w.sweep_plan().points(True)
        else:
            assert w.ladder is None and w.plan is None


def test_common_shim_reexports_suite_ladders():
    from benchmarks import common
    from repro.suite import FULL_SETS, QUICK_GRID, QUICK_SETS, WORKING_SETS

    assert common.QUICK_SETS == QUICK_SETS
    assert common.sets(True) == QUICK_SETS and common.sets(False) == FULL_SETS
    assert common.grids(True) == QUICK_GRID
    assert tuple(QUICK_SETS) == WORKING_SETS.quick


# ---------------------------------------------------------------------------
# Spatter patterns + disk-cache stats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("factory", [gather, scatter, gather_scatter])
@pytest.mark.parametrize("template", ["unified", "independent"])
def test_spatter_patterns_validate(factory, template):
    d = Driver(lambda env: factory(stride=4),
               DriverConfig(template=template, programs=4, ntimes=2,
                            reps=1), cache=TranslationCache())
    d.validate()


def test_spatter_accounting():
    pat = gather(stride=8)
    assert pat.bytes_per_point() == 8  # one read + one write, f32
    shapes = {s.name: s.concrete_shape({"n": 64}) for s in pat.spaces}
    assert shapes == {"D": (64,), "S": (512,)}


def test_stats_report_disk_cache_counters():
    s = TranslationCache().stats()
    assert set(s["disk"]) == {"enabled", "hits", "misses"}
    assert s["disk"]["hits"] >= 0 and s["disk"]["misses"] >= 0
