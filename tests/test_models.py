"""Architecture smoke tests: every assigned arch instantiates a REDUCED
config of its family, runs one forward + one train step on CPU, asserts
output shapes and finiteness; decode-vs-full consistency per family."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import Shape, get_config, list_archs
from repro.data.pipeline import make_batch_fn
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import lm
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B, S, seed=0):
    shape = Shape("t", S, B, "train")
    return {k: jnp.asarray(v) for k, v in make_batch_fn(cfg, shape, seed)(0).items()}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    p = lm.init_params(KEY, cfg)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)

    hidden, _, aux = lm.apply(
        p, cfg, tokens=batch.get("tokens"), embeds=batch.get("frame_embeds"),
        prefix_embeds=batch.get("vision_embeds"), cond=batch.get("cond"),
        remat=False)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))

    opt = adamw(1e-3)
    step = jax.jit(make_train_step(cfg, None, opt))
    state = {"params": p, "opt": opt.init(p)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p, state["params"]))
    assert max(delta) > 0


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-27b",
                                  "deepseek-v2-lite-16b", "xlstm-1.3b",
                                  "zamba2-1.2b", "starcoder2-15b"])
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe:  # remove capacity drops for the equivalence check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    p = lm.init_params(KEY, cfg, dtype=jnp.float32)
    B, S, extra = 2, 16, 4
    toks = jax.random.randint(KEY, (B, S + extra), 0, cfg.vocab_size)
    h_full, _, _ = lm.apply(p, cfg, tokens=toks, remat=False)
    cache = lm.init_cache(cfg, B, S + extra, dtype=jnp.float32)
    h, cache, _ = lm.apply(p, cfg, tokens=toks[:, :S], cache=cache,
                           remat=False)
    hs = [h]
    for t in range(extra):
        h, cache, _ = lm.apply(p, cfg, tokens=toks[:, S + t:S + t + 1],
                               cache=cache, remat=False)
        hs.append(h)
    h_inc = jnp.concatenate(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h_inc),
                               rtol=2e-3, atol=2e-3)


def test_microbatched_train_step_matches_single():
    cfg = get_config("internlm2-1.8b").reduced()
    p = lm.init_params(KEY, cfg, dtype=jnp.float32)
    batch = _batch_for(cfg, 4, 32)
    opt = adamw(1e-3, grad_clip=0.0)
    s1 = jax.jit(make_train_step(cfg, None, opt))(
        {"params": p, "opt": opt.init(p)}, batch)
    s2 = jax.jit(make_train_step(cfg, None, opt, num_microbatches=2))(
        {"params": p, "opt": opt.init(p)}, batch)
    np.testing.assert_allclose(float(s1[1]["loss"]), float(s2[1]["loss"]),
                               rtol=1e-4)
    a = jax.tree.leaves(s1[0]["params"])[0]
    b = jax.tree.leaves(s2[0]["params"])[0]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-3,
                               atol=1e-5)


def test_serve_step_emits_tokens():
    cfg = get_config("internlm2-1.8b").reduced()
    p = lm.init_params(KEY, cfg)
    cache = lm.init_cache(cfg, 2, 8)
    cache = dataclasses.replace if False else cache
    step = jax.jit(make_serve_step(cfg, None))
    cache["len"] = jnp.asarray(4, jnp.int32)  # pretend 4 tokens prefilled
    tok, cache2 = step(p, cache, {"tokens": jnp.zeros((2, 1), jnp.int32)})
    assert tok.shape == (2, 1)
    assert int(cache2["len"]) == 5


def test_gemma3_local_global_pattern():
    from repro.models.lm import _gemma_layer_meta
    cfg = get_config("gemma3-27b")
    wins, thetas = _gemma_layer_meta(cfg)
    wins = np.asarray(wins)
    assert (wins == 0).sum() == cfg.n_layers // cfg.global_every
    assert wins[cfg.global_every - 1] == 0 and wins[0] == cfg.window


def test_moe_capacity_drops_are_bounded():
    """With cf>=1 and balanced-ish tokens, most tokens keep their experts."""
    from repro.models.moe import moe_apply, moe_init
    from repro.config import MoEConfig
    moe = MoEConfig(n_routed=8, n_shared=0, top_k=2, d_ff_expert=16,
                    capacity_factor=2.0)
    p = moe_init(KEY, 32, moe, dtype=jnp.float32)
    x = jax.random.normal(KEY, (4, 16, 32), jnp.float32)
    y, aux = moe_apply(p, x, moe, par=None)
    assert y.shape == x.shape
    assert float(aux) > 0
    # output should be nonzero for most tokens (not everything dropped)
    nz = float(jnp.mean((jnp.abs(y) > 1e-8).any(-1)))
    assert nz > 0.9
