"""Pallas-backend parity tests (the PR-7 contracts).

Covers: the grid-mapped parametric pallas emitter's bit-exactness
against the numpy window mirror (``windowed_oracle``) on the whole
capacity arrays, the 1-compile-per-ladder cache property through the
Driver with pallas records stamped (backend / pallas_mode / strided /
donated), donation threading through the shared pallas executable
(seed tuples are consumed, outputs re-thread), the sweep engine's
``pallas->jax`` backend-demotion rung, and the structured
``LowerFailure`` classification of every pallas lowering refusal
(custom kernels, guarded schedules, strided accesses).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Driver,
    DriverConfig,
    LowerFailure,
    SymbolicLowerError,
    TranslationCache,
    gather,
    identity,
    jacobi1d,
    jacobi2d,
    pointer_chase,
    triad,
    windowed_oracle,
)
from repro.core.codegen import (
    lower_pallas,
    lower_pallas_parametric,
    pallas_platform_mode,
)
from repro.suite import SweepPlan, VariantSpec, env_axis, run_plan


# ---------------------------------------------------------------------------
# platform mode probe
# ---------------------------------------------------------------------------


def test_platform_mode_is_probed_and_memoized():
    mode = pallas_platform_mode()
    assert mode in ("compiled", "interpret")
    assert pallas_platform_mode() == mode  # memoized, not re-probed


# ---------------------------------------------------------------------------
# grid-window bit-exactness vs the numpy window mirror
# ---------------------------------------------------------------------------


def _run_pallas_param(pat, sch, env, cap_env, chunk, *, assume_full=False,
                      ntimes=2):
    step = lower_pallas_parametric(pat, sch, cap_env, chunk=chunk,
                                   assume_full=assume_full)
    assert step.param_path == "strided"
    assert step.pallas_mode == pallas_platform_mode()
    got = {k: jnp.asarray(v) for k, v in pat.allocate(cap_env).items()}
    pvals = [env[p] for p in ("n",)]
    for _ in range(ntimes):
        got = step(got, pvals)
    return {k: np.asarray(v) for k, v in got.items()}


@pytest.mark.parametrize("factory,sch,envs,cap,chunk,assume_full", [
    # masked rank-1 windows, partial tails included (100 is not a
    # multiple of the 64-chunk)
    (triad, identity(), [{"n": 100}, {"n": 256}], {"n": 256}, 64, False),
    # assume-full windows: every rung tiles the chunk exactly
    (triad, identity(), [{"n": 4096}, {"n": 8192}], {"n": 8192}, 4096, True),
    # stencil halos through the window blend
    (jacobi1d, identity(), [{"n": 100}, {"n": 258}], {"n": 258}, 64, False),
    # rank-2 N-D window boxes
    (jacobi2d, identity(), [{"n": 66}, {"n": 130}], {"n": 130},
     ((0, 32), (1, 32)), False),
    # descending windows
    (triad, identity().reverse("i"), [{"n": 100}, {"n": 256}], {"n": 256},
     64, False),
    # strided outer band (interleave) with a unit-stride lane band
    (triad, identity().interleave("i", 2), [{"n": 128}, {"n": 256}],
     {"n": 256}, 64, False),
])
def test_grid_windows_match_windowed_oracle(factory, sch, envs, cap, chunk,
                                            assume_full):
    """The pallas grid executable must agree with the numpy window
    mirror bit-for-bit on the WHOLE capacity arrays — tail lanes,
    masked-off grid steps, and untouched slack included."""
    pat = factory()
    for env in envs:
        got = _run_pallas_param(pat, sch, env, cap, chunk,
                                assume_full=assume_full)
        mirror = windowed_oracle(pat, sch, env, cap, pat.allocate(cap),
                                 ntimes=2, chunk=chunk,
                                 assume_full=assume_full)
        for k in mirror:
            np.testing.assert_array_equal(
                got[k], mirror[k],
                err_msg=f"space {k} diverged at n={env['n']} ({sch.name})",
            )


def test_parametric_pallas_refuses_gather_only_nests():
    """No gather fallback: a nest the strided planner rejects raises
    SymbolicLowerError instead of silently emitting a masked gather."""
    sch = identity().tile_by_count("i", 4, outer="prog", inner="i")
    with pytest.raises(SymbolicLowerError, match="no gather"):
        lower_pallas_parametric(triad(), sch, {"n": 1024})
    with pytest.raises(SymbolicLowerError, match="custom kernel"):
        lower_pallas_parametric(pointer_chase(), identity(), {"n": 1024})


# ---------------------------------------------------------------------------
# driver integration: one compile per ladder, stamped + donated records
# ---------------------------------------------------------------------------


def _pallas_cfg(**kw):
    base = dict(template="independent", programs=4, backend="pallas",
                ntimes=2, reps=1)
    base.update(kw)
    return DriverConfig(**base)


def test_pallas_ladder_compiles_once_and_stamps_records():
    cache = TranslationCache()
    d = Driver(lambda env: triad(),
               _pallas_cfg(parametric=True, param_path="strided"),
               cache=cache)
    recs = d.run([256, 512, 1024])
    assert cache.stats()["compile_misses"] == 1
    mode = pallas_platform_mode()
    for r in recs:
        assert r.backend == "pallas"
        assert r.extra["pallas_mode"] == mode
        assert r.extra["param_path"] == "strided"
        assert r.extra["parametric"] and r.extra["donated"] is True
    assert [r.n for r in recs] == [256, 512, 1024]
    d.validate_parametric([256, 512, 1024])


def test_pallas_parametric_matches_jax_records():
    """Same ladder, both backends: identity fields and values agree
    (the oracle agreement is validate_parametric above; here the
    record-level contract)."""
    ladder = [256, 512]
    recs = {}
    for backend in ("jax", "pallas"):
        d = Driver(lambda env: triad(),
                   DriverConfig(template="independent", programs=4,
                                backend=backend, parametric=True,
                                param_path="strided", ntimes=2, reps=1),
                   cache=TranslationCache())
        recs[backend] = d.run(ladder)
    for rj, rp in zip(recs["jax"], recs["pallas"]):
        for f in ("pattern", "template", "schedule", "n",
                  "working_set_bytes", "programs", "ntimes", "level"):
            assert getattr(rj, f) == getattr(rp, f), f
        assert rj.extra["param_path"] == rp.extra["param_path"] == "strided"
        assert rj.extra["param_window_rank"] \
            == rp.extra["param_window_rank"] == 1


def test_pallas_parametric_executable_donates_and_threads():
    """The shared pallas executable consumes its seed tuple (donated
    capacity buffers) and threads outputs into subsequent calls —
    the same contract as the jax parametric path."""
    d = Driver(lambda env: triad(),
               _pallas_cfg(parametric=True, param_path="strided"),
               cache=TranslationCache())
    p = d.prepare([256, 512])[0]
    assert p.parametric and p.lowered.pallas_mode == pallas_platform_mode()
    arrays = p.lowered.pattern.allocate(p.lowered.env)
    tup = tuple(jnp.asarray(arrays[k]) for k in p.compiled.names)
    fn = p.executable()
    out1 = fn(tup)
    out2 = fn(tup)          # timing loop re-passes the seed: threads out1
    assert all(o.shape == t.shape for o, t in zip(out2, out1))
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(tup[0])  # the seed's buffers were donated away


# ---------------------------------------------------------------------------
# structured refusals
# ---------------------------------------------------------------------------


def test_lower_refusals_carry_structured_context():
    # custom (jax-only) kernel
    with pytest.raises(LowerFailure) as ei:
        lower_pallas(pointer_chase(), identity(), {"n": 64})
    assert ei.value.context["backend"] == "pallas"
    assert ei.value.context["reason"] == "custom_kernel"
    # guarded schedule (7 does not divide 100)
    with pytest.raises(LowerFailure) as ei:
        lower_pallas(triad(), identity().tile("i", 7), {"n": 100})
    assert ei.value.context["reason"] == "guarded_schedule"
    # strided access: S[4*i] cannot be a contiguous pallas window
    with pytest.raises(LowerFailure) as ei:
        lower_pallas(gather(stride=4), identity(), {"n": 64})
    assert ei.value.context["reason"] == "strided_access"


# ---------------------------------------------------------------------------
# the pallas->jax demotion rung
# ---------------------------------------------------------------------------


def test_sweep_demotes_pallas_to_jax_structurally():
    """A pallas-ineligible pattern inside a pallas-backend sweep demotes
    to the jax backend instead of failing the point: the rung is walked
    first, rows survive on jax, and the demotion is recorded."""
    cfg = DriverConfig(template="unified", programs=2, ntimes=2, reps=1,
                       backend="pallas", validate_n=None)
    plan = SweepPlan.product(env_axis((256, 512)))
    report = run_plan(lambda env: gather(stride=4), [VariantSpec("g", cfg)],
                      plan, cache=TranslationCache())
    assert report.ok and not report.failures
    assert [r.point.label for r in report.rows] == ["n256", "n512"]
    assert [d.step for d in report.demotions] == ["pallas->jax"]
    assert report.demotions[0].stage == "lower"
    assert report.demotions[0].error == "LowerFailure"
    for r in report.rows:
        assert r.record.backend == "jax"          # the demoted backend
        assert "pallas_mode" not in r.record.extra


def test_variant_backend_override_resolves_config():
    v = VariantSpec("t", DriverConfig(template="independent", programs=4),
                    backend="pallas")
    assert v.resolved_config().backend == "pallas"
    assert v.config.backend == "jax"              # original untouched
    plain = VariantSpec("t", DriverConfig(template="independent", programs=4))
    assert plain.resolved_config() is plain.config
