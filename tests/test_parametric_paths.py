"""Lowering-regime equivalence layer (the PR-4 acceptance contract).

Property-based (hypothesis, or the deterministic stub when it is
absent): over randomized pattern/stride/offset/extent/schedule/programs
combinations, every regime must agree —

    specialized strided  ==  parametric strided  ==  parametric gather
                         ==  serial oracle       ==  numpy window mirror

with the mirror compared bit-for-bit over the *whole* capacity arrays
(tail lanes, pad columns and all), not just the measured region. The
non-property tests pin the precondition edge cases: forced regimes,
indivisible tiles, zero-stride (constant-index) reads, negative strides,
mixed-sign and diagonal accesses, fixed-size spaces that fail the
window-bounds check, and single-point-ladder fallback — each reporting
its regime through ``extra.param_path``.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Access,
    Affine,
    DataSpace,
    Driver,
    DriverConfig,
    PatternSpec,
    Statement,
    SymbolicLowerError,
    TranslationCache,
    domain,
    gather,
    gather_scatter,
    identity,
    jacobi1d,
    jacobi2d,
    jacobi3d,
    nstream,
    param_strided_plan,
    scatter,
    triad,
    windowed_oracle,
)
from repro.core.codegen import (
    lower_jax,
    lower_jax_parametric,
    param_strided_in_bounds,
    param_strided_window,
    param_window_bands,
    plan_nest,
    serial_oracle,
)
from repro.core.drivers import independent_view
from repro.core.staging import stage_lower_parametric


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _offset_stream(off: int) -> PatternSpec:
    """A[i] = 2 * B[i + off] — exercises constant index offsets."""
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("B", (i + off,)),),
        write=Access("A", (i,)),
        combine=lambda vals, env: vals[0] * np.float32(2.0),
    )
    return PatternSpec(
        f"ostream{off}",
        (
            DataSpace("A", ("n",), "float32", 0.0),
            DataSpace("B", (Affine.of("n") + off,), "float32",
                      lambda i: (i % 13).astype(np.float32)),
        ),
        stmt,
        domain(("i", 0, "n")),
    )


def _run_param(pat, sch, env, cap_env, chunk, path):
    """Two sweeps of the parametric step at ``env`` on capacity arrays."""
    step = lower_jax_parametric(
        pat, sch, cap_env, chunk=chunk, param_path=path
    )
    assert step.param_path == path
    got = {k: jnp.asarray(v) for k, v in pat.allocate(cap_env).items()}
    pv = (np.int32(env["n"]),)
    for _ in range(2):
        got = step(got, pv)
    return {k: np.asarray(v) for k, v in got.items()}


def _assert_region(pat, env, got, want, label):
    for k in want:
        region = tuple(slice(0, d) for d in pat.space(k).concrete_shape(env))
        np.testing.assert_allclose(
            got[k][region], want[k], rtol=1e-5, atol=1e-5,
            err_msg=f"{label}: space {k} diverged at n={env['n']}",
        )


def _check_all_regimes(pat, sch, env, cap_env, chunk):
    """The four-way (plus mirror) agreement check for one case."""
    pnest = sch.lower_symbolic(pat.domain, ("n",))
    splan = param_strided_plan(pat, pnest)
    assert splan is not None, (pat.name, sch.name)
    assert param_strided_in_bounds(pat, pnest, splan, env, cap_env, chunk)

    nest = sch.lower(pat.domain, env)
    arrays = pat.allocate(env)
    want = serial_oracle(pat, nest, arrays, env, ntimes=2)

    # specialized path (strided-slice fast form whenever the plan admits it)
    step = lower_jax(pat, sch, env)
    got = {k: jnp.asarray(v) for k, v in arrays.items()}
    for _ in range(2):
        got = step(got)
    _assert_region(pat, env, {k: np.asarray(v) for k, v in got.items()},
                   want, "specialized")

    strided = _run_param(pat, sch, env, cap_env, chunk, "strided")
    _assert_region(pat, env, strided, want, "parametric-strided")
    gathered = _run_param(pat, sch, env, cap_env, chunk, "gather")
    _assert_region(pat, env, gathered, want, "parametric-gather")

    # the numpy mirror must agree with the jax strided step on the WHOLE
    # capacity arrays — tail lanes and untouched slack included
    mirror = windowed_oracle(pat, sch, env, cap_env, pat.allocate(cap_env),
                             ntimes=2, chunk=chunk)
    for k in mirror:
        np.testing.assert_allclose(
            strided[k], mirror[k], rtol=1e-5, atol=1e-5,
            err_msg=f"mirror: space {k} diverged at n={env['n']}",
        )


# ---------------------------------------------------------------------------
# the property: all regimes agree on random cases
# ---------------------------------------------------------------------------

# base unit divisible by every interleave/unroll factor and program count
# drawn below, so divisibility constraints hold by construction
_M = 12

_params = st.composite


@_params
def _cases(draw):
    kind = draw(st.sampled_from(
        ["triad", "nstream", "gather", "scatter", "gather_scatter",
         "jacobi1d", "ostream"]))
    if kind == "triad":
        pat, halo = triad(), 0
    elif kind == "nstream":
        pat, halo = nstream(draw(st.integers(1, 4))), 0
    elif kind == "gather":
        pat, halo = gather(stride=draw(st.integers(1, 5))), 0
    elif kind == "scatter":
        pat, halo = scatter(stride=draw(st.integers(1, 5))), 0
    elif kind == "gather_scatter":
        pat, halo = gather_scatter(stride=draw(st.integers(1, 5))), 0
    elif kind == "ostream":
        pat, halo = _offset_stream(draw(st.integers(0, 4))), 0
    else:
        pat, halo = jacobi1d(), 2

    programs = draw(st.sampled_from([1, 2, 4]))
    if programs > 1 and kind != "jacobi1d":
        # the independent template rewrite (jacobi's halo'd interior
        # would need transformed ladder points; keep it single-program)
        pat = independent_view(pat, programs)

    sched = draw(st.sampled_from(["identity", "reverse", "interleave",
                                  "unroll"]))
    sch = identity()
    if sched == "reverse":
        sch = sch.reverse("i")
    elif sched == "interleave":
        sch = sch.interleave("i", draw(st.sampled_from([2, 3])))
    elif sched == "unroll":
        sch = sch.unroll("i", draw(st.sampled_from([2, 3])))

    n = _M * draw(st.integers(1, 4)) + halo
    cap = n + _M * draw(st.integers(0, 3))
    chunk = draw(st.sampled_from([4, 8, 16, 64]))
    return pat, sch, {"n": n}, {"n": cap}, chunk


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(_cases())
def test_all_regimes_agree_on_random_cases(case):
    pat, sch, env, cap_env, chunk = case
    _check_all_regimes(pat, sch, env, cap_env, chunk)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(st.integers(1, 11), st.sampled_from([4, 16]))
def test_partial_windows_agree(n, chunk):
    """Rungs smaller than one window take the masked branch for every
    pattern shape — including the padded independent template whose pad
    columns must keep their init values."""
    _check_all_regimes(triad(), identity(), {"n": n}, {"n": 48}, chunk)
    _check_all_regimes(triad(), identity().reverse("i"), {"n": n},
                       {"n": 48}, chunk)
    pad = independent_view(triad(), 2, pad=5)
    _check_all_regimes(pad, identity(), {"n": n}, {"n": 48}, chunk)


# ---------------------------------------------------------------------------
# precondition edge cases
# ---------------------------------------------------------------------------


def test_forced_strided_raises_on_ineligible_nest():
    pat = triad()
    sch = identity().tile_by_count("i", 4, outer="prog", inner="i")
    with pytest.raises(SymbolicLowerError, match="strided-eligible"):
        lower_jax_parametric(pat, sch, {"n": 64}, param_path="strided")
    # auto on the same nest silently takes the gather regime
    step = lower_jax_parametric(pat, sch, {"n": 64}, param_path="auto")
    assert step.param_path == "gather"
    with pytest.raises(ValueError, match="param_path"):
        lower_jax_parametric(pat, identity(), {"n": 64}, param_path="nope")


def test_param_path_flows_through_staging():
    lw = stage_lower_parametric(triad(), identity(), {"n": 256})
    assert lw.param_path == "strided"
    c = lw.compile(ntimes=2)
    assert c.param_path == "strided"
    lw2 = stage_lower_parametric(triad(), identity(), {"n": 256},
                                 param_path="gather")
    assert lw2.param_path == "gather"


def test_indivisible_tile_falls_back_to_specialized():
    """A ladder violating a symbolic divisibility constraint cannot share
    an executable at all — records report param_path='specialized'."""
    d = Driver(lambda env: triad(),
               DriverConfig(template="independent", programs=2, ntimes=2,
                            reps=1, schedule=identity().tile("i", 48),
                            parametric="auto"), cache=TranslationCache())
    recs = d.run([256, 128])
    assert [r.extra["param_path"] for r in recs] == ["specialized"] * 2


def test_single_point_ladder_reports_specialized():
    d = Driver(lambda env: triad(),
               DriverConfig(template="independent", programs=2, ntimes=2,
                            reps=1, parametric="auto"),
               cache=TranslationCache())
    (rec,) = d.run([512])
    assert rec.extra["param_path"] == "specialized"
    assert not rec.extra["parametric"]


def test_zero_stride_read_broadcasts():
    """A constant-index (stride-0) read is a broadcast lane, not a window
    — still strided-eligible."""
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("B", (i,)), Access("S", (0,))),
        write=Access("A", (i,)),
        combine=lambda vals, env: vals[0] + vals[1],
    )
    pat = PatternSpec(
        "bias_stream",
        (
            DataSpace("A", ("n",), "float32", 0.0),
            DataSpace("B", ("n",), "float32",
                      lambda i: (i % 7).astype(np.float32)),
            DataSpace("S", (1,), "float32", 2.5),
        ),
        stmt,
        domain(("i", 0, "n")),
    )
    _check_all_regimes(pat, identity(), {"n": 24}, {"n": 36}, 8)
    _check_all_regimes(pat, identity(), {"n": 5}, {"n": 36}, 8)


def test_negative_stride_windows_via_reverse():
    """reverse() negates every access uniformly, so a reversed Spatter
    gather runs descending |stride|=2 windows with symbolic offsets —
    strided-eligible, unlike a hand-mixed-sign statement (below)."""
    _check_all_regimes(gather(stride=2), identity().reverse("i"),
                       {"n": 24}, {"n": 36}, 8)
    _check_all_regimes(gather(stride=2), identity().reverse("i"),
                       {"n": 6}, {"n": 36}, 16)
    _check_all_regimes(scatter(stride=3), identity().reverse("i"),
                       {"n": 24}, {"n": 36}, 8)


def test_mixed_sign_accesses_fall_back_to_gather():
    """S[i] and T[n-1-i] in one statement disagree on the band sign —
    unsliceable, so auto takes the gather regime (and still validates)."""
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("S", (i,)), Access("T", (Affine.of("n") - 1 - i,))),
        write=Access("D", (i,)),
        combine=lambda vals, env: vals[0] + vals[1],
    )
    pat = PatternSpec(
        "fold",
        (
            DataSpace("D", ("n",), "float32", 0.0),
            DataSpace("S", ("n",), "float32",
                      lambda i: (i % 5).astype(np.float32)),
            DataSpace("T", ("n",), "float32",
                      lambda i: (i % 3).astype(np.float32)),
        ),
        stmt,
        domain(("i", 0, "n")),
    )
    pnest = identity().lower_symbolic(pat.domain, ("n",))
    assert param_strided_plan(pat, pnest) is None
    env, cap = {"n": 24}, {"n": 32}
    want = serial_oracle(pat, identity().lower(pat.domain, env),
                         pat.allocate(env), env, ntimes=2)
    got = _run_param(pat, identity(), env, cap, 8, "gather")
    _assert_region(pat, env, got, want, "mixed-sign gather")


def test_self_aliasing_statement_falls_back_to_gather():
    """A[i] = A[i] + B[i] reads its own write space: the min-start window
    overlap would re-read updated lanes, so the strided regime must
    refuse it (the gather regime visits every lane exactly once and
    still matches the oracle)."""
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("A", (i,)), Access("B", (i,))),
        write=Access("A", (i,)),
        combine=lambda vals, env: vals[0] + vals[1],
    )
    pat = PatternSpec(
        "accum",
        (
            DataSpace("A", ("n",), "float32", 1.0),
            DataSpace("B", ("n",), "float32",
                      lambda i: (i % 3).astype(np.float32)),
        ),
        stmt,
        domain(("i", 0, "n")),
    )
    pnest = identity().lower_symbolic(pat.domain, ("n",))
    assert param_strided_plan(pat, pnest) is None
    env, cap = {"n": 10}, {"n": 16}
    want = serial_oracle(pat, identity().lower(pat.domain, env),
                         pat.allocate(env), env, ntimes=2)
    got = _run_param(pat, identity(), env, cap, 4, "gather")
    _assert_region(pat, env, got, want, "self-aliasing gather")
    d = Driver(lambda env: pat,
               DriverConfig(template="unified", programs=1, ntimes=2,
                            reps=1, parametric="auto"),
               cache=TranslationCache())
    recs = d.run([256, 512])
    assert {r.extra["param_path"] for r in recs} == {"gather"}


def test_unknown_param_path_raises_at_construction():
    with pytest.raises(ValueError, match="param_path"):
        Driver(lambda env: triad(), DriverConfig(param_path="Strided"))


def test_diagonal_access_falls_back_to_gather():
    """M[i, i] references one band in two dims — never window-sliceable."""
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("M", (i, i)),),
        write=Access("D", (i,)),
        combine=lambda vals, env: vals[0],
    )
    pat = PatternSpec(
        "diag",
        (
            DataSpace("D", ("n",), "float32", 0.0),
            DataSpace("M", ("n", "n"), "float32",
                      lambda i, j: (i * 2 + j).astype(np.float32)),
        ),
        stmt,
        domain(("i", 0, "n")),
    )
    pnest = identity().lower_symbolic(pat.domain, ("n",))
    assert param_strided_plan(pat, pnest) is None
    step = lower_jax_parametric(pat, identity(), {"n": 16})
    assert step.param_path == "gather"


def test_bounds_check_demotes_fixed_size_spaces():
    """A tail-anchored read of a FIXED-size buffer (A[i] = B[K - n + i],
    reading B's last n elements): rungs smaller than one window would
    slice past B's end — those envs fail the exact bounds check, so the
    driver demotes that ladder to gather, while a ladder of window-safe
    rungs keeps the strided regime."""
    K = 40
    i = Affine.of("i")
    stmt = Statement(
        reads=(Access("B", (i + K - Affine.of("n"),)),),
        write=Access("A", (i,)),
        combine=lambda vals, env: vals[0],
    )
    pat = PatternSpec(
        "tailstream",
        (
            DataSpace("A", ("n",), "float32", 0.0),
            DataSpace("B", (K,), "float32",
                      lambda i: (i % 11).astype(np.float32)),
        ),
        stmt,
        domain(("i", 0, "n")),
    )
    pnest = identity().lower_symbolic(pat.domain, ("n",))
    splan = param_strided_plan(pat, pnest)
    assert splan is not None
    cap = {"n": 32}
    chunk = 16  # C = 16: a rung of 8 reads window [K-8, K-8+16) past B
    assert param_strided_in_bounds(pat, pnest, splan, {"n": 16}, cap, chunk)
    assert param_strided_in_bounds(pat, pnest, splan, {"n": 32}, cap, chunk)
    assert not param_strided_in_bounds(pat, pnest, splan, {"n": 8}, cap,
                                       chunk)
    # through the driver (default chunk: C = capacity extent = 32, so
    # every partial rung overruns B): auto demotes the ladder to gather
    # — measured, validated, just not window-sliced
    cache = TranslationCache()
    d = Driver(lambda env: pat,
               DriverConfig(template="unified", programs=1, ntimes=2,
                            reps=1, parametric="auto"), cache=cache)
    recs = d.run([8, 16, 32])
    assert {r.extra["param_path"] for r in recs} == {"gather"}
    # and the gather fallback still matches the oracle at the risky rung
    d.validate_parametric([8, 16, 32])


def test_assume_full_mode_matches_masked_mode():
    """The mask-free hot emitter (every chunk provably full) must agree
    with the masked emitter and its mirror wherever its caller contract
    holds (window extent >= chunk at every env)."""
    for pat, sch in [
        (triad(), identity()),
        (triad(), identity().reverse("i")),
        (independent_view(triad(), 2, pad=5), identity()),
        (gather(stride=3), identity()),
    ]:
        env, cap_env, chunk = {"n": 24}, {"n": 48}, 8
        want = _run_param(pat, sch, env, cap_env, chunk, "strided")
        step = lower_jax_parametric(pat, sch, cap_env, chunk=chunk,
                                    param_path="strided", assume_full=True)
        got = {k: jnp.asarray(v) for k, v in pat.allocate(cap_env).items()}
        pv = (np.int32(env["n"]),)
        for _ in range(2):
            got = step(got, pv)
        mirror = windowed_oracle(pat, sch, env, cap_env,
                                 pat.allocate(cap_env), ntimes=2,
                                 chunk=chunk, assume_full=True)
        for k in want:
            np.testing.assert_allclose(np.asarray(got[k]), want[k],
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"assume_full {pat.name}/{k}")
            np.testing.assert_allclose(np.asarray(got[k]), mirror[k],
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"full-mirror {pat.name}/{k}")


def test_driver_clamps_chunk_for_full_ladders():
    """A ladder whose smallest rung is >= the clamp floor resolves to
    the mask-free emitter with the chunk clamped to that rung."""
    d = Driver(lambda env: triad(),
               DriverConfig(template="independent", programs=4, ntimes=2,
                            reps=1, parametric="auto"),
               cache=TranslationCache())
    envs = d._point_envs([1 << 10, 1 << 12], None)
    path, chunk, full = d._resolve_param_path(envs, {"n": 1 << 12})
    assert (path, chunk, full) == ("strided", 1 << 10, True)
    # a sub-floor rung takes the masked emitter with the second-tier
    # clamp: the lane chunk is bounded by the floor, not the capacity
    envs = d._point_envs([256, 1 << 12], None)
    path, chunk, full = d._resolve_param_path(envs, {"n": 1 << 12})
    assert path == "strided" and full is False and chunk == 1024


# ---------------------------------------------------------------------------
# N-D windows (multi-dimensional stencil nests)
# ---------------------------------------------------------------------------


def _check_nd_windows(pat, sch, envs, cap_env, want_rank):
    """Resolve the ladder's N-D window spec, then prove the jax step
    bit-identical to the numpy mirror over the WHOLE capacity arrays and
    to the specialized path / serial oracle over the [0, n) region."""
    pnest = sch.lower_symbolic(pat.domain, ("n",))
    splan = param_strided_plan(pat, pnest)
    assert splan is not None, (pat.name, sch.name)
    assert len(param_window_bands(pnest, splan)) == want_rank
    spec, full = param_strided_window(pnest, splan, envs, cap_env)
    assert isinstance(spec, tuple) and len(spec) == want_rank
    step = lower_jax_parametric(pat, sch, cap_env, chunk=spec,
                                param_path="strided", assume_full=full)
    assert step.param_path == "strided"
    assert step.param_window_rank == want_rank
    for env in envs:
        assert param_strided_in_bounds(pat, pnest, splan, env, cap_env,
                                       spec)
        got = {k: jnp.asarray(v) for k, v in pat.allocate(cap_env).items()}
        pv = (np.int32(env["n"]),)
        for _ in range(2):
            got = step(got, pv)
        got = {k: np.asarray(v) for k, v in got.items()}
        mirror = windowed_oracle(pat, sch, env, cap_env,
                                 pat.allocate(cap_env), ntimes=2,
                                 chunk=spec, assume_full=full)
        for k in mirror:
            np.testing.assert_array_equal(
                got[k], mirror[k],
                err_msg=f"N-D mirror: {k} not bit-identical at n={env['n']}",
            )
        # specialized path over the measured region: bit-identical too
        spec_step = lower_jax(pat, sch, env)
        sgot = {k: jnp.asarray(v) for k, v in pat.allocate(env).items()}
        for _ in range(2):
            sgot = spec_step(sgot)
        for k in got:
            region = tuple(
                slice(0, d) for d in pat.space(k).concrete_shape(env)
            )
            np.testing.assert_array_equal(
                got[k][region], np.asarray(sgot[k]),
                err_msg=f"N-D vs specialized: {k} diverged at n={env['n']}",
            )
        # and the serial oracle (plain numpy semantics)
        want = serial_oracle(pat, sch.lower(pat.domain, env),
                             pat.allocate(env), env, ntimes=2)
        _assert_region(pat, env, got, want, "N-D strided")


def test_nd_windows_jacobi2d_bit_exact():
    """The headline case: independent-template jacobi2d windows an
    (i-chunk x j-chunk) box per step — rank-2 windows, full ladder
    bit-identical to the mirror, the specialized path, and the oracle."""
    pat = independent_view(jacobi2d(), 4)
    _check_nd_windows(pat, identity(), [{"n": 18}, {"n": 34}], {"n": 34},
                      want_rank=2)


def test_nd_windows_jacobi2d_unaligned_rungs():
    """Rung extents that do NOT divide the window chunks exercise the
    per-band min-start overlap (overlapped lanes recompute identical
    values)."""
    pat = independent_view(jacobi2d(), 2)
    _check_nd_windows(pat, identity(),
                      [{"n": 18}, {"n": 23}, {"n": 29}], {"n": 29},
                      want_rank=2)


@pytest.mark.slow
def test_nd_windows_jacobi3d_bit_exact():
    pat = independent_view(jacobi3d(), 2)
    _check_nd_windows(pat, identity(), [{"n": 10}, {"n": 18}], {"n": 18},
                      want_rank=3)


def test_nd_windows_masked_lane_tiny_rungs():
    """A 2D ladder under the mask-free floor keeps N-D outer windows
    (always full via min-start overlap) while the lane band takes the
    sign-anchored masked emission — including a rung smaller than one
    lane window."""
    pat = jacobi2d()
    sch = identity()
    pnest = sch.lower_symbolic(pat.domain, ("n",))
    splan = param_strided_plan(pat, pnest)
    envs = [{"n": 6}, {"n": 10}]
    spec, full = param_strided_window(pnest, splan, envs, {"n": 10})
    assert isinstance(spec, tuple) and full is False
    _check_nd_windows(pat, sch, envs, {"n": 10}, want_rank=2)


def test_masked_lane_second_clamp_tier_rank1():
    """A masked ladder whose small rung is far below the capacity must
    not pay capacity-extent lane windows: the chunk is clamped to
    ``max(floor, smallest rung)`` and the runtime trip count covers the
    larger rungs. Pinned through the driver's ladder resolution."""
    d = Driver(lambda env: triad(),
               DriverConfig(template="independent", programs=4, ntimes=2,
                            reps=1, parametric="auto"),
               cache=TranslationCache())
    cap = {"n": 1 << 15}
    envs = d._point_envs([8, 1 << 15], None)
    path, chunk, full = d._resolve_param_path(envs, cap)
    assert path == "strided" and full is False
    assert chunk == 1024          # floor, not the 32768-lane capacity
    # the clamped masked emission stays bit-exact against its mirror and
    # the specialized path at the tiny rung
    _check_all_regimes(triad(), identity(), {"n": 8}, cap, chunk)


def test_masked_lane_second_clamp_tier_nd():
    """N-D form of the same policy: the lane band of a masked stencil
    ladder is clamped by ``max(floor, smallest rung extent)``, never the
    capacity extent."""
    pat = jacobi2d()
    sch = identity()
    pnest = sch.lower_symbolic(pat.domain, ("n",))
    splan = param_strided_plan(pat, pnest)
    envs = [{"n": 6}, {"n": 130}]
    cap_env = {"n": 130}
    # floor=64 scales the scenario down: the small rung's whole window
    # is 4x4=16 points (masked), the capacity lane extent is 128, and
    # the clamp tier must bound the lane chunk at the floor, 64
    spec, full = param_strided_window(pnest, splan, envs, cap_env,
                                      floor=64)
    assert full is False
    lane = dict(spec)[param_window_bands(pnest, splan)[-1]]
    assert lane == 64
    for env in envs:
        assert param_strided_in_bounds(pat, pnest, splan, env, cap_env,
                                       spec)
        step = lower_jax_parametric(pat, sch, cap_env, chunk=spec,
                                    param_path="strided", assume_full=full)
        got = {k: jnp.asarray(v) for k, v in pat.allocate(cap_env).items()}
        for _ in range(2):
            got = step(got, (np.int32(env["n"]),))
        got = {k: np.asarray(v) for k, v in got.items()}
        mirror = windowed_oracle(pat, sch, env, cap_env,
                                 pat.allocate(cap_env), ntimes=2,
                                 chunk=spec, assume_full=full)
        for k in mirror:
            np.testing.assert_array_equal(
                got[k], mirror[k],
                err_msg=f"clamped lane mirror: {k} at n={env['n']}")


def test_nd_window_policy_through_driver():
    """The driver resolves stencil ladders to an N-D window spec, runs
    them strided with one shared executable, and stamps the window rank
    into every record."""
    cache = TranslationCache()
    d = Driver(lambda env: jacobi2d(),
               DriverConfig(template="independent", programs=4, ntimes=2,
                            reps=1, validate_n=18, parametric="auto"),
               cache=cache)
    envs = d._point_envs([18, 34], None)
    path, spec, full = d._resolve_param_path(envs, {"n": 34})
    assert path == "strided" and full is True
    assert isinstance(spec, tuple) and len(spec) == 2
    recs = d.run([18, 34])
    assert cache.stats()["compile_misses"] == 1
    assert [r.extra["param_path"] for r in recs] == ["strided"] * 2
    assert [r.extra["param_window_rank"] for r in recs] == [2, 2]
    d.validate_parametric([18, 34])


def test_nd_window_bands_exclude_unwritten_dims():
    """A dynamic band the write ignores must stay a serial loop band
    (windowing it would collapse its last-value-wins writes):
    D[i] = M[k, i] over an outer k loop keeps only the final k row —
    the k band is read but never written, so it must not be windowed."""
    i, k = Affine.of("i"), Affine.of("k")
    stmt = Statement(
        reads=(Access("M", (k, i)),),
        write=Access("D", (i,)),
        combine=lambda vals, env: vals[0],
    )
    pat = PatternSpec(
        "rowlast",
        (
            DataSpace("D", ("n",), "float32", 0.0),
            DataSpace("M", ("n", "n"), "float32",
                      lambda k, i: (i + 3 * k % 7).astype(np.float32)),
        ),
        stmt,
        domain(("k", 0, "n"), ("i", 0, "n")),
    )
    pnest = identity().lower_symbolic(pat.domain, ("n",))
    splan = param_strided_plan(pat, pnest)
    assert splan is not None
    # only the innermost (lane) band is windowable; k stays a loop band
    assert param_window_bands(pnest, splan) == (1,)
    spec, _ = param_strided_window(pnest, splan,
                                   [{"n": 8}, {"n": 12}], {"n": 12})
    assert isinstance(spec, int)  # rank-1 ladders keep the legacy form
    # the serial loop band executes k in order: the strided step and its
    # mirror must agree with the point-by-point oracle (last k wins) —
    # the vectorized oracle cannot express a band-collapsing write, so
    # diff against the forced point loop
    env, cap = {"n": 8}, {"n": 12}
    step = lower_jax_parametric(pat, identity(), cap, chunk=spec,
                                param_path="strided")
    assert step.param_window_rank == 1
    got = {k: jnp.asarray(v) for k, v in pat.allocate(cap).items()}
    for _ in range(2):
        got = step(got, (np.int32(env["n"]),))
    got = {k: np.asarray(v) for k, v in got.items()}
    mirror = windowed_oracle(pat, identity(), env, cap, pat.allocate(cap),
                             ntimes=2, chunk=spec)
    for k in mirror:
        np.testing.assert_array_equal(got[k], mirror[k])
    want = serial_oracle(pat, identity().lower(pat.domain, env),
                         pat.allocate(env), env, ntimes=2, force_loop=True)
    _assert_region(pat, env, got, want, "loop-band strided")


def test_windowed_oracle_rejects_ineligible():
    pat = triad()
    sch = identity().tile_by_count("i", 4, outer="prog", inner="i")
    with pytest.raises(ValueError, match="strided-eligible"):
        windowed_oracle(pat, sch, {"n": 16}, {"n": 64}, pat.allocate({"n": 64}))


def test_strided_ladder_compiles_once_and_reports_path():
    """The acceptance property: a strided-eligible ladder shares ONE
    executable (1 compile + 1 lower miss), every record says so, and the
    specialized fast-path plan agrees the nest is strided territory."""
    cache = TranslationCache()
    d = Driver(lambda env: triad(),
               DriverConfig(template="independent", programs=4, ntimes=2,
                            reps=1, parametric="auto"), cache=cache)
    recs = d.run([256, 512, 1024, 2048])
    s = cache.stats()
    assert s["compile_misses"] == 1 and s["lower_misses"] == 1
    assert all(r.extra["param_path"] == "strided" for r in recs)
    assert all(r.extra["parametric"] for r in recs)
    plan = plan_nest(independent_view(triad(), 4), identity(), {"n": 256})
    assert plan.fast
