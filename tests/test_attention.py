"""Attention-layer unit tests: flash chunking, windows, rings, MLA."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    RING_EMPTY_POS, chunked_attention, ring_update,
)

KEY = jax.random.PRNGKey(5)


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    kk = jnp.repeat(k, g, 2).astype(jnp.float32)
    vv = jnp.repeat(v, g, 2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk) / np.sqrt(D)
    qp = jnp.arange(Sq) + q_offset
    kp = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window:
        mask &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv).astype(q.dtype)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([(4, 2), (8, 8), (6, 2)]),
       st.sampled_from([16, 48, 64]),
       st.sampled_from([0, 8]),
       st.sampled_from([8, 16, 1000]))
def test_chunked_matches_naive(heads, S, window, kv_chunk):
    H, Hkv = heads
    q = jax.random.normal(KEY, (2, S, H, 8), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, S, Hkv, 8), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, S, Hkv, 8), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            kv_chunk=kv_chunk, q_chunk=16)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_traced_window_matches_static():
    q = jax.random.normal(KEY, (1, 32, 4, 8), jnp.float32)
    k = jax.random.normal(KEY, (1, 32, 4, 8), jnp.float32)
    v = jax.random.normal(KEY, (1, 32, 4, 8), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, window=8, kv_chunk=8)
    b = jax.jit(lambda w: chunked_attention(
        q, k, v, causal=True, window=w, kv_chunk=8))(jnp.asarray(8))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_ring_update_wraps_and_tracks_positions():
    B, W, Hkv, D = 1, 4, 1, 2
    ck = jnp.zeros((B, W, Hkv, D))
    cv = jnp.zeros((B, W, Hkv, D))
    pos = jnp.full((W,), RING_EMPTY_POS, jnp.int32)
    # write positions 0..5 one at a time through a window of 4
    for p in range(6):
        kn = jnp.full((B, 1, Hkv, D), float(p))
        ck, cv, pos = ring_update(ck, cv, pos, kn, kn, p)
    # slots hold positions 4,5,2,3 (p % 4)
    np.testing.assert_array_equal(np.asarray(pos), [4, 5, 2, 3])
    np.testing.assert_allclose(np.asarray(ck[0, :, 0, 0]), [4, 5, 2, 3])


def test_ring_update_bulk_prefill_keeps_tail():
    B, W, Hkv, D = 1, 4, 1, 2
    ck = jnp.zeros((B, W, Hkv, D))
    cv = jnp.zeros((B, W, Hkv, D))
    pos = jnp.full((W,), RING_EMPTY_POS, jnp.int32)
    k_new = jnp.arange(10, dtype=jnp.float32).reshape(1, 10, 1, 1)
    k_new = jnp.broadcast_to(k_new, (B, 10, Hkv, D))
    ck, cv, pos = ring_update(ck, cv, pos, k_new, k_new, 0)
    # only the last 4 of 10 positions survive
    assert sorted(np.asarray(pos).tolist()) == [6, 7, 8, 9]


def test_ring_attention_equals_linear_cache_decode():
    """One decode step via ring == attention over the full history with a
    window mask (position > window boundary)."""
    W, window = 9, 8
    B, Hkv, D, H = 1, 2, 4, 4
    S_hist = 20
    keys = jax.random.normal(KEY, (B, S_hist + 1, Hkv, D), jnp.float32)
    vals = jax.random.normal(jax.random.PRNGKey(9), (B, S_hist + 1, Hkv, D),
                             jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(8), (B, 1, H, D), jnp.float32)

    ck = jnp.zeros((B, W, Hkv, D))
    cv = jnp.zeros((B, W, Hkv, D))
    pos = jnp.full((W,), RING_EMPTY_POS, jnp.int32)
    for p in range(S_hist + 1):
        ck, cv, pos = ring_update(ck, cv, pos, keys[:, p:p + 1],
                                  vals[:, p:p + 1], p)
    out_ring = chunked_attention(
        q, ck, cv, causal=True, q_offset=S_hist, window=window,
        kv_positions=pos, kv_chunk=3)
    out_ref = naive_attention(q, keys, vals, causal=True, window=window,
                              q_offset=S_hist)
    np.testing.assert_allclose(np.asarray(out_ring), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-4)


def test_mla_absorbed_equals_decompressed():
    from repro.config import MLAConfig
    from repro.models.attention import mla_apply, mla_init

    mla = MLAConfig(q_lora_rank=16, kv_lora_rank=24, qk_nope_head_dim=8,
                    qk_rope_head_dim=4, v_head_dim=8)
    d, H, B, S = 32, 4, 2, 12
    p = mla_init(KEY, d, H, mla, dtype=jnp.float32)
    x = jax.random.normal(KEY, (B, S, d), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cache0 = {"ckv": jnp.zeros((B, S, 24), jnp.float32),
              "krope": jnp.zeros((B, S, 4), jnp.float32),
              "len": jnp.zeros((), jnp.int32)}
    _, c = mla_apply(p, x[:, :-1], n_heads=H, mla=mla,
                     positions=pos[:, :-1], cache=cache0,
                     absorbed_decode=False)
    o_abs, _ = mla_apply(p, x[:, -1:], n_heads=H, mla=mla,
                         positions=pos[:, -1:], cache=c,
                         absorbed_decode=True)
    o_dec, _ = mla_apply(p, x[:, -1:], n_heads=H, mla=mla,
                         positions=pos[:, -1:], cache=c,
                         absorbed_decode=False)
    np.testing.assert_allclose(np.asarray(o_abs), np.asarray(o_dec),
                               rtol=2e-4, atol=2e-4)
