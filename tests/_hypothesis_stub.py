"""Deterministic mini-hypothesis used when the real package is absent.

The test suite property-tests with a small hypothesis surface:
``given``, ``settings``, ``st.integers/sampled_from/booleans/floats/
lists/data/composite``. This stub replays each ``@given`` test over
``max_examples`` pseudo-random examples drawn from a generator seeded by
the test's qualified name — deterministic across runs, no shrinking, no
database. ``tests/conftest.py`` installs it as ``sys.modules
["hypothesis"]`` only when the real package is unavailable.
"""
from __future__ import annotations

import random
import types

_DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)


def sampled_from(seq):
    items = list(seq)
    if not items:
        raise ValueError("sampled_from requires a non-empty sequence")
    return Strategy(lambda rng: items[rng.randrange(len(items))])


def integers(min_value, max_value):
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans():
    return Strategy(lambda rng: rng.random() < 0.5)


def floats(min_value=None, max_value=None, allow_nan=True,
           allow_infinity=None, **_kw):
    lo = -1e9 if min_value is None else min_value
    hi = 1e9 if max_value is None else max_value

    def sample(rng):
        # bias toward boundary values the way hypothesis does
        r = rng.random()
        if r < 0.1:
            return float(lo)
        if r < 0.2:
            return float(hi)
        if r < 0.3:
            return 0.0 if lo <= 0.0 <= hi else float(lo)
        return rng.uniform(lo, hi)

    return Strategy(sample)


def lists(elements: Strategy, min_size=0, max_size=None, **_kw):
    hi = (min_size + 16) if max_size is None else max_size
    return Strategy(
        lambda rng: [elements.example(rng)
                     for _ in range(rng.randint(min_size, hi))]
    )


class DataObject:
    """Interactive draws (``st.data()``) share the test's generator."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: Strategy, label=None):
        return strategy.example(self._rng)


class _DataStrategy(Strategy):
    def __init__(self):
        super().__init__(lambda rng: DataObject(rng))


def data():
    return _DataStrategy()


def composite(fn):
    """``@st.composite def s(draw, *args)`` -> callable returning a Strategy."""

    def make(*args, **kwargs):
        def sample(rng):
            draw = DataObject(rng).draw
            return fn(draw, *args, **kwargs)

        return Strategy(sample)

    make.__name__ = fn.__name__
    make.__doc__ = fn.__doc__
    return make


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        def wrapper():
            max_examples = getattr(
                wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES
            )
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for i in range(max_examples):
                args = [s.example(rng) for s in strategies]
                try:
                    fn(*args)
                except BaseException:
                    shown = [
                        a if not isinstance(a, DataObject) else "<data>"
                        for a in args
                    ]
                    print(f"[hypothesis-stub] falsified on example "
                          f"{i}: {shown!r}")
                    raise

        # keep pytest's collected signature argument-free (no __wrapped__:
        # pytest would treat the original params as fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__qualname__ = fn.__qualname__
        return wrapper

    return deco


strategies = types.SimpleNamespace(
    sampled_from=sampled_from,
    integers=integers,
    booleans=booleans,
    floats=floats,
    lists=lists,
    data=data,
    composite=composite,
)
