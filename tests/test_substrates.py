"""Substrate tests: optimizer, compression, checkpoint, data, FT loop."""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import Checkpointer, latest_step, restore, save
from repro.data.pipeline import Loader, SyntheticSource
from repro.optim import (
    adafactor, adamw, cosine_schedule, dequantize_int8, error_feedback,
    quantize_int8,
)
from repro.runtime.fault_tolerance import FTConfig, FaultTolerantLoop

KEY = jax.random.PRNGKey(3)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def _quad_params():
    return {"w": jnp.asarray([1.5, -2.0, 0.5]), "b": jnp.asarray([0.3])}


def _quad_loss(p):
    return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(5e-2, weight_decay=0.0),
    lambda: adafactor(5e-2, weight_decay=0.0),
    lambda: error_feedback(adamw(5e-2, weight_decay=0.0)),
])
def test_optimizers_descend_quadratic(make_opt):
    opt = make_opt()
    p = _quad_params()
    s = opt.init(p)
    l0 = float(_quad_loss(p))
    for _ in range(60):
        g = jax.grad(_quad_loss)(p)
        p, s = opt.update(g, s, p)
    assert float(_quad_loss(p)) < 0.2 * l0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) < float(lr(jnp.asarray(50)))


def test_adamw_bf16_moments_track_f32():
    p = _quad_params()
    o32, o16 = adamw(1e-2), adamw(1e-2, moment_dtype=jnp.bfloat16)
    s32, s16 = o32.init(p), o16.init(p)
    p32 = p16 = p
    for _ in range(10):
        g = jax.grad(_quad_loss)(p32)
        p32, s32 = o32.update(g, s32, p32)
        g = jax.grad(_quad_loss)(p16)
        p16, s16 = o16.update(g, s16, p16)
    np.testing.assert_allclose(np.asarray(p32["w"]), np.asarray(p16["w"]),
                               rtol=0.05, atol=0.01)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
def test_int8_quantization_roundtrip_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6  # half-ULP of the int8 grid


def test_error_feedback_removes_bias():
    """Constant gradient: EF-compressed updates converge to the same mean
    step as uncompressed (bias cancels across steps)."""
    g = {"w": jnp.full((4,), 0.013, jnp.float32)}
    p = {"w": jnp.zeros((4,), jnp.float32)}
    base = adamw(1e-2, weight_decay=0.0)
    opt = error_feedback(base)
    s = opt.init(p)
    p_ef = p
    for _ in range(50):
        p_ef, s = opt.update(g, s, p_ef)
    s0 = base.init(p)
    p_ref = p
    for _ in range(50):
        p_ref, s0 = base.update(g, s0, p_ref)
    np.testing.assert_allclose(np.asarray(p_ef["w"]), np.asarray(p_ref["w"]),
                               rtol=0.02, atol=1e-4)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,), jnp.float32),
                  "d": (jnp.zeros((2,)), jnp.full((1,), 7.0))},
            "step": jnp.asarray(5, jnp.int32)}
    save(tmp_path, 5, tree)
    assert latest_step(tmp_path) == 5
    out = restore(tmp_path, 5, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_tmp_never_visible(tmp_path):
    tree = {"x": jnp.ones((3,))}
    save(tmp_path, 1, tree)
    (tmp_path / "step_00000002.tmp").mkdir()  # simulated dead writer
    assert latest_step(tmp_path) == 1


def test_checkpointer_async_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3):
        ck.save_async(s, {"x": jnp.full((2,), float(s))})
    ck.wait()
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert steps == ["step_00000002", "step_00000003"]


def test_restore_shape_mismatch_fails(tmp_path):
    save(tmp_path, 1, {"x": jnp.ones((3,))})
    with pytest.raises(ValueError):
        restore(tmp_path, 1, {"x": jax.ShapeDtypeStruct((4,), jnp.float32)})


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_synthetic_source_deterministic():
    src = SyntheticSource(vocab_size=100, batch=2, seq_len=8, seed=1)
    a, b = src.get(3), src.get(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_loader_prefetch_order():
    src = SyntheticSource(vocab_size=50, batch=1, seq_len=4)
    loader = Loader(src, None)
    steps = [next(loader)[0] for _ in range(4)]
    loader.close()
    assert steps == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def _toy_step(state, batch):
    p = state["p"] - 0.1 * batch["g"]
    return {"p": p}, {"loss": jnp.sum(p ** 2)}


def _batches():
    step = 0
    while True:
        yield step, {"g": jnp.full((2,), 0.5)}
        step += 1


def test_ft_retries_transient(tmp_path):
    faults = {2: "transient"}
    loop = FaultTolerantLoop(
        _toy_step, {"p": jnp.ones((2,))},
        FTConfig(str(tmp_path), ckpt_every=100),
        failure_hook=lambda s: faults.get(s))
    out = loop.run(_batches(), 5)
    assert out["final_step"] == 5
    assert any("retry0" in e for _, e in out["events"])


def test_ft_checkpoints_and_resume(tmp_path):
    loop = FaultTolerantLoop(
        _toy_step, {"p": jnp.ones((2,))},
        FTConfig(str(tmp_path), ckpt_every=2))
    loop.run(_batches(), 4)
    assert latest_step(tmp_path) == 4

    fresh = FaultTolerantLoop(
        _toy_step, {"p": jnp.ones((2,))},
        FTConfig(str(tmp_path), ckpt_every=2))
    resumed = fresh.try_resume()
    assert resumed == 4
    np.testing.assert_allclose(
        np.asarray(fresh.state["p"]),
        np.asarray(loop.state["p"]))


def test_ft_resize_hook_called(tmp_path):
    called = []

    def resize(state):
        called.append(True)
        return state

    faults = {3: "resize"}
    loop = FaultTolerantLoop(
        _toy_step, {"p": jnp.ones((2,))},
        FTConfig(str(tmp_path), ckpt_every=100),
        failure_hook=lambda s: faults.get(s), resize_hook=resize)
    out = loop.run(_batches(), 5)
    assert called and any(e == "resized" for _, e in out["events"])
    # pre-resize checkpoint exists
    assert latest_step(tmp_path) == 3
