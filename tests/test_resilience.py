"""Resilience-layer tests (the PR-6 acceptance contract).

Covers: the failure taxonomy (stages, transient flags, classification,
FailureRecord schema + JSON round-trip), adaptive time_fn (CV mode, rep
budget, straggler counting, wall-clock watchdog raising BudgetExceeded),
time_pair's strict A/B alternation, the capacity pre-flight
(CapacityRefused instead of OOM), fault-isolated run_plan (injected
lower/compile/validate/measure faults, per-point isolation in
multi-group plans, demotion-ladder order, transient retry), the
resumable run journal (write → crash → resume with byte-identical
replayed rows and zero recompiles), and the RunReport schema.
"""
from __future__ import annotations

import dataclasses
import json
import time

import pytest

from repro.core import (
    BenchFailure,
    BudgetExceeded,
    CapacityRefused,
    CompileFailure,
    Driver,
    DriverConfig,
    FailureRecord,
    LowerFailure,
    MeasureFailure,
    ResiliencePolicy,
    SweepFailures,
    TranslationCache,
    ValidateFailure,
    classify_failure,
    gather,
    time_fn,
    time_pair,
    triad,
)
from repro.core import drivers as drivers_mod
from repro.core.staging import ParamLowered
from repro.suite import (
    RunJournal,
    SweepPlan,
    VariantSpec,
    env_axis,
    pattern_axis,
    run_plan,
    stable_fingerprint,
)

CFG = DriverConfig(template="unified", programs=2, ntimes=2, reps=1,
                   validate_n=None)


def _plan(*ns):
    return SweepPlan.product(env_axis(tuple(ns)))


# ---------------------------------------------------------------------------
# taxonomy
# ---------------------------------------------------------------------------


def test_taxonomy_stages_and_transience():
    assert LowerFailure.stage == "lower" and not LowerFailure.transient
    assert CompileFailure.stage == "compile" and not CompileFailure.transient
    assert ValidateFailure.stage == "validate"
    assert MeasureFailure.stage == "measure" and MeasureFailure.transient
    assert issubclass(BudgetExceeded, MeasureFailure)
    assert BudgetExceeded.transient
    assert CapacityRefused.stage == "capacity"
    assert not CapacityRefused.transient
    for cls in (LowerFailure, CompileFailure, ValidateFailure,
                MeasureFailure, BudgetExceeded, CapacityRefused):
        assert issubclass(cls, BenchFailure)
        assert issubclass(cls, RuntimeError)


def test_classify_wraps_and_passes_through():
    plain = ValueError("boom")
    wrapped = classify_failure(plain, "compile", template="unified")
    assert isinstance(wrapped, CompileFailure)
    assert wrapped.cause is plain
    assert wrapped.context["template"] == "unified"
    assert "ValueError" in str(wrapped)
    # an existing BenchFailure keeps its own stage; context merges
    cap = CapacityRefused("too big", context={"budget_bytes": 10})
    again = classify_failure(cap, "measure", env={"n": 4})
    assert again is cap
    assert again.stage == "capacity"
    assert again.context["env"] == {"n": 4}
    assert again.context["budget_bytes"] == 10  # original context wins


def test_failure_record_json_roundtrip():
    fr = FailureRecord(
        variant="v", label="n256", stage="compile", error="CompileFailure",
        message="boom", pattern="triad", template="unified",
        schedule="identity", backend="jax", env={"n": 256},
        axis_point={"n": "n256"}, context={"cause": "ValueError",
                                           "weird": object()},
        attempts=3, demotions=("strided->gather",))
    d = json.loads(fr.json())
    assert d["stage"] == "compile" and d["attempts"] == 3
    assert d["demotions"] == ["strided->gather"]
    # arbitrary context objects were sanitized, not crashed on
    assert isinstance(d["context"]["weird"], str)
    rebuilt = FailureRecord(**d)
    assert rebuilt.label == fr.label and rebuilt.stage == fr.stage


# ---------------------------------------------------------------------------
# adaptive measurement quality
# ---------------------------------------------------------------------------


def test_time_fn_legacy_reps_exact():
    calls = []
    t = time_fn(lambda: calls.append(1), reps=4, warmup=1)
    assert t.reps == 4 and len(t.all_seconds) == 4
    assert len(calls) == 5  # warmup + reps
    assert t.converged and t.target_cv is None
    assert t.minimum == min(t.all_seconds)
    assert t.seconds == sorted(t.all_seconds)[2]


def test_time_fn_adaptive_runs_to_rep_budget_when_cv_unreachable():
    t = time_fn(lambda: None, reps=3, warmup=0, target_cv=0.0, max_reps=9)
    assert t.reps == 9            # CV of real timings never hits exactly 0
    assert not t.converged
    assert t.target_cv == 0.0
    q = t.quality()
    assert {"median_s", "min_s", "cv", "reps", "target_cv", "converged",
            "slow_reps"} <= set(q)
    assert q["reps"] == 9 and q["converged"] is False


def test_time_fn_adaptive_converges_on_loose_target():
    t = time_fn(lambda: None, reps=3, warmup=0, target_cv=1e9, max_reps=50)
    assert t.reps == 3 and t.converged


def test_time_fn_straggler_counting():
    calls = {"i": 0}

    def fn():
        calls["i"] += 1
        time.sleep(0.05 if calls["i"] == 6 else 0.001)

    t = time_fn(fn, reps=6, warmup=1)  # call 6 = timed rep 5 (a straggler)
    assert t.slow_reps >= 1
    assert t.quality()["slow_reps"] >= 1


def test_time_fn_watchdog_raises_budget_exceeded():
    with pytest.raises(BudgetExceeded) as ei:
        time_fn(lambda: time.sleep(0.03), reps=50, warmup=0, budget_s=0.05)
    ctx = ei.value.context
    assert ctx["budget_s"] == 0.05
    assert ctx["elapsed_s"] > 0.05
    assert 0 < ctx["reps_done"] < 50
    assert ei.value.transient  # a retry under calmer load may fit


def test_time_pair_alternates_and_reports_quality():
    order = []
    ta, tb = time_pair(lambda: order.append("a"), (),
                       lambda: order.append("b"), (), reps=3, passes=2,
                       warmup=1)
    # warmup pair first, then strict A/B alternation
    assert order == ["a", "b"] * 7
    assert ta.reps == tb.reps == 6
    assert ta.minimum <= ta.seconds
    assert {"median_s", "min_s", "cv"} <= set(tb.quality())


# ---------------------------------------------------------------------------
# guard rails in the driver
# ---------------------------------------------------------------------------


def test_capacity_preflight_refuses_structured():
    d = Driver(lambda env: triad(),
               dataclasses.replace(CFG, capacity_budget_bytes=1024),
               cache=TranslationCache())
    with pytest.raises(CapacityRefused) as ei:
        d.run([1 << 14])
    ctx = ei.value.context
    assert ctx["required_bytes"] == 2 * ctx["working_set_bytes"]
    assert ctx["required_bytes"] > ctx["budget_bytes"] == 1024
    assert ctx["pattern"] == "triad"
    assert ctx["env"]["n"] == 1 << 14


def test_capacity_preflight_admits_within_budget():
    d = Driver(lambda env: triad(),
               dataclasses.replace(CFG, capacity_budget_bytes=1 << 30),
               cache=TranslationCache())
    (rec,) = d.run([256])
    assert rec.n == 256


def test_records_stamp_timing_quality():
    d = Driver(lambda env: triad(), CFG, cache=TranslationCache())
    (rec,) = d.run([256])
    q = rec.extra["timing_quality"]
    assert q["reps"] == CFG.reps
    assert q["min_s"] <= q["median_s"]


def test_driver_budget_exceeded_carries_context():
    d = Driver(lambda env: triad(),
               dataclasses.replace(CFG, time_budget_s=1e-9, reps=3),
               cache=TranslationCache())
    with pytest.raises(BudgetExceeded) as ei:
        d.run([256])
    assert ei.value.context["template"] == "unified"
    assert ei.value.context["pattern"] == "triad"


# ---------------------------------------------------------------------------
# fault-isolated run_plan
# ---------------------------------------------------------------------------


def _poisoned_factory(env, stride=2):
    if stride == 13:
        raise RuntimeError("injected poison")
    return gather(stride=stride)


def test_poisoned_point_does_not_abort_sweep():
    plan = SweepPlan.product(pattern_axis("stride", (2, 13, 8)),
                             env_axis((256,)))
    report = run_plan(_poisoned_factory, [VariantSpec("g", CFG)], plan,
                      cache=TranslationCache())
    assert [r.point.label for r in report.rows] == ["stride2/n256",
                                                    "stride8/n256"]
    assert [f.label for f in report.failures] == ["stride13/n256"]
    f = report.failures[0]
    assert f.stage == "lower" and f.error == "LowerFailure"
    assert f.context["cause"] == "RuntimeError"
    assert "injected poison" in f.message
    assert f.attempts >= 2 and f.demotions  # the ladder was walked
    assert not report.ok


def test_strict_mode_raises_original_exception():
    plan = SweepPlan.product(pattern_axis("stride", (2, 13)),
                             env_axis((256,)))
    with pytest.raises(RuntimeError, match="injected poison"):
        run_plan(_poisoned_factory, [VariantSpec("g", CFG)], plan,
                 cache=TranslationCache(), on_error="raise")


def test_run_plan_rejects_unknown_on_error():
    with pytest.raises(ValueError, match="on_error"):
        run_plan(lambda env: triad(), [VariantSpec("t", CFG)], _plan(256),
                 cache=TranslationCache(), on_error="ignore")


def test_injected_compile_fault_demotes_to_specialized(monkeypatch):
    """A parametric-only compile fault walks strided->gather (still
    parametric: still broken) then parametric->specialized (works), and
    the records carry the demotion trail — 'demoted-then-recorded'."""
    real = ParamLowered.compile

    def broken(self, **kw):
        raise RuntimeError("parametric compile poisoned")

    monkeypatch.setattr(ParamLowered, "compile", broken)
    cfg = dataclasses.replace(CFG, template="independent", programs=2,
                              parametric="auto", param_path="auto")
    report = run_plan(lambda env: triad(), [VariantSpec("t", cfg)],
                      _plan(256, 512), cache=TranslationCache())
    assert report.ok
    assert [r.point.label for r in report.rows] == ["n256", "n512"]
    steps = [d.step for d in report.demotions]
    assert steps == ["strided->gather", "parametric->specialized"]
    assert report.demotions[0].stage == "compile"
    assert report.demotions[0].error == "CompileFailure"
    for r in report.rows:
        assert r.record.extra["param_path"] == "specialized"
        assert r.record.extra["demotions"] == ["strided->gather",
                                               "parametric->specialized"]
    monkeypatch.setattr(ParamLowered, "compile", real)


def test_demotion_ladder_order_ends_undonated():
    """A fault that only clears once donation is off exercises the full
    ladder in order; the surviving record reports donated=False."""
    calls = {"n": 0}
    real = Driver.measure_point

    def flaky(self, p):
        if getattr(p.compiled, "donated", True):
            raise RuntimeError("donation stream poisoned")
        return real(self, p)

    plan = _plan(256, 512)
    cfg = dataclasses.replace(CFG, template="independent", programs=2,
                              parametric="auto")
    try:
        Driver.measure_point = flaky
        report = run_plan(lambda env: triad(), [VariantSpec("t", cfg)],
                          plan, cache=TranslationCache())
    finally:
        Driver.measure_point = real
    assert report.ok
    steps = [d.step for d in report.demotions]
    assert steps == ["strided->gather", "parametric->specialized",
                     "donated->undonated"]
    for r in report.rows:
        assert r.record.extra["donated"] is False


def test_injected_validate_fault_is_classified(monkeypatch):
    real = Driver.validate

    def bad(self, env=None):
        raise AssertionError("oracle disagrees")

    monkeypatch.setattr(Driver, "validate", bad)
    cfg = dataclasses.replace(CFG, validate_n=64)
    report = run_plan(lambda env: triad(), [VariantSpec("t", cfg)],
                      _plan(256), cache=TranslationCache())
    assert not report.rows
    assert {f.stage for f in report.failures} == {"validate"}
    assert {f.error for f in report.failures} == {"ValidateFailure"}
    monkeypatch.setattr(Driver, "validate", real)
    # strict mode: the original AssertionError propagates
    with pytest.raises(AssertionError, match="oracle disagrees"):
        monkeypatch.setattr(Driver, "validate", bad)
        run_plan(lambda env: triad(), [VariantSpec("t", cfg)], _plan(256),
                 cache=TranslationCache(), on_error="raise")


def test_transient_measure_fault_retries_without_demotion():
    real = Driver.measure_point
    calls = {"n": 0}

    def once_flaky(self, p):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("spurious load spike")
        return real(self, p)

    try:
        Driver.measure_point = once_flaky
        report = run_plan(
            lambda env: triad(), [VariantSpec("t", CFG)], _plan(256),
            cache=TranslationCache(),
            resilience=ResiliencePolicy(max_retries=2, backoff_s=0.0))
    finally:
        Driver.measure_point = real
    assert report.ok and len(report.rows) == 1
    assert not report.demotions  # the retry fixed it inside the same rung


def test_capacity_refusal_isolated_per_point():
    """One oversized point fails with a structured capacity refusal;
    the in-budget point still measures (after parametric demotion —
    the shared executable would allocate everything at capacity)."""
    ws = 3 * 256 * 4  # triad working set at n=256
    cfg = dataclasses.replace(CFG, template="independent", programs=2,
                              parametric="auto",
                              capacity_budget_bytes=8 * ws)
    report = run_plan(lambda env: triad(), [VariantSpec("t", cfg)],
                      _plan(256, 1 << 20), cache=TranslationCache())
    assert [r.point.label for r in report.rows] == ["n256"]
    (f,) = report.failures
    assert f.label == f"n{1 << 20}"
    assert f.stage == "capacity" and f.error == "CapacityRefused"
    assert f.context["required_bytes"] > f.context["budget_bytes"]


def test_multi_group_isolation_other_variant_untouched():
    plan = SweepPlan.product(pattern_axis("stride", (2, 13)),
                             env_axis((256,)))
    report = run_plan(
        _poisoned_factory,
        [VariantSpec("a", CFG),
         VariantSpec("b", dataclasses.replace(CFG, programs=4))],
        plan, cache=TranslationCache())
    assert [(r.variant, r.point.label) for r in report.rows] == [
        ("a", "stride2/n256"), ("b", "stride2/n256")]
    assert {(f.variant, f.label) for f in report.failures} == {
        ("a", "stride13/n256"), ("b", "stride13/n256")}


def test_sweep_failures_aggregate():
    plan = SweepPlan.product(pattern_axis("stride", (13,)), env_axis((256,)))
    report = run_plan(_poisoned_factory, [VariantSpec("g", CFG)], plan,
                      cache=TranslationCache())
    with pytest.raises(SweepFailures) as ei:
        report.raise_if_failed()
    assert ei.value.failures == tuple(report.failures)
    assert "stride13/n256" in str(ei.value)


def test_run_report_sequence_protocol():
    report = run_plan(lambda env: triad(), [VariantSpec("t", CFG)],
                      _plan(256, 512), cache=TranslationCache())
    assert len(report) == 2
    assert [r.point.label for r in report] == ["n256", "n512"]
    assert report[0].variant == "t"
    assert report.ok and report.summary()["failures"] == []


# ---------------------------------------------------------------------------
# resumable journal
# ---------------------------------------------------------------------------


def test_stable_fingerprint_is_deterministic():
    cfg = CFG
    a = stable_fingerprint("v", (("n", "n256"),), "n256", cfg,
                           lambda env: triad())
    b = stable_fingerprint("v", (("n", "n256"),), "n256", cfg,
                           lambda env: triad())
    assert a == b and len(a) == 40
    assert a != stable_fingerprint("v2", (("n", "n256"),), "n256", cfg)
    assert stable_fingerprint(1) != stable_fingerprint("1")
    assert stable_fingerprint(True) != stable_fingerprint(1)


def test_journal_full_replay_byte_identical(tmp_path):
    jpath = tmp_path / "run.jsonl"
    v = [VariantSpec("t", CFG)]
    c1 = TranslationCache()
    r1 = run_plan(lambda env: triad(), v, _plan(256, 512), cache=c1,
                  journal=str(jpath))
    assert r1.replayed == 0 and len(r1.rows) == 2
    assert len(jpath.read_text().splitlines()) == 2
    c2 = TranslationCache()
    r2 = run_plan(lambda env: triad(), v, _plan(256, 512), cache=c2,
                  journal=str(jpath))
    assert r2.replayed == 2
    assert c2.stats()["compile_misses"] == 0  # nothing re-staged
    assert [a.record.json() for a in r1.rows] == \
           [b.record.json() for b in r2.rows]
    assert [a.point.label for a in r1.rows] == \
           [b.point.label for b in r2.rows]


def test_journal_crash_resume_completes_remainder(tmp_path):
    """Kill a journaled sweep mid-run (simulated: truncate the journal
    to its first completed point), re-invoke, and only the remainder
    executes — the replayed row stays byte-identical."""
    jpath = tmp_path / "run.jsonl"
    v = [VariantSpec("t", CFG)]
    full = run_plan(lambda env: triad(), v, _plan(256, 512, 1024),
                    cache=TranslationCache(), journal=str(jpath))
    lines = jpath.read_text().splitlines()
    assert len(lines) == 3
    jpath.write_text(lines[0] + "\n")        # "crash" after point one
    c2 = TranslationCache()
    resumed = run_plan(lambda env: triad(), v, _plan(256, 512, 1024),
                       cache=c2, journal=str(jpath))
    assert resumed.replayed == 1
    assert len(resumed.rows) == 3
    assert c2.stats()["compile_misses"] > 0   # the remainder really ran
    assert resumed.rows[0].record.json() == full.rows[0].record.json()
    assert [r.point.label for r in resumed.rows] == ["n256", "n512",
                                                     "n1024"]
    # and the journal is complete again: a third invocation is all replay
    r3 = run_plan(lambda env: triad(), v, _plan(256, 512, 1024),
                  cache=TranslationCache(), journal=str(jpath))
    assert r3.replayed == 3


def test_journal_tolerates_torn_tail_line(tmp_path):
    jpath = tmp_path / "run.jsonl"
    v = [VariantSpec("t", CFG)]
    run_plan(lambda env: triad(), v, _plan(256, 512),
             cache=TranslationCache(), journal=str(jpath))
    lines = jpath.read_text().splitlines()
    jpath.write_text(lines[0] + "\n" + lines[1][: len(lines[1]) // 2])
    resumed = run_plan(lambda env: triad(), v, _plan(256, 512),
                       cache=TranslationCache(), journal=str(jpath))
    assert resumed.replayed == 1 and len(resumed.rows) == 2


def test_journal_replays_failures_too(tmp_path):
    jpath = tmp_path / "run.jsonl"
    plan = SweepPlan.product(pattern_axis("stride", (2, 13)),
                             env_axis((256,)))
    v = [VariantSpec("g", CFG)]
    r1 = run_plan(_poisoned_factory, v, plan, cache=TranslationCache(),
                  journal=str(jpath))
    assert len(r1.rows) == 1 and len(r1.failures) == 1
    r2 = run_plan(_poisoned_factory, v, plan, cache=TranslationCache(),
                  journal=str(jpath))
    assert r2.replayed == 2          # the failure replays as completed too
    assert len(r2.rows) == 1 and len(r2.failures) == 1
    assert r2.failures[0].label == "stride13/n256"


def test_journal_key_distinguishes_configs(tmp_path):
    jpath = tmp_path / "run.jsonl"
    v1 = [VariantSpec("t", CFG)]
    run_plan(lambda env: triad(), v1, _plan(256),
             cache=TranslationCache(), journal=str(jpath))
    # same variant label, different config -> different key -> re-runs
    v2 = [VariantSpec("t", dataclasses.replace(CFG, ntimes=4))]
    r = run_plan(lambda env: triad(), v2, _plan(256),
                 cache=TranslationCache(), journal=str(jpath))
    assert r.replayed == 0 and len(r.rows) == 1


def test_narrowed_parametric_viability_probe_still_specializes():
    """The narrowed except in _parametric_viable keeps demoting expected
    probe failures (custom kernels, env-dependent structure) to the
    specialized path rather than crashing."""
    from repro.core import pointer_chase

    cfg = dataclasses.replace(CFG, programs=1, parametric="auto")
    d = Driver(lambda env: pointer_chase(), cfg, cache=TranslationCache())
    recs = d.run([128, 256])
    assert [r.extra["param_path"] for r in recs] == ["specialized"] * 2


# ---------------------------------------------------------------------------
# PR-8: concurrent journal writes + threadpool crash-resume + collectives
# ---------------------------------------------------------------------------


def test_concurrent_journal_appends_no_torn_lines(tmp_path):
    """Many threads append rows at once (the ThreadPoolBackend writer
    pattern): every line in the file must parse as a whole JSON entry
    and every key must land in the in-memory map."""
    import threading
    import types

    from repro.core.measure import Record

    jpath = tmp_path / "j.jsonl"
    jr = RunJournal(jpath)
    n_threads, per_thread = 8, 25
    barrier = threading.Barrier(n_threads)

    def rec(i):
        return Record("triad", "unified", "identity", "jax", 256, 3072, 1,
                      2, 1e-6, 1.0, 1.0,
                      extra={"payload": "x" * 512, "i": i})

    def worker(t):
        barrier.wait()
        for i in range(per_thread):
            key = f"{t:02d}-{i:04d}"
            pt = types.SimpleNamespace(label=f"n{t}/{i}")
            jr.append_row(key, "v", pt, rec(i))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    lines = jpath.read_text().splitlines()
    assert len(lines) == n_threads * per_thread
    keys = set()
    for line in lines:
        e = json.loads(line)  # a torn line would raise here
        assert e["kind"] == "row" and len(e["record"]["extra"]["payload"]) == 512
        keys.add(e["key"])
    assert len(keys) == n_threads * per_thread
    assert len(jr) == n_threads * per_thread
    # a fresh load sees the identical entry set
    assert len(RunJournal(jpath)) == n_threads * per_thread


def test_threadpool_crash_resume_byte_identical(tmp_path):
    """Journaled run under ThreadPoolBackend, 'crash' (truncate), resume
    under ThreadPoolBackend: replayed rows byte-identical to the
    original run, remainder re-executes, final row order = plan order."""
    from repro.suite import ThreadPoolBackend

    jpath = tmp_path / "run.jsonl"
    v = [VariantSpec("t", CFG)]
    plan = SweepPlan.product(env_axis((256, 512, 1024)))
    full = run_plan(lambda env: triad(), v, plan, cache=TranslationCache(),
                    journal=str(jpath), backend=ThreadPoolBackend(3))
    assert len(full.rows) == 3
    lines = jpath.read_text().splitlines()
    assert len(lines) == 3
    jpath.write_text(lines[0] + "\n")        # crash after one entry
    c2 = TranslationCache()
    resumed = run_plan(lambda env: triad(), v, plan, cache=c2,
                       journal=str(jpath), backend=ThreadPoolBackend(3))
    assert resumed.replayed == 1
    assert len(resumed.rows) == 3
    assert c2.stats()["compile_misses"] > 0   # the remainder really ran
    assert [r.point.label for r in resumed.rows] == ["n256", "n512",
                                                     "n1024"]
    replayed_label = json.loads(lines[0])["label"]
    (orig,) = [r for r in full.rows if r.point.label == replayed_label]
    (rep,) = [r for r in resumed.rows if r.point.label == replayed_label]
    assert orig.record.json() == rep.record.json()
    # the journal is whole again: a serial re-run is all replay
    r3 = run_plan(lambda env: triad(), v, plan, cache=TranslationCache(),
                  journal=str(jpath))
    assert r3.replayed == 3


def test_collective_wire_byte_formulas():
    from repro.suite import expected_wire_bytes

    # all_gather over k devices: (k-1)/k of the gathered k*S*4 bytes
    assert expected_wire_bytes("all_gather", 1024, 8) == 7 / 8 * 8 * 1024 * 4
    # all_reduce: reduce-scatter + all-gather = 2(k-1)/k of S*4 bytes
    assert expected_wire_bytes("all_reduce", 1024, 8) == 2 * 7 / 8 * 1024 * 4
    # degenerate 1-device mesh: no wire traffic at all
    assert expected_wire_bytes("all_gather", 1024, 1) == 0
    assert expected_wire_bytes("all_reduce", 1024, 1) == 0
    with pytest.raises(ValueError, match="unknown collective"):
        expected_wire_bytes("all_to_all", 1024, 8)


def test_collective_ladder_skips_on_single_device(capsys):
    """On a 1-device box (the default test process) the ladder measures
    nothing and the runner emits the skip comment."""
    import jax

    from repro.suite import collective_runner, measure_collectives

    if len(jax.devices()) != 1:  # pragma: no cover - forced-device env
        pytest.skip("multi-device environment")
    assert measure_collectives(quick=True) == []
    lines = collective_runner(quick=True)
    assert len(lines) == 1 and lines[0].startswith("# collective ladder skipped")


@pytest.mark.slow
def test_collective_ladder_agreement_on_forced_mesh(tmp_path):
    """Ring accounting and analyze_collectives must agree within 10% on
    a forced 8-device host mesh (subprocess: device count is fixed at
    jax import)."""
    import os
    import subprocess
    import sys

    code = (
        "import os, json\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        "from repro.suite import measure_collectives\n"
        "print(json.dumps(measure_collectives(quick=True)))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
        + [str(__import__("pathlib").Path(__file__).resolve().parents[1]
               / "src")])
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    assert {r["op"] for r in rows} == {"all_gather", "all_reduce"}
    assert all(r["devices"] == 8 for r in rows)
    for r in rows:
        assert abs(r["agreement"] - 1.0) <= 0.10, r
        assert r["gbs"] > 0 and r["seconds"] > 0
