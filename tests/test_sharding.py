"""Partition-rule tests against an abstract production mesh (no devices)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.config import SHAPES, get_config
from repro.models import lm
from repro.models.moe import Parallelism
from repro.optim import adafactor, adamw
from repro.runtime.sharding import (
    auto_parallelism, batch_axes_for, batch_specs, cache_specs, param_count,
    param_specs,
)


def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(sizes, names)  # jax >= 0.5 (axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))  # 0.4.x shape_tuple


def mesh2d():
    return _abstract_mesh((16, 16), ("data", "model"))


def mesh3d():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def par_for(mesh, fsdp=(), ep=("model",)):
    return Parallelism(mesh=mesh, dp_axes=("data",), tp_axis="model",
                       ep_axes=ep, fsdp_axes=fsdp,
                       pod_axis="pod" if "pod" in mesh.axis_names else None)


def _flat(tree):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


def test_dense_param_specs_column_row():
    cfg = get_config("internlm2-1.8b")
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_specs(shapes, par_for(mesh2d()))
    flat = {tuple(str(getattr(e, "key", e)) for e in p): s
            for p, s in _flat(specs)}
    assert flat[("emb",)] == P("model", None)
    assert flat[("layers", "attn", "w_q")] == P(None, None, "model")
    assert flat[("layers", "attn", "w_o")] == P(None, "model", None)
    assert flat[("layers", "mlp", "w_gate")] == P(None, None, "model")
    assert flat[("layers", "ln1")] == P()


def test_moe_expert_specs_and_fsdp():
    cfg = get_config("deepseek-v2-lite-16b")
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    par = par_for(mesh2d(), fsdp=("data",))
    specs = param_specs(shapes, par)
    flat = {tuple(str(getattr(e, "key", e)) for e in p): s
            for p, s in _flat(specs)}
    assert flat[("layers", "moe", "w_gate_e")] == P(
        None, ("model",), ("data",), None)
    assert flat[("layers", "moe", "w_out_e")] == P(
        None, ("model",), None, ("data",))
    # router stays replicated (f32, tiny, feeds global top-k)
    assert flat[("layers", "moe", "router")] == P()


def test_nondivisible_dims_degrade_to_replicated():
    cfg = get_config("xlstm-1.3b")  # w_if out dim = 2*heads = 8 < 16
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_specs(shapes, par_for(mesh2d()))
    flat = {tuple(str(getattr(e, "key", e)) for e in p): s
            for p, s in _flat(specs)}
    key = ("groups", "mlstm", "blk", "w_if")
    assert flat[key][-1] is None  # 8 % 16 != 0 -> dropped, not an error


def test_adafactor_row_col_specs_follow_parent():
    cfg = get_config("internlm2-1.8b")
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    opt = adafactor()
    ostate = jax.eval_shape(opt.init, shapes)
    specs = param_specs(ostate, par_for(mesh2d(), fsdp=("data",)))
    flat = {tuple(str(getattr(e, "key", e)) for e in p): s
            for p, s in _flat(specs)}
    # w_q param spec is (None, fsdp, tp); row drops the last dim
    assert flat[("v", "layers", "attn", "w_q", "row")] == P(None, ("data",))
    assert flat[("v", "layers", "attn", "w_q", "col")] == P(None, "model")


def test_auto_parallelism_policies():
    # small-model training: TP off, model axis joins DP, ZeRO over data
    small = auto_parallelism(get_config("internlm2-1.8b"), mesh2d(),
                             SHAPES["train_4k"])
    assert small.tp_axis is None
    assert small.dp_axes == ("data", "model")
    assert small.fsdp_axes == ("data",)
    # small-model serving keeps TP for latency + weight residency
    small_serve = auto_parallelism(get_config("internlm2-1.8b"), mesh2d(),
                                   SHAPES["decode_32k"])
    assert small_serve.tp_axis == "model"
    big = auto_parallelism(get_config("mistral-large-123b"), mesh2d(),
                           SHAPES["train_4k"])
    assert big.tp_axis == "model"
    assert big.fsdp_axes == ("data",)
    kimi = auto_parallelism(get_config("kimi-k2-1t-a32b"), mesh3d(),
                            SHAPES["train_4k"])
    assert "pod" in kimi.ep_axes
    assert all(a not in kimi.ep_axes for a in kimi.fsdp_axes)


def test_batch_axes_divisibility():
    par = par_for(mesh3d())
    assert batch_axes_for(par, 256) == ("pod", "data")
    assert batch_axes_for(par, 2) == ("pod",)
    assert batch_axes_for(par, 1) == ()


def test_cache_specs_head_dim_fallback():
    cfg = get_config("mistral-large-123b")  # kv=8 < tp=16
    par = par_for(mesh2d())
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 128, 1024))
    specs = cache_specs(cache, par, cfg, 128)
    assert specs["k"] == P(None, ("data",), None, None, "model")


def test_cache_specs_context_parallel_for_b1():
    cfg = get_config("gemma3-27b")
    par = par_for(mesh2d())
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 1, 4096))
    specs = cache_specs(cache, par, cfg, 1)
    # batch unshardable -> S over data, heads over model
    assert specs["k"] == P(None, None, "data", "model", None)


def test_param_count_known_scale():
    n = param_count(get_config("internlm2-1.8b"))
    assert 1.5e9 < n < 2.3e9
    n_kimi = param_count(get_config("kimi-k2-1t-a32b"))
    assert 0.9e12 < n_kimi < 1.2e12
