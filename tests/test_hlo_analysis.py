"""Unit tests for the HLO collective parser (roofline input)."""
from __future__ import annotations

import textwrap

from repro.launch.hlo_analysis import (
    CollectiveStats, _shape_bytes, analyze_collectives,
    analyze_memory_ops, parse_computations,
)

HLO = textwrap.dedent("""
    HloModule jit_step

    %region_add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %add = f32[] add(f32[] %a, f32[] %b)
    }

    %body.1 (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
      %arg = (s32[], f32[128,256]) parameter(0)
      %i = s32[] get-tuple-element((s32[], f32[128,256]) %arg), index=0
      %x = f32[128,256] get-tuple-element((s32[], f32[128,256]) %arg), index=1
      %ar = f32[128,256] all-reduce(f32[128,256] %x), replica_groups={{0,1,2,3}}, to_apply=%region_add
      %one = s32[] constant(1)
      %ni = s32[] add(s32[] %i, s32[] %one)
      ROOT %t = (s32[], f32[128,256]) tuple(s32[] %ni, f32[128,256] %ar)
    }

    %cond.1 (arg: (s32[], f32[128,256])) -> pred[] {
      %arg = (s32[], f32[128,256]) parameter(0)
      %i = s32[] get-tuple-element((s32[], f32[128,256]) %arg), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
    }

    ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
      %p0 = f32[128,256] parameter(0)
      %ag = f32[512,256] all-gather(f32[128,256] %p0), replica_groups=[4,4]<=[16], dimensions={0}
      %rs = f32[32,256] reduce-scatter(f32[128,256] %p0), replica_groups={{0,1,2,3}}, to_apply=%region_add
      %zero = s32[] constant(0)
      %init = (s32[], f32[128,256]) tuple(s32[] %zero, f32[128,256] %p0)
      %w = (s32[], f32[128,256]) while((s32[], f32[128,256]) %init), condition=%cond.1, body=%body.1
      ROOT %out = f32[128,256] get-tuple-element((s32[], f32[128,256]) %w), index=1
    }
""")


def test_parse_computations_splits():
    comps = parse_computations(HLO)
    assert any("body" in c for c in comps)
    assert any("main" in c or "entry" in c.lower() for c in comps)


def test_collectives_counts_and_trip_correction():
    stats = analyze_collectives(HLO)
    # all-gather: result 512*256*4 bytes, k=4 -> (k-1)/k factor
    ag = 512 * 256 * 4 * 3 / 4
    assert abs(stats.bytes_by_kind["all-gather"] - ag) < 1
    # reduce-scatter: result 32*256*4, (k-1) factor with k=4
    rs = 32 * 256 * 4 * 3
    assert abs(stats.bytes_by_kind["reduce-scatter"] - rs) < 1
    # all-reduce inside while body x10 trip count, 2(k-1)/k with k=4
    ar = 128 * 256 * 4 * 1.5 * 10
    assert abs(stats.bytes_by_kind["all-reduce"] - ar) < 1
    assert stats.count_by_kind["all-reduce"] == 10


def test_total_bytes_positive():
    stats = analyze_collectives(HLO)
    assert stats.total_bytes > 0
    assert isinstance(stats, CollectiveStats)
    assert stats.unknown_dtypes == ()


# -- regression: attribute-trailing computation headers ---------------------
# Newer jaxlib emits headers whose opening line carries attributes after
# the `{` (so the line no longer *ends* with it); the splitter must be
# brace-depth driven, not endswith-driven.

HLO_TRAILING = textwrap.dedent("""
    HloModule jit_step

    %helper.1 (a: f32[8]{0}) -> f32[8]{0} { // scheduled
      %a = f32[8]{0} parameter(0)
      ROOT %m = f32[8]{0} multiply(f32[8]{0} %a, f32[8]{0} %a)
    }

    ENTRY %main.2 (p0: f32[8]{0}) -> f32[8]{0}, execution_thread="main" {
      %p0 = f32[8]{0} parameter(0)
      %ag = f32[32]{0} all-gather(f32[8]{0} %p0), replica_groups={{0,1,2,3}}, dimensions={0}
      ROOT %c = f32[8]{0} call(f32[8]{0} %p0), to_apply=%helper.1
    }
""")


def test_parse_computations_attribute_trailing_headers():
    comps = parse_computations(HLO_TRAILING)
    assert any("main" in c for c in comps), comps.keys()
    assert any("helper" in c for c in comps), comps.keys()
    stats = analyze_collectives(HLO_TRAILING)
    assert abs(stats.bytes_by_kind["all-gather"] - 32 * 4 * 3 / 4) < 1


# -- regression: unknown dtypes surface structurally, never count as 0 ------

def test_shape_bytes_unknown_dtype_marker():
    sb = _shape_bytes("c64[16,16]")
    assert sb.nbytes == 0 and sb.unknown == ("c64",)
    sb = _shape_bytes("(f32[8], c128[4])")
    assert sb.nbytes == 8 * 4 and sb.unknown == ("c128",)


HLO_UNKNOWN = textwrap.dedent("""
    HloModule jit_step

    ENTRY %main (p0: c64[64]) -> c64[64] {
      %p0 = c64[64] parameter(0)
      ROOT %ar = c64[64] all-reduce(c64[64] %p0), replica_groups={{0,1}}
    }
""")


def test_collectives_unknown_dtype_marker():
    stats = analyze_collectives(HLO_UNKNOWN)
    assert "c64" in stats.unknown_dtypes
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.bytes_by_kind["all-reduce"] == 0.0


# -- regression: async -start pairs count once, result element only --------

HLO_ASYNC = textwrap.dedent("""
    HloModule jit_step

    ENTRY %main (p0: f32[128]) -> f32[512] {
      %p0 = f32[128] parameter(0)
      %ags = (f32[128], f32[512], u32[], u32[]) all-gather-start(f32[128] %p0), replica_groups={{0,1,2,3}}, dimensions={0}
      ROOT %agd = f32[512] all-gather-done((f32[128], f32[512], u32[], u32[]) %ags)
    }
""")


def test_async_start_counts_result_once():
    stats = analyze_collectives(HLO_ASYNC)
    # exactly one all-gather, costed on the 512-element *result* element
    # of the -start tuple (not operand+result+contexts, not the -done)
    assert stats.count_by_kind["all-gather"] == 1
    assert abs(stats.bytes_by_kind["all-gather"] - 512 * 4 * 3 / 4) < 1
    assert stats.unknown_dtypes == ()


# -- analyze_memory_ops: trip-weighted per-op traffic ----------------------

def test_analyze_memory_ops_trip_weighting():
    ops = analyze_memory_ops(HLO)
    # the while-body all-reduce runs 10 times; its result is 128*256 f32
    assert ops["all-reduce"].count == 10
    assert abs(ops["all-reduce"].result_bytes - 10 * 128 * 256 * 4) < 1
    # entry-level ops run once; bookkeeping opcodes are excluded
    assert ops["all-gather"].count == 1
    assert "parameter" not in ops and "get-tuple-element" not in ops
    # the async pair contributes one op, result bytes only
    a = analyze_memory_ops(HLO_ASYNC)
    assert a["all-gather"].count == 1
    assert a["all-gather"].result_bytes == 512 * 4
