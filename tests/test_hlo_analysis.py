"""Unit tests for the HLO collective parser (roofline input)."""
from __future__ import annotations

import textwrap

from repro.launch.hlo_analysis import (
    CollectiveStats, analyze_collectives, parse_computations,
)

HLO = textwrap.dedent("""
    HloModule jit_step

    %region_add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %add = f32[] add(f32[] %a, f32[] %b)
    }

    %body.1 (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
      %arg = (s32[], f32[128,256]) parameter(0)
      %i = s32[] get-tuple-element((s32[], f32[128,256]) %arg), index=0
      %x = f32[128,256] get-tuple-element((s32[], f32[128,256]) %arg), index=1
      %ar = f32[128,256] all-reduce(f32[128,256] %x), replica_groups={{0,1,2,3}}, to_apply=%region_add
      %one = s32[] constant(1)
      %ni = s32[] add(s32[] %i, s32[] %one)
      ROOT %t = (s32[], f32[128,256]) tuple(s32[] %ni, f32[128,256] %ar)
    }

    %cond.1 (arg: (s32[], f32[128,256])) -> pred[] {
      %arg = (s32[], f32[128,256]) parameter(0)
      %i = s32[] get-tuple-element((s32[], f32[128,256]) %arg), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
    }

    ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
      %p0 = f32[128,256] parameter(0)
      %ag = f32[512,256] all-gather(f32[128,256] %p0), replica_groups=[4,4]<=[16], dimensions={0}
      %rs = f32[32,256] reduce-scatter(f32[128,256] %p0), replica_groups={{0,1,2,3}}, to_apply=%region_add
      %zero = s32[] constant(0)
      %init = (s32[], f32[128,256]) tuple(s32[] %zero, f32[128,256] %p0)
      %w = (s32[], f32[128,256]) while((s32[], f32[128,256]) %init), condition=%cond.1, body=%body.1
      ROOT %out = f32[128,256] get-tuple-element((s32[], f32[128,256]) %w), index=1
    }
""")


def test_parse_computations_splits():
    comps = parse_computations(HLO)
    assert any("body" in c for c in comps)
    assert any("main" in c or "entry" in c.lower() for c in comps)


def test_collectives_counts_and_trip_correction():
    stats = analyze_collectives(HLO)
    # all-gather: result 512*256*4 bytes, k=4 -> (k-1)/k factor
    ag = 512 * 256 * 4 * 3 / 4
    assert abs(stats.bytes_by_kind["all-gather"] - ag) < 1
    # reduce-scatter: result 32*256*4, (k-1) factor with k=4
    rs = 32 * 256 * 4 * 3
    assert abs(stats.bytes_by_kind["reduce-scatter"] - rs) < 1
    # all-reduce inside while body x10 trip count, 2(k-1)/k with k=4
    ar = 128 * 256 * 4 * 1.5 * 10
    assert abs(stats.bytes_by_kind["all-reduce"] - ar) < 1
    assert stats.count_by_kind["all-reduce"] == 10


def test_total_bytes_positive():
    stats = analyze_collectives(HLO)
    assert stats.total_bytes > 0
    assert isinstance(stats, CollectiveStats)
