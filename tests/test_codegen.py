"""Backend equivalence: vectorized-JAX and Pallas vs the serial oracle.

The paper's validation stage (<kernel>_val.in) replayed for every
(pattern x schedule x backend) combination, including multi-sweep runs
(stencils are not idempotent, so ntimes>1 catches read/write aliasing
bugs the single-sweep check would miss).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    identity, jacobi1d, jacobi2d, jacobi3d, lower_jax, lower_pallas,
    nstream, serial_oracle, stream_copy, stream_scale, stream_sum, triad,
)
from repro.core.pattern import jacobi2d9

TOL = dict(rtol=2e-5, atol=2e-5)


def _run_backend(step, arrays, ntimes=2):
    got = {k: jnp.asarray(v) for k, v in arrays.items()}
    for _ in range(ntimes):
        got = step(got)
    return got


def _check(pattern, schedule, env, *, backends=("jax", "pallas"),
           grid_bands=None, ntimes=2):
    arrays = pattern.allocate(env)
    nest = schedule.lower(pattern.domain, env)
    want = serial_oracle(pattern, nest, arrays, env, ntimes=ntimes)
    for be in backends:
        if be == "jax":
            step = lower_jax(pattern, schedule, env)
        else:
            step = lower_pallas(pattern, schedule, env, grid_bands=grid_bands)
        got = _run_backend(step, arrays, ntimes)
        for k in want:
            np.testing.assert_allclose(
                np.asarray(got[k], np.float32), want[k].astype(np.float32),
                err_msg=f"{pattern.name}/{schedule.name}/{be}/{k}", **TOL,
            )


@pytest.mark.parametrize("factory", [triad, stream_copy, stream_scale,
                                     stream_sum, lambda: nstream(5)])
def test_stream_identity(factory):
    pat = factory()
    _check(pat, identity().tile("i", 16), {"n": 64}, grid_bands=("i_T",))


@pytest.mark.parametrize("factor", [2, 4])
def test_triad_interleave(factor):
    _check(triad(), identity().interleave("i", factor).tile("i", 8),
           {"n": 64}, grid_bands=("i_T",))


def test_triad_unroll_reverse():
    _check(triad(), identity().unroll("i", 2), {"n": 64},
           backends=("jax",))
    _check(triad(), identity().reverse("i"), {"n": 64}, backends=("jax",))


def test_jacobi1d_tiled():
    _check(jacobi1d(), identity().tile("i", 16), {"n": 66},
           grid_bands=("i_T",))


def test_jacobi2d_tiled_2d():
    sch = identity().tile("i", 8).tile("j", 16)
    _check(jacobi2d(), sch, {"n": 34}, grid_bands=("i_T", "j_T"))


def test_jacobi2d9_box():
    sch = identity().tile("i", 8).tile("j", 8)
    _check(jacobi2d9(), sch, {"n": 18}, grid_bands=("i_T", "j_T"))


def test_jacobi3d_partial_blocking():
    # paper's partial blocking: tile the two least-significant dims only
    sch = identity().tile("j", 8).tile("k", 8)
    _check(jacobi3d(), sch, {"n": 18}, grid_bands=("j_T", "k_T"))


def test_jacobi3d_xyz_blocking():
    sch = identity().tile("i", 8).tile("j", 8).tile("k", 8)
    _check(jacobi3d(), sch, {"n": 18}, grid_bands=("i_T", "j_T", "k_T"))


def test_interchange_is_noop_on_result():
    _check(jacobi2d(), identity().interchange("i", "j"), {"n": 18},
           backends=("jax",))


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([16, 32, 64]), st.sampled_from([1, 2, 4]),
       st.booleans())
def test_property_triad_schedules(n, factor, rev):
    sch = identity().interleave("i", factor)
    if rev:
        sch = sch.reverse("i")
    _check(triad(), sch, {"n": n}, backends=("jax",))


def test_gather_path_matches_fast_path():
    pat = triad()
    env = {"n": 64}
    sch = identity().interleave("i", 2)
    fast = lower_jax(pat, sch, env)
    gather = lower_jax(pat, sch, env, force_gather=True)
    arrays = {k: jnp.asarray(v) for k, v in pat.allocate(env).items()}
    a = fast(dict(arrays))["A"]
    b = gather(dict(arrays))["A"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
