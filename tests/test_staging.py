"""Translation-cache + staged-pipeline tests.

Covers the PR-1 acceptance contract: hit/miss accounting across repeated
``Driver.run`` working sets, invalidation when env / schedule / template
change, cached-vs-cold output equivalence, compile-time reporting, the
vectorized oracle fast path, and once-per-variant validation.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    Driver, DriverConfig, TranslationCache, Variant, identity, jacobi1d,
    jacobi2d, jacobi3d, serial_oracle, stage_lower, sweep, triad,
)
from repro.core import drivers as drivers_mod

WS = [256, 512, 1024]  # three working sets, per the acceptance criteria


def _cfg(**kw):
    base = dict(template="unified", programs=4, ntimes=2, reps=1,
                validate_n=64)
    base.update(kw)
    return DriverConfig(**base)


# ---------------------------------------------------------------------------
# hit/miss accounting
# ---------------------------------------------------------------------------


def test_repeated_runs_hit_cache_across_working_sets():
    cache = TranslationCache()
    d = Driver(lambda env: triad(), _cfg(), cache=cache)

    d.run(WS)
    s1 = cache.stats()
    assert s1["lower_misses"] == len(WS)
    assert s1["compile_misses"] == len(WS)
    assert s1["lower_hits"] == 0 and s1["compile_hits"] == 0

    d.run(WS)  # identical tuples: nothing may lower or compile again
    s2 = cache.stats()
    assert s2["lower_misses"] == len(WS)
    assert s2["compile_misses"] == len(WS)
    assert s2["lower_hits"] >= len(WS)
    assert s2["compile_hits"] >= len(WS)
    assert s2["hit_rate"] > 0


def test_fresh_driver_same_structure_still_hits():
    """Factories rebuild PatternSpec objects per call; the structural
    fingerprint must identify them anyway."""
    cache = TranslationCache()
    Driver(lambda env: triad(), _cfg(), cache=cache).run([512])
    Driver(lambda env: triad(), _cfg(), cache=cache).run([512])
    s = cache.stats()
    assert s["lower_misses"] == 1 and s["lower_hits"] == 1
    assert s["compile_misses"] == 1 and s["compile_hits"] == 1


def test_cache_keys_invalidate_on_config_changes():
    cache = TranslationCache()
    Driver(lambda env: triad(), _cfg(), cache=cache).run([512])
    base = cache.stats()["lower_misses"]

    # different env (working set)
    Driver(lambda env: triad(), _cfg(), cache=cache).run([513 - 1 + 256])
    assert cache.stats()["lower_misses"] == base + 1

    # different schedule
    Driver(lambda env: triad(),
           _cfg(schedule=identity().interleave("i", 2)),
           cache=cache).run([512])
    assert cache.stats()["lower_misses"] == base + 2

    # different template
    Driver(lambda env: triad(), _cfg(template="independent"),
           cache=cache).run([512])
    assert cache.stats()["lower_misses"] == base + 3

    # different pattern constants (combine closure) must not collide
    Driver(lambda env: triad(scalar=2.0), _cfg(), cache=cache).run([512])
    assert cache.stats()["lower_misses"] == base + 4


def test_ntimes_change_recompiles_but_shares_lowering():
    cache = TranslationCache()
    d1 = Driver(lambda env: triad(), _cfg(ntimes=2), cache=cache)
    d2 = Driver(lambda env: triad(), _cfg(ntimes=4), cache=cache)
    d1.run([512])
    d2.run([512])
    s = cache.stats()
    assert s["lower_misses"] == 1 and s["lower_hits"] >= 1
    assert s["compile_misses"] == 2


# ---------------------------------------------------------------------------
# cached-vs-cold equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("template", ["unified", "independent"])
def test_cached_output_equals_cold_output(template):
    cold_cache, warm_cache = TranslationCache(), TranslationCache()
    mk = lambda c: Driver(lambda env: triad(), _cfg(template=template),
                          cache=c)
    _, _, env, compiled_cold, tup, names = mk(cold_cache).build({"n": 512})

    warm = mk(warm_cache)
    warm.build({"n": 512})                       # prime
    _, _, _, compiled_warm, tup2, _ = warm.build({"n": 512})
    assert compiled_warm.from_cache

    out_cold = compiled_cold(tup)
    out_warm = compiled_warm(tup2)
    for a, b in zip(out_cold, out_warm):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_record_fields_and_compile_time_reporting():
    cache = TranslationCache()
    d = Driver(lambda env: triad(), _cfg(), cache=cache)
    (rec,) = d.run([512])
    assert rec.gbs > 0 and rec.seconds > 0
    assert rec.extra["barrier"] is False
    assert rec.extra["compile_seconds"] >= 0
    assert rec.extra["lower_seconds"] >= 0
    assert rec.extra["cache_hit"] is False
    (rec2,) = d.run([512])
    assert rec2.extra["cache_hit"] is True
    # cached replay preserves the record identity fields
    for f in ("pattern", "template", "schedule", "backend", "n",
              "working_set_bytes", "programs", "ntimes", "level"):
        assert getattr(rec2, f) == getattr(rec, f)


# ---------------------------------------------------------------------------
# validation memo + sweep sharing
# ---------------------------------------------------------------------------


def test_validate_runs_once_per_variant(monkeypatch):
    calls = []
    real = drivers_mod.serial_oracle

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(drivers_mod, "serial_oracle", spy)
    cache = TranslationCache()
    d = Driver(lambda env: triad(), _cfg(), cache=cache)
    d.validate()
    d.validate()
    Driver(lambda env: triad(), _cfg(), cache=cache).validate()
    assert len(calls) == 1


def test_sweep_shares_cache_and_reports_stats():
    cache = TranslationCache()
    variants = [
        Variant("a", _cfg(template="independent", programs=2)),
        Variant("b", _cfg(template="independent", programs=2,
                          schedule=identity().interleave("i", 2))),
    ]
    res = sweep(lambda env: triad(), variants, [256, 512], cache=cache)
    assert res.best[0] in ("a", "b")
    assert res.cache_stats is not None
    assert res.cache_stats["lower_misses"] >= 4
    # sweeping again is pure cache hits for lowering + compilation
    res2 = sweep(lambda env: triad(), variants, [256, 512], cache=cache)
    assert res2.cache_stats["lower_misses"] == res.cache_stats["lower_misses"]
    assert res2.cache_stats["compile_misses"] == res.cache_stats["compile_misses"]
    assert res2.cache_stats["compile_hits"] > res.cache_stats["compile_hits"]


# ---------------------------------------------------------------------------
# staged artifacts directly
# ---------------------------------------------------------------------------


def test_stage_lower_pallas_keyed_separately():
    cache = TranslationCache()
    pat = triad()
    env = {"n": 256}
    stage_lower(pat, identity(), env, "jax", cache=cache)
    stage_lower(pat, identity(), env, "pallas", cache=cache)
    stage_lower(pat, identity(), env, "jax", cache=cache)
    s = cache.stats()
    assert s["lower_misses"] == 2 and s["lower_hits"] == 1


# ---------------------------------------------------------------------------
# vectorized oracle fast path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("factory,sch,env", [
    # vectorized fast path (single band per dim, write space never read)
    (triad, identity(), {"n": 64}),
    (triad, identity().interleave("i", 2), {"n": 64}),
    (triad, identity().reverse("i"), {"n": 64}),
    (jacobi1d, identity(), {"n": 66}),
    (jacobi2d, identity().interchange("i", "j"), {"n": 18}),
    (jacobi3d, identity(), {"n": 10}),
    # unified-template shape: programs split via outer tile, inner intact
    (triad, identity().tile("i", 16, outer="prog", inner="i"), {"n": 64}),
    # tiled nests fall back to the point loop; equality must still hold
    (jacobi1d, identity().tile("i", 16), {"n": 66}),
])
def test_vectorized_oracle_matches_point_loop(factory, sch, env):
    pat = factory()
    nest = sch.lower(pat.domain, env)
    arrays = pat.allocate(env)
    fast = serial_oracle(pat, nest, arrays, env, ntimes=2)
    slow = serial_oracle(pat, nest, arrays, env, ntimes=2, force_loop=True)
    for k in slow:
        np.testing.assert_allclose(fast[k], slow[k], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# donated specialized measurement executables (PR-5)
# ---------------------------------------------------------------------------


def test_prepare_donates_specialized_executables():
    """Measurement executables from ``prepare`` donate their buffers: a
    call consumes its input tuple (no working-set-sized copy survives to
    be observed), ``bind`` threads outputs into subsequent calls, and a
    foreign tuple mid-stream raises instead of being silently ignored."""
    import jax.numpy as jnp

    d = Driver(lambda env: triad(), _cfg(parametric=False),
               cache=TranslationCache())
    (p,) = d.prepare([512])
    assert p.compiled.donated and not p.parametric
    arrays = p.lowered.pattern.allocate(p.lowered.env)
    tup = tuple(jnp.asarray(arrays[k]) for k in p.compiled.names)
    fn = p.executable()
    out1 = fn(tup)
    out2 = fn(tup)          # timing loop re-passes the seed: threads out1
    assert all(o.shape == t.shape for o, t in zip(out2, out1))
    # the seed tuple's buffers were donated away on the first call
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(tup[0])
    # a brand-new tuple cannot join an existing donated stream
    fresh = tuple(jnp.asarray(v) for v in
                  (np.zeros(512, np.float32),) * len(tup))
    with pytest.raises(ValueError, match="threads its buffers"):
        fn(fresh)
    # and calling the raw executable with consumed buffers fails loudly
    with pytest.raises(Exception):
        p.compiled.run(tup)


def test_build_stays_undonated_and_recallable():
    """``Driver.build`` keeps the re-callable undonated compile (library
    callers replay tuples), and the donate flag is part of the cache
    key, so the two executables never collide."""
    cache = TranslationCache()
    d = Driver(lambda env: triad(), _cfg(parametric=False), cache=cache)
    _, _, _, compiled, tup, _ = d.build({"n": 512})
    assert compiled.donated is False
    a = compiled(tup)
    b = compiled(tup)       # same tuple twice: undonated must allow it
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    d.prepare([512])        # donated twin compiles separately
    assert cache.stats()["compile_misses"] == 2


def test_donated_records_match_undonated_records():
    """Donation must not change what is measured: records from the
    donated measurement path carry the same identity fields and values
    as a run through the undonated executable."""
    import jax.numpy as jnp

    cache = TranslationCache()
    d = Driver(lambda env: triad(), _cfg(parametric=False), cache=cache)
    (rec,) = d.run([1024])
    assert rec.extra["param_path"] == "specialized"
    assert rec.extra["donated"] is True
    # undonated twin executed by hand on the same arrays
    lw = d.lower({"n": 1024})
    c = lw.compile(ntimes=d.cfg.ntimes, donate=False, cache=cache)
    arrays = lw.pattern.allocate(lw.env)
    tup = tuple(jnp.asarray(arrays[k]) for k in c.names)
    out = c(tup)
    donated = d.prepare([1024])[0]
    arrays2 = donated.lowered.pattern.allocate(donated.lowered.env)
    tup2 = tuple(jnp.asarray(arrays2[k]) for k in donated.compiled.names)
    out2 = donated.executable()(tup2)
    for x, y in zip(out, out2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# concurrent cache access (PR-8 — the ThreadPoolBackend contract)
# ---------------------------------------------------------------------------


def test_concurrent_same_key_staging_builds_once():
    """Eight threads race the same lowering key on a fresh cache: the
    builder must run exactly once (the others block on the cache lock
    and hit), and the counters must account for every request."""
    import threading

    cache = TranslationCache()
    pat = triad()
    sch = identity()
    barrier = threading.Barrier(8)
    errors = []

    def worker():
        try:
            barrier.wait()
            stage_lower(pat, sch, {"n": 512}, cache=cache)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    s = cache.stats()
    assert s["lower_misses"] == 1
    assert s["lower_hits"] == 7


def test_wrapped_device_index_shares_cache_entry():
    """Device-axis indices that resolve (modulo the visible device
    count) to the same physical device must share one cache entry —
    a collapsed plan (dev0..devN on a smaller box) should not compile
    duplicate identical executables."""
    import jax

    cache = TranslationCache()
    pat = triad()
    sch = identity()
    ndev = len(jax.devices())
    a = stage_lower(pat, sch, {"n": 512}, device=0, cache=cache)
    b = stage_lower(pat, sch, {"n": 512}, device=ndev, cache=cache)
    assert a is b
    s = cache.stats()
    assert s["lower_misses"] == 1
    assert s["lower_hits"] == 1
    # an unpinned lowering stays a distinct entry (ambient default
    # device is not necessarily devices()[0] under default_device scopes)
    stage_lower(pat, sch, {"n": 512}, device=None, cache=cache)
    assert cache.stats()["lower_misses"] == 2


def test_concurrent_mixed_keys_eviction_counters_consistent():
    """Concurrent distinct-key traffic through a capacity-2 LRU: no
    torn counter updates — hits + misses equals the request count and
    evictions never exceeds insertions minus capacity."""
    import threading

    cache = TranslationCache(capacity=2)
    pat = triad()
    sch = identity()
    sizes = [256, 512, 1024, 2048]
    rounds = 4
    barrier = threading.Barrier(len(sizes))
    errors = []

    def worker(n):
        try:
            barrier.wait()
            for _ in range(rounds):
                stage_lower(pat, sch, {"n": n}, cache=cache)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(n,)) for n in sizes]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    s = cache.stats()
    requests = len(sizes) * rounds
    assert s["lower_hits"] + s["lower_misses"] == requests
    assert s["lower_misses"] >= len(sizes)  # every key missed at least once
    assert s["evictions"] >= s["lower_misses"] - 2  # capacity-2 LRU
    assert 0.0 <= s["hit_rate"] <= 1.0


def test_disk_counter_listener_updates_are_locked():
    """The jax disk-cache monitoring listener increments shared counters
    from compile threads; hammer it from many threads and demand no
    lost updates."""
    import threading

    from repro.core import staging as staging_mod

    before = staging_mod.disk_cache_stats()
    with staging_mod._disk_lock:
        pass  # the lock object exists and is a real lock

    def worker():
        for _ in range(1000):
            with staging_mod._disk_lock:
                staging_mod._disk_counters["hits"] += 1

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    after = staging_mod.disk_cache_stats()
    assert after["hits"] - before["hits"] == 8000
    with staging_mod._disk_lock:
        staging_mod._disk_counters["hits"] = before["hits"]
