"""Trace-driven Spatter replay + multi-pattern mixes: the conformance
layer for PR 10's trace subsystem.

Three test families:

* **Golden fixtures** — the committed JSON captures under
  ``tests/fixtures/spatter/`` parse to exactly the documented index
  semantics, land on the regime the affine detector promises, and
  replay **bit-exactly** against a direct numpy replay of the JSON.
  Malformed files are rejected with a typed :class:`SpatterParseError`
  (stable ``reason`` slug), never a stack trace from inside numpy.
* **Property tests** — random Spatter patterns (uniform / MS1 / index
  list, via hypothesis or the deterministic stub) round-trip through
  parse -> spec -> replay with index-trace and byte-count equality
  against an independent reconstruction from the raw JSON fields.
* **Mix accounting** — ``mix_patterns`` composes components into one
  executable whose records carry the per-pattern byte split, whose
  fingerprints are stable across factory rebuilds (journal/cache
  identity), and whose validation replays every component's oracle.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Driver,
    DriverConfig,
    TranslationCache,
    gather,
    identity,
    lower_jax,
    mix_patterns,
    mix_space,
    pointer_chase,
    triad,
)
from repro.core.domain import Affine, domain
from repro.core.staging import fingerprint_pattern
from repro.suite.spatter_io import (
    MAX_PATTERN_LEN,
    SpatterParseError,
    load_spatter,
    parse_spatter,
    replay_exact,
    trace_workload,
)

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "spatter"


# ---------------------------------------------------------------------------
# golden fixtures: parse -> exact index semantics -> regime placement
# ---------------------------------------------------------------------------

def test_uniform_fixture_parses_to_affine_strides():
    pats = load_spatter(FIXTURES / "uniform.json")
    assert [p.kernel for p in pats] == ["gather", "scatter"]
    assert all(p.form == "uniform" for p in pats)
    g, s = pats
    assert g.indices == tuple(4 * j for j in range(8))
    assert g.delta == 32            # seamless continuation: L * stride
    assert g.affine_stride == (4, 0)
    assert s.indices == tuple(2 * j for j in range(16))
    assert s.delta == 32            # explicit in the file
    assert s.affine_stride == (2, 0)
    # affine traces ride the ordinary strided regime: no custom kernel
    for p in pats:
        spec = p.pattern_spec()
        assert spec.kernel is None and spec.oracle is None
        assert spec.trace == p.trace_stamp


def test_ms1_fixture_parses_to_gap_jumps():
    pats = load_spatter(FIXTURES / "ms1.json")
    m16, m8 = pats
    # MS1:16:4,8,12:32 — stride-1 runs of 4, +32 jump at each break
    assert m16.indices == (0, 1, 2, 3, 35, 36, 37, 38,
                           70, 71, 72, 73, 105, 106, 107, 108)
    assert m16.delta == 109         # default: max index + 1
    assert m16.affine_stride is None
    # MS1:8:4:64 with explicit delta
    assert m8.indices == (0, 1, 2, 3, 67, 68, 69, 70)
    assert m8.delta == 128
    # value-dependent traces ride the bound-index kernel regime
    for p in pats:
        spec = p.pattern_spec()
        assert spec.kernel is not None and spec.oracle is not None
        assert {s.name for s in spec.spaces} == {"D", "S", "I"}


def test_index_list_fixture_round_trips_verbatim():
    pats = load_spatter(FIXTURES / "index_list.json")
    g, s = pats
    assert g.form == "index" and g.kernel == "gather"
    assert g.indices == (0, 8, 2, 8, 33, 1, 5, 13)
    assert g.delta == 34            # default: max index + 1
    assert s.kernel == "scatter" and s.delta == 16
    assert g.affine_stride is None and s.affine_stride is None


def test_fixture_patterns_replay_bit_exactly():
    """The acceptance property: every committed fixture pattern's spec
    moves exactly the bytes a direct numpy replay of the JSON moves."""
    for name in ("uniform.json", "ms1.json", "index_list.json"):
        for sp in load_spatter(FIXTURES / name):
            assert replay_exact(sp, n=256), (name, sp.entry)


def test_compiled_ms1_gather_is_bit_exact_against_numpy_replay():
    """End-to-end through the staged executable (not just the oracle):
    one compiled sweep of the MS1 gather equals S[trace] bit-for-bit —
    trace replay is pure data movement."""
    import jax.numpy as jnp

    sp = load_spatter(FIXTURES / "ms1.json")[0]
    spec = sp.pattern_spec()
    env = {"n": 512}
    arrays = spec.allocate(env)
    step = lower_jax(spec, identity(), env)
    out = step({k: jnp.asarray(v) for k, v in arrays.items()})
    want = np.asarray(arrays["S"])[sp.replay_indices(512)]
    assert np.array_equal(np.asarray(out["D"]), want)


# ---------------------------------------------------------------------------
# structured rejection: typed reasons, not stack traces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("text,reason", [
    ("{not json", "invalid_json"),
    ("42", "bad_entry"),
    ("[]", "empty_pattern"),
    ("[42]", "bad_entry"),
    ('[{"kernel": "Gather"}]', "bad_entry"),
    ('[{"kernel": "MultiScatter", "pattern": [1]}]', "unknown_kernel"),
    ('[{"pattern": "FANCY:8:1"}]', "bad_pattern"),
    ('[{"pattern": "UNIFORM:8"}]', "bad_pattern"),
    ('[{"pattern": "UNIFORM:8:x"}]', "bad_pattern"),
    ('[{"pattern": 3.5}]', "bad_pattern"),
    ('[{"pattern": [1, 2.5]}]', "bad_pattern"),
    ('[{"pattern": "UNIFORM:0:4"}]', "empty_pattern"),
    ('[{"pattern": []}]', "empty_pattern"),
    ('[{"pattern": "MS1:8:4"}]', "bad_ms1"),
    ('[{"pattern": "MS1:8:0:32"}]', "bad_ms1"),
    ('[{"pattern": "MS1:8:4,2:32"}]', "bad_ms1"),
    ('[{"pattern": "MS1:8:4,6:32,32,32"}]', "bad_ms1"),
    ('[{"pattern": "UNIFORM:4:-2"}]', "negative_index"),
    ('[{"pattern": [3, -1]}]', "negative_index"),
    ('[{"pattern": [1, 2], "delta": -4}]', "negative_index"),
    (f'[{{"pattern": "UNIFORM:{MAX_PATTERN_LEN + 1}:1"}}]', "oversized"),
])
def test_malformed_files_reject_with_typed_reason(text, reason):
    with pytest.raises(SpatterParseError) as ei:
        parse_spatter(text, source="inline")
    assert ei.value.reason == reason
    assert "inline" in str(ei.value)


def test_oversized_index_list_rejects():
    text = json.dumps([{"pattern": list(range(MAX_PATTERN_LEN + 1))}])
    with pytest.raises(SpatterParseError) as ei:
        parse_spatter(text)
    assert ei.value.reason == "oversized"


def test_unreadable_file_rejects_typed():
    with pytest.raises(SpatterParseError) as ei:
        load_spatter(FIXTURES / "does_not_exist.json")
    assert ei.value.reason == "bad_entry"


# ---------------------------------------------------------------------------
# property tests: parse -> replay equals a direct replay of the JSON
# ---------------------------------------------------------------------------

@st.composite
def spatter_entry(draw):
    """A random Spatter JSON entry plus the independently-computed
    expected index period."""
    form = draw(st.sampled_from(["uniform", "ms1", "index"]))
    kernel = draw(st.sampled_from(["Gather", "Scatter"]))
    entry: dict = {"kernel": kernel}
    if form == "uniform":
        L = draw(st.integers(1, 12))
        stride = draw(st.integers(0, 9))
        entry["pattern"] = f"UNIFORM:{L}:{stride}"
        expect = [j * stride for j in range(L)]
        default_delta = (expect[-1] - expect[0]
                         + (stride if L > 1 else 1))
    elif form == "ms1":
        L = draw(st.integers(2, 16))
        breaks = sorted({draw(st.integers(1, L - 1))
                         for _ in range(draw(st.integers(1, 3)))})
        gaps = [draw(st.integers(1, 64)) for _ in breaks]
        entry["pattern"] = (f"MS1:{L}:{','.join(map(str, breaks))}:"
                            f"{','.join(map(str, gaps))}")
        gap_at = dict(zip(breaks, gaps))
        expect = [0]
        for j in range(1, L):
            expect.append(expect[-1] + gap_at.get(j, 1))
        default_delta = max(expect) + 1
    else:
        expect = [draw(st.integers(0, 500))
                  for _ in range(draw(st.integers(1, 24)))]
        entry["pattern"] = list(expect)
        default_delta = max(expect) + 1
    if draw(st.booleans()):
        entry["delta"] = draw(st.integers(0, 512))
        delta = entry["delta"]
    else:
        delta = default_delta
    return entry, expect, delta


@settings(max_examples=40, deadline=None)
@given(spatter_entry(), st.sampled_from([17, 64, 256]))
def test_parsed_replay_matches_direct_numpy_replay(case, n):
    entry, expect, delta = case
    sp = parse_spatter(json.dumps([entry]), source="prop")[0]
    assert sp.indices == tuple(expect)
    assert sp.delta == delta
    # index-trace equality: the module's replay against an independent
    # vectorized reconstruction from the raw JSON fields
    idx = np.asarray(expect, dtype=np.int64)
    k = np.arange(n, dtype=np.int64)
    direct = (idx[k % len(idx)] + delta * (k // len(idx))) % n
    assert np.array_equal(sp.replay_indices(n), direct)
    # byte-count equality: the spec accounts exactly the bytes one
    # sweep of the replay moves (affine: payload read+write; bound
    # index: index read + payload read + write, 4 B each)
    spec = sp.pattern_spec()
    env = {"n": n}
    pts = spec.domain.point_count(env)
    assert pts == n
    bpp = spec.bytes_per_point()
    assert bpp == (8 if sp.affine_stride is not None else 12)
    assert bpp * pts == bpp * n
    # and the moved payload is bit-identical to the direct replay
    assert replay_exact(sp, n=n)


@settings(max_examples=20, deadline=None)
@given(spatter_entry())
def test_pattern_hash_tracks_semantics_not_source(case):
    entry, _expect, _delta = case
    a = parse_spatter(json.dumps([entry]), source="fileA")[0]
    b = parse_spatter(json.dumps([entry]), source="fileB")[0]
    assert a.pattern_hash == b.pattern_hash
    assert a.trace_stamp["source"] != b.trace_stamp["source"]
    flipped = dict(entry)
    flipped["kernel"] = ("Scatter" if a.kernel == "gather" else "Gather")
    c = parse_spatter(json.dumps([flipped]), source="fileA")[0]
    assert c.pattern_hash != a.pattern_hash


# ---------------------------------------------------------------------------
# trace provenance on records and in fingerprints
# ---------------------------------------------------------------------------

def test_records_carry_trace_provenance():
    sp = load_spatter(FIXTURES / "ms1.json")[0]
    d = Driver(lambda env: sp.pattern_spec(),
               DriverConfig(template="unified", programs=1, ntimes=2,
                            reps=1, validate_n=64),
               cache=TranslationCache())
    (rec,) = d.run([256])
    assert rec.extra["trace"] == sp.trace_stamp
    assert rec.extra["trace"]["form"] == "ms1"
    assert rec.extra["trace"]["pattern_hash"] == sp.pattern_hash


def test_fingerprint_distinguishes_trace_and_is_rebuild_stable():
    pats = load_spatter(FIXTURES / "ms1.json")
    f0 = fingerprint_pattern(pats[0].pattern_spec())
    f0b = fingerprint_pattern(
        load_spatter(FIXTURES / "ms1.json")[0].pattern_spec())
    assert f0 == f0b                     # journal/cache identity holds
    assert f0 != fingerprint_pattern(pats[1].pattern_spec())
    # same structure, different provenance -> different fingerprint
    spec = pats[0].pattern_spec()
    moved = dataclasses.replace(
        spec, trace={**spec.trace, "source": "elsewhere.json"})
    assert fingerprint_pattern(moved) != f0


def test_trace_workload_runs_fixture_through_sweep_engine():
    from repro.suite.runner import collect_records

    w = trace_workload(FIXTURES / "ms1.json", name="trace_test_ms1")
    recs = collect_records(w, quick=True)
    assert len(recs) == 2 * 2            # 2 patterns x 2 quick env points
    for lbl, rec in recs:
        assert lbl.startswith("trace/")
        assert rec.extra["trace"]["form"] == "ms1"
        assert rec.gbs > 0


# ---------------------------------------------------------------------------
# multi-pattern mixes: composition, accounting, validation
# ---------------------------------------------------------------------------

def _demo_mix(n=256, gn=64):
    return mix_patterns(
        (("triad", triad(), {"n": n}), ("gather", gather(stride=8), {"n": gn})),
        name="mixdemo")


def test_mix_metadata_accounts_component_bytes():
    m = _demo_mix()
    assert m.mix["primary"] == "triad"
    comps = {c["label"]: c for c in m.mix["components"]}
    assert comps["triad"]["points"] == 256
    assert comps["triad"]["bytes"] == 256 * triad().bytes_per_point()
    assert comps["gather"]["bytes"] == 64 * gather(stride=8).bytes_per_point()
    assert sum(c["fraction"] for c in m.mix["components"]) == pytest.approx(1)
    # component spaces are namespaced and disjoint
    names = {s.name for s in m.spaces}
    assert mix_space(0, "A") in names and mix_space(1, "D") in names


def test_mix_records_carry_per_pattern_byte_split():
    d = Driver(lambda env: _demo_mix(env["n"], env["n"] // 4),
               DriverConfig(template="unified", programs=1, ntimes=2,
                            reps=1, validate_n=64),
               cache=TranslationCache())
    (rec,) = d.run([{"n": 256}])
    mix = rec.extra["mix"]
    assert mix["primary"] == "triad"
    assert len(mix["components"]) == 2
    assert all(c["bytes"] > 0 for c in mix["components"])
    total = sum(c["bytes"] for c in mix["components"]) * rec.ntimes
    assert rec.gbs * rec.seconds * 1e9 == pytest.approx(total)


def test_mix_validates_every_component_against_its_oracle():
    # includes a custom-kernel component: the chase's own oracle replays
    # inside the mix oracle
    m = mix_patterns(
        (("triad", triad(), {"n": 128}),
         ("chase", pointer_chase(), {"n": 64})),
        name="mix_with_kernel")
    d = Driver(lambda env: m,
               DriverConfig(template="unified", programs=1, ntimes=2,
                            reps=1, validate_n=64),
               cache=TranslationCache())
    d.validate({"n": 128})               # raises ValidateFailure on drift


def test_mix_fingerprint_stable_across_rebuilds_and_ratio_sensitive():
    f1 = fingerprint_pattern(_demo_mix())
    f2 = fingerprint_pattern(_demo_mix())
    assert f1 == f2
    assert f1 != fingerprint_pattern(_demo_mix(gn=128))


def test_mix_rejects_bad_compositions():
    with pytest.raises(ValueError, match="at least one"):
        mix_patterns(())
    with pytest.raises(ValueError, match="duplicate"):
        mix_patterns((("a", triad(), {"n": 64}), ("a", triad(), {"n": 64})))
    with pytest.raises(ValueError, match="primary"):
        mix_patterns((("a", triad(), {"n": 64}),), primary="b")
    tri = dataclasses.replace(
        triad(), domain=domain(("i", 0, "n"), ("j", 0, Affine.of("i"))))
    with pytest.raises(ValueError, match="rectangular"):
        mix_patterns((("tri", tri, {"n": 64}),))


def test_contended_workload_isolated_vs_loaded_split():
    from repro.suite import load_builtins, workload
    from repro.suite.runner import collect_records

    load_builtins()
    recs = collect_records(workload("mess_contended"), quick=True)
    parts = {len(r.extra["mix"]["components"]) for _, r in recs}
    assert parts == {1, 2}               # isolated baseline + contended
    for _, r in recs:
        for c in r.extra["mix"]["components"]:
            assert c["bytes"] > 0
