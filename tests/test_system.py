"""End-to-end system tests: real training runs, distributed execution in a
subprocess (8 fake host devices), fault-tolerant loop with elastic
resharding of a real model state."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import Shape, get_config
from repro.data.pipeline import make_batch_fn
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import lm
from repro.optim import adamw, cosine_schedule, error_feedback

KEY = jax.random.PRNGKey(0)


def test_training_reduces_loss_tiny_lm():
    """A tiny reduced LM memorizes one repeated synthetic batch."""
    cfg = get_config("internlm2-1.8b").reduced()
    p = lm.init_params(KEY, cfg, dtype=jnp.float32)
    opt = adamw(3e-3)
    step = jax.jit(make_train_step(cfg, None, opt), donate_argnums=0)
    state = {"params": p, "opt": opt.init(p)}
    shape = Shape("t", 64, 4, "train")
    fn = make_batch_fn(cfg, shape, seed=7)
    fixed = {k: jnp.asarray(v) for k, v in fn(0).items()}  # memorize one batch
    losses = []
    for _ in range(40):
        state, m = step(state, fixed)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::8]


def test_grad_compression_trains():
    cfg = get_config("internlm2-1.8b").reduced()
    p = lm.init_params(KEY, cfg, dtype=jnp.float32)
    opt = error_feedback(adamw(3e-3))
    step = jax.jit(make_train_step(cfg, None, opt), donate_argnums=0)
    state = {"params": p, "opt": opt.init(p)}
    shape = Shape("t", 64, 4, "train")
    fixed = {k: jnp.asarray(v)
             for k, v in make_batch_fn(cfg, shape, seed=7)(0).items()}
    losses = []
    for _ in range(30):
        state, m = step(state, fixed)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_prefill_then_decode_pipeline():
    cfg = get_config("internlm2-1.8b").reduced()
    p = lm.init_params(KEY, cfg)
    prefill = jax.jit(make_prefill_step(cfg, None))
    decode = jax.jit(make_serve_step(cfg, None))
    B, P, G = 2, 16, 6
    cache = lm.init_cache(cfg, B, P + G)
    toks = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    tok, cache = prefill(p, cache, {"tokens": toks})
    outs = [tok]
    for _ in range(G - 1):
        tok, cache = decode(p, cache, {"tokens": tok})
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    assert gen.shape == (B, G)
    assert int(cache["len"]) == P + G - 1
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab_size)))


DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp
    sys.path.insert(0, "src")
    from repro.config import Shape, get_config
    from repro.data.pipeline import make_batch_fn
    from repro.launch.steps import make_train_step
    from repro.models import lm
    from repro.models.moe import Parallelism
    from repro.optim import adamw
    from repro.runtime.sharding import batch_specs, param_specs, shardings

    cfg = get_config(sys.argv[1]).reduced()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    par = Parallelism(mesh=mesh, dp_axes=("data",), tp_axis="model")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    opt = adamw(3e-3)
    state = {"params": params, "opt": opt.init(params)}
    sds = jax.eval_shape(lambda: state)
    sspec = {"params": param_specs(sds["params"], par),
             "opt": param_specs(sds["opt"], par)}
    sshard = shardings(sspec, mesh)
    shape = Shape("t", 64, 8, "train")
    batch = {k: jnp.asarray(v) for k, v in make_batch_fn(cfg, shape, 7)(0).items()}
    bshard = shardings(batch_specs(jax.eval_shape(lambda: batch), par), mesh)
    step = jax.jit(make_train_step(cfg, par, opt, num_microbatches=2,
                                   grad_shardings=sshard["params"]),
                   in_shardings=(sshard, bshard), out_shardings=(sshard, None),
                   donate_argnums=0)
    state = jax.device_put(state, sshard)
    batch = jax.device_put(batch, bshard)
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    print(json.dumps({"first": losses[0], "last": losses[-1]}))
""")


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "deepseek-v2-lite-16b"])
def test_distributed_train_subprocess(arch):
    """Real sharded training on an 8-device (4x2) host mesh, including the
    shard_map MoE path, run in a subprocess so this process keeps 1 device."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", DIST_SCRIPT, arch],
        capture_output=True, text=True, timeout=900, cwd=os.getcwd(), env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert np.isfinite(res["first"]) and np.isfinite(res["last"])
    assert res["last"] < res["first"]


def test_ft_loop_with_real_model_and_reshard(tmp_path):
    """Fault-tolerant loop drives a real reduced model; elastic resize
    round-trips the state through a checkpoint restore."""
    from repro.runtime.fault_tolerance import FTConfig, FaultTolerantLoop

    cfg = get_config("internlm2-1.8b").reduced()
    p = lm.init_params(KEY, cfg, dtype=jnp.float32)
    opt = adamw(1e-3)
    step = jax.jit(make_train_step(cfg, None, opt), donate_argnums=0)
    state = {"params": p, "opt": opt.init(p)}
    shape = Shape("t", 32, 2, "train")
    fn = make_batch_fn(cfg, shape, seed=3)

    def batches():
        s = 0
        while True:
            yield s, {k: jnp.asarray(v) for k, v in fn(s).items()}
            s += 1

    resized = []

    def resize_hook(st):
        # simulate topology change: round-trip through host arrays
        resized.append(True)
        return jax.tree.map(lambda a: jnp.asarray(np.asarray(a)), st)

    faults = {2: "transient", 4: "resize"}
    loop = FaultTolerantLoop(
        step, state, FTConfig(str(tmp_path), ckpt_every=3),
        failure_hook=lambda s: faults.get(s), resize_hook=resize_hook)
    out = loop.run(batches(), 6)
    assert out["final_step"] == 6
    assert resized
    kinds = [e for _, e in out["events"]]
    assert any("retry" in k for k in kinds) and "resized" in kinds
