"""Paper Fig. 16 — spatial tile-size sweep for Jacobi 3D.

The paper sweeps 2D (partial) blocking tiles 16..64 and finds no win on
large-cache CPUs. The TPU adaptation sweeps the (bj, bk) output-tile
shape of the blocked Pallas kernel AND compares the xyz-blocked kernel
against the streaming (partial-block) kernel, whose halo traffic model is
derived in kernels/stencil.py. Derived column = achieved GB/s (CPU
interpret numbers; the structural result — streaming >= xyz at equal
tiles, driven by halo re-reads — is substrate-independent).

Staged pipeline: every (kernel, tile) variant is lowered serially
(tracing is GIL-bound) and AOT-compiled concurrently (XLA releases the
GIL), then timing runs against the pre-compiled executables only —
translation cost never pollutes the measured numbers and is reported as
a comment line instead.
"""
import time

import jax
import jax.numpy as jnp

from repro.core.measure import time_fn
from repro.core.staging import pipeline_compile
from repro.kernels import ops
from repro.suite import Workload, emit, register, run_module


def _tile_sweep(quick: bool = True) -> list[str]:
    out = []
    n = 34 if quick else 66
    x = jax.random.normal(jax.random.PRNGKey(0), (n, n, n), jnp.float32)
    interior = (n - 2) ** 3
    bytes_moved = 2 * interior * 4
    tiles = [8, 16, 32] if quick else [8, 16, 32, 64]

    # stages 1+2, overlapped: lower each variant on the main thread
    # (tracing is GIL-bound) while finished lowerings compile on worker
    # threads (XLA releases the GIL), so translation wall-time is
    # ~max(lower, compile) instead of their sum.
    t0 = time.perf_counter()
    variants = []
    for bj in tiles:
        for bk in tiles:
            if (n - 2) % bj or (n - 2) % bk:
                continue
            variants.append((f"fig16/stream/b{bj}x{bk}",
                             lambda bj=bj, bk=bk: ops.jacobi3d_streaming.lower(
                                 x, block=(bj, bk))))
            variants.append((f"fig16/xyz/b8x{bj}x{bk}",
                             lambda bj=bj, bk=bk: ops.jacobi3d.lower(
                                 x, block=(8, bj, bk))))
    compiled = pipeline_compile([lower for _, lower in variants])
    translate_s = time.perf_counter() - t0

    # stage 3: execute + time the pre-compiled executables
    for (label, _), exe in zip(variants, compiled):
        t = time_fn(exe, x, reps=2, warmup=1)
        out.append(f"{label},{t.seconds*1e6:.2f},"
                   f"{bytes_moved/t.seconds/1e9:.3f}GB/s")
    print(f"# fig16 staged: {len(variants)} variants, "
          f"lower+compile {translate_s:.2f}s (overlapped)", flush=True)
    return emit(out)


# Fully custom experiment (dedicated Pallas kernels, not the driver
# templates): registers a ``runner`` and shares the registry surface.
register(Workload(
    name="fig16_tile_sweep",
    figure="fig16",
    title="spatial tile-size sweep for the blocked Jacobi-3D kernels",
    tags=("paper-figs",),
    runner=_tile_sweep,
))


def run(quick: bool = True) -> list[str]:
    return run_module("fig16_tile_sweep", quick)
