"""Paper Fig. 16 — spatial tile-size sweep for Jacobi 3D.

The paper sweeps 2D (partial) blocking tiles 16..64 and finds no win on
large-cache CPUs. The TPU adaptation sweeps the (bj, bk) output-tile
shape of the blocked Pallas kernel AND compares the xyz-blocked kernel
against the streaming (partial-block) kernel, whose halo traffic model is
derived in kernels/stencil.py. Derived column = achieved GB/s (CPU
interpret numbers; the structural result — streaming >= xyz at equal
tiles, driven by halo re-reads — is substrate-independent).
"""
import jax
import jax.numpy as jnp

from repro.core.measure import time_fn
from repro.kernels import ops

from .common import emit


def run(quick: bool = True) -> list[str]:
    out = []
    n = 34 if quick else 66
    x = jax.random.normal(jax.random.PRNGKey(0), (n, n, n), jnp.float32)
    interior = (n - 2) ** 3
    bytes_moved = 2 * interior * 4
    tiles = [8, 16, 32] if quick else [8, 16, 32, 64]
    for bj in tiles:
        for bk in tiles:
            if (n - 2) % bj or (n - 2) % bk:
                continue
            t = time_fn(lambda bj=bj, bk=bk: ops.jacobi3d_streaming(
                x, block=(bj, bk)), reps=2)
            out.append(f"fig16/stream/b{bj}x{bk},{t.seconds*1e6:.2f},"
                       f"{bytes_moved/t.seconds/1e9:.3f}GB/s")
            t2 = time_fn(lambda bj=bj, bk=bk: ops.jacobi3d(
                x, block=(8, bj, bk)), reps=2)
            out.append(f"fig16/xyz/b8x{bj}x{bk},{t2.seconds*1e6:.2f},"
                       f"{bytes_moved/t2.seconds/1e9:.3f}GB/s")
    return emit(out)
