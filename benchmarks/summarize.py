"""Build the EXPERIMENTS.md §Dry-run table + comparisons vs the v0 baseline.

    PYTHONPATH=src python -m benchmarks.summarize [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]


def load(dirname: str) -> dict[tuple, dict]:
    out = {}
    for f in sorted((ROOT / dirname).glob("*.json")):
        d = json.loads(f.read_text())
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def fmt_gib(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(cells: dict) -> str:
    md = ["| arch | shape | mesh | status | live GiB | fits 16G | "
          "collective GB/step | HLO flops/dev | mb |",
          "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), d in sorted(cells.items()):
        if "skipped" in d:
            md.append(f"| {a} | {s} | {m} | SKIP (full-attn 512k) | – | – | – | – | – |")
            continue
        if "error" in d:
            md.append(f"| {a} | {s} | {m} | **FAIL** | – | – | – | – | – |")
            continue
        mem = d["memory"]
        md.append(
            f"| {a} | {s} | {m} | OK | {fmt_gib(mem['live_bytes'])} | "
            f"{'yes' if mem['fits_16g'] else 'NO'} | "
            f"{d['collectives']['total_bytes']/1e9:.1f} | "
            f"{d['cost']['flops']:.2e} | {d.get('microbatches','–')} |"
        )
    return "\n".join(md)


def compare(before: dict, after: dict) -> str:
    md = ["| cell | live GiB before→after | collective GB before→after |",
          "|---|---|---|"]
    for key in sorted(after):
        b, a = before.get(key), after[key]
        if not b or "memory" not in b or "memory" not in a:
            continue
        lb, la = b["memory"]["live_bytes"], a["memory"]["live_bytes"]
        cb, ca = (b["collectives"]["total_bytes"],
                  a["collectives"]["total_bytes"])
        if abs(lb - la) / max(lb, 1) < 0.05 and abs(cb - ca) / max(cb, 1) < 0.05:
            continue
        md.append(
            f"| {key[0]}/{key[1]}/{key[2]} | {fmt_gib(lb)}→{fmt_gib(la)} | "
            f"{cb/1e9:.1f}→{ca/1e9:.1f} |"
        )
    return "\n".join(md)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--baseline", default="experiments/dryrun_v0_baseline")
    args = ap.parse_args()
    cells = load(args.dir)
    print("## Dry-run table\n")
    print(dryrun_table(cells))
    base_dir = ROOT / args.baseline
    if base_dir.exists():
        print("\n## Changes vs v0 baseline\n")
        print(compare(load(args.baseline), cells))
    ok = sum(1 for d in cells.values()
             if "skipped" not in d and "error" not in d)
    fit = sum(1 for d in cells.values()
              if d.get("memory", {}).get("fits_16g"))
    skip = sum(1 for d in cells.values() if "skipped" in d)
    fail = sum(1 for d in cells.values() if "error" in d)
    print(f"\ncells: {len(cells)} | ok: {ok} | skip: {skip} | fail: {fail} "
          f"| fits-16GiB: {fit}/{ok}")


if __name__ == "__main__":
    main()
