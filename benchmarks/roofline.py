"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Three terms per cell, all in seconds (v5e constants):

    compute    = MODEL_FLOPS / (chips x 197e12 bf16 FLOP/s)
    memory     = step_bytes  / (chips x 819e9  B/s HBM)
    collective = collective_bytes_per_device / 50e9 B/s per ICI link
                 (DCN-crossing kinds reported separately)

MODEL_FLOPS and step bytes come from benchmarks.model_math (closed form —
compiled cost_analysis counts scan bodies once and is reported only as a
cross-check); collective bytes come from the trip-corrected HLO parse
stored in the dry-run JSONs (already per-device).

Output: experiments/roofline.csv + a markdown table for EXPERIMENTS.md,
with the dominant term, MODEL_FLOPS/HLO_FLOPS utilization ratio, and a
one-line "what would move the dominant term" note per cell.
"""
from __future__ import annotations

import json
import pathlib

from repro.config import SHAPES, get_config
from repro.suite import Workload, register, run_module

from .model_math import step_flops

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN_DIR = ROOT / "experiments" / "dryrun"


def cell_roofline(rec: dict) -> dict | None:
    if "skipped" in rec or "error" in rec:
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    cost = step_flops(cfg, shape)

    t_compute = cost.flops / (chips * PEAK_FLOPS)
    t_memory = cost.total_bytes / (chips * HBM_BW)
    coll_dev = rec["collectives"]["total_bytes"]
    t_coll = coll_dev / ICI_BW

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    hlo_flops_dev = rec["cost"]["flops"]
    util = cost.flops / chips / max(hlo_flops_dev, 1.0)

    hints = {
        "compute": "raise per-chip matmul efficiency: larger microbatch "
                   "tiles, skip masked-out causal KV chunks",
        "memory": "cut bytes: bounded window caches, bf16 collectives, "
                  "fewer f32 temporaries in attention",
        "collective": "cut collective bytes: sequence-parallel norms, "
                      "reduce-scatter grads (ZeRO-2), bf16 psums, "
                      "fewer microbatches",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": cost.flops,
        "hlo_flops_per_dev": hlo_flops_dev,
        "model_over_hlo": util,
        "live_gib": rec.get("memory", {}).get("live_bytes", 0) / 2 ** 30,
        "fits_16g": rec.get("memory", {}).get("fits_16g"),
        "bound_frac": terms[dominant] / max(sum(terms.values()), 1e-30),
        "hint": hints[dominant],
    }


def load_all() -> list[dict]:
    rows = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        row = cell_roofline(rec)
        if row:
            rows.append(row)
    return rows


def _roofline(quick: bool = True) -> list[str]:
    rows = load_all()
    out = []
    csv_path = ROOT / "experiments" / "roofline.csv"
    hdr = ("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
           "dominant,model_over_hlo,live_gib,fits_16g")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['t_compute_s']:.4e},"
            f"{r['t_memory_s']:.4e},{r['t_collective_s']:.4e},"
            f"{r['dominant']},{r['model_over_hlo']:.2f},"
            f"{r['live_gib']:.2f},{r['fits_16g']}"
        )
        dom_t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        out.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{dom_t*1e6:.1f},{r['dominant']}"
        )
    csv_path.parent.mkdir(exist_ok=True)
    csv_path.write_text("\n".join(lines) + "\n")
    for ln in out:
        print(ln, flush=True)
    print(f"# wrote {csv_path} ({len(rows)} cells)", flush=True)
    return out


register(Workload(
    name="roofline",
    figure="roofline",
    title="roofline refresh from the dry-run artifacts",
    tags=("paper-figs",),
    runner=_roofline,
))


def run(quick: bool = True) -> list[str]:
    return run_module("roofline", quick)


def markdown_table() -> str:
    rows = load_all()
    md = ["| arch | shape | mesh | compute s | memory s | collective s | "
          "dominant | model/HLO | live GiB |",
          "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['model_over_hlo']:.2f} | {r['live_gib']:.2f} |"
        )
    return "\n".join(md)


if __name__ == "__main__":
    run()
