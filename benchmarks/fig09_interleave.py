"""Paper Fig. 9 — the interleaved-triad optimization.

Registry entry: the schedule-engine variants plus the dedicated Pallas
kernel timings (a ``post`` hook) are declared in
``repro.suite.catalog`` and executed by the shared suite runner.
"""
from repro.suite import run_module


def run(quick: bool = True) -> list[str]:
    return run_module("fig09_interleave", quick)
