"""Paper Fig. 9 — the interleaved-triad optimization.

Splitting each array into f blocks accessed simultaneously (Listing 7)
doubles the concurrent stream count. Two reproductions: (a) the schedule
transformation through the polyhedral engine (jax backend), and (b) the
blocked Pallas kernel where interleaving is a (factor, n/factor) layout
view — plus per-call timing of the dedicated kernels.
"""
import jax
import jax.numpy as jnp

from repro.core import Driver, DriverConfig, identity, triad
from repro.core.measure import time_fn
from repro.kernels import ops

from .common import csv_line, emit, sets


def run(quick: bool = True) -> list[str]:
    out = []
    for factor in (1, 2, 4):
        sch = identity() if factor == 1 else identity().interleave("i", factor)
        d = Driver(lambda env: triad(),
                   DriverConfig(template="independent", programs=2,
                                ntimes=16, reps=2, schedule=sch))
        d.validate()
        for rec in d.run(sets(quick)):
            out.append(csv_line(f"fig09/engine/il{factor}/n{rec.n}", rec))
    # dedicated pallas kernels
    n = 1 << 16
    key = jax.random.PRNGKey(0)
    b = jax.random.normal(key, (n,), jnp.float32)
    c = jax.random.normal(key, (n,), jnp.float32)
    bytes_moved = 3 * n * 4
    t = time_fn(lambda: ops.triad(b, c, block=4096), reps=3)
    out.append(f"fig09/kernel/naive,{t.seconds*1e6:.2f},"
               f"{bytes_moved/t.seconds/1e9:.3f}GB/s")
    for f in (2, 4):
        t = time_fn(lambda f=f: ops.triad_interleaved(b, c, factor=f,
                                                      block=2048), reps=3)
        out.append(f"fig09/kernel/il{f},{t.seconds*1e6:.2f},"
                   f"{bytes_moved/t.seconds/1e9:.3f}GB/s")
    return emit(out)
