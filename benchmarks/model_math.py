"""Analytic FLOP/byte models per (arch x shape) — the roofline numerators.

``cost_analysis()`` counts while-loop (scan) bodies once, so compiled
numbers undercount layer-stacked work; these closed-form counts are the
whole-step ground truth the roofline uses (the HLO-derived values are
reported alongside as a cross-check; see launch/hlo_analysis.py for the
trip-corrected collective counts).

Conventions: matmul flops = 2*m*n*k; backward = 2x forward; attention
counts q@k and p@v (causal factor 1/2 applied; the implementation
currently computes masked full scores, so an `impl_factor` of 2 on the
attention term is reported separately as MODEL/HLO waste).
"""
from __future__ import annotations

import dataclasses

from repro.config import ArchConfig, Shape

__all__ = ["step_flops", "active_params", "StepCost"]


@dataclasses.dataclass
class StepCost:
    flops: float               # whole-step model flops (global, fwd[+bwd])
    weight_bytes: float        # param bytes read per step (global)
    act_bytes: float           # activation/cache bytes moved (global, approx)

    @property
    def total_bytes(self) -> float:
        return self.weight_bytes + self.act_bytes


def active_params(cfg: ArchConfig) -> float:
    """Params touched per token (MoE: shared + top_k routed only)."""
    from repro.runtime.sharding import param_count

    total = param_count(cfg)
    if not cfg.moe:
        return float(total)
    moe = cfg.moe
    expert_p = 3 * cfg.d_model * moe.d_ff_expert
    n_moe_layers = cfg.n_layers - moe.first_k_dense
    routed_total = n_moe_layers * moe.n_routed * expert_p
    routed_active = n_moe_layers * moe.top_k * expert_p
    return float(total - routed_total + routed_active)


def _attn_flops(cfg: ArchConfig, B: int, Sq: int, Skv: int,
                causal: bool) -> float:
    """q@k + p@v flops for one layer (global, forward)."""
    if cfg.mla:
        hd_qk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        hd_v = cfg.mla.v_head_dim
    else:
        hd_qk = hd_v = cfg.head_dim
    eff = 0.5 if (causal and Sq == Skv) else 1.0
    return 2.0 * B * Sq * Skv * cfg.n_heads * (hd_qk + hd_v) * eff


def _layer_seq_flops(cfg: ArchConfig, B: int, Sq: int, Skv: int,
                     causal: bool) -> float:
    """Per-layer attention-like sequence-mixing flops (global, forward)."""
    if cfg.ssm is not None and cfg.family == "ssm":
        # mLSTM chunked: intra-chunk (Sq*chunk) + state path
        ch = cfg.ssm.chunk if Sq > 1 else 1
        N = cfg.ssm.head_dim
        H = cfg.n_heads
        return 2.0 * B * Sq * ch * H * N + 4.0 * B * Sq * H * N * N / max(ch, 1)
    if cfg.ssm is not None and cfg.family == "hybrid":
        ch = cfg.ssm.chunk if Sq > 1 else 1
        d_in = cfg.ssm.expand * cfg.d_model
        N = cfg.ssm.d_state
        intra = 2.0 * B * Sq * ch * (d_in + 2 * N)
        return intra
    win = cfg.window
    if win and cfg.global_every:
        # gemma3: 5/6 layers windowed, 1/6 global — average
        loc = _attn_flops(cfg, B, Sq, min(Skv, win), causal=False)
        glo = _attn_flops(cfg, B, Sq, Skv, causal)
        k = cfg.global_every
        return ((k - 1) * loc + glo) / k
    if win and cfg.family == "hybrid":
        return _attn_flops(cfg, B, Sq, min(Skv, win), causal=False)
    return _attn_flops(cfg, B, Sq, Skv, causal)


def step_flops(cfg: ArchConfig, shape: Shape) -> StepCost:
    B, S = shape.global_batch, shape.seq_len
    n_active = active_params(cfg)
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_mat = n_active - emb              # matmul-participating params

    if shape.kind == "train":
        tokens = B * S
        dense = 6.0 * n_mat * tokens + 6.0 * tokens * cfg.d_model * cfg.vocab_size
        attn = 3.0 * cfg.n_layers * _layer_seq_flops(cfg, B, S, S, True)
        flops = dense + attn
        weight_bytes = 2.0 * n_active * 3  # fwd + bwd reread + optimizer
        act_bytes = tokens * cfg.d_model * 2.0 * cfg.n_layers * 4
        return StepCost(flops, weight_bytes, act_bytes)

    if shape.kind == "prefill":
        tokens = B * S
        dense = 2.0 * n_mat * tokens
        attn = cfg.n_layers * _layer_seq_flops(cfg, B, S, S, True)
        flops = dense + attn + 2.0 * B * cfg.d_model * cfg.vocab_size
        cache_entry = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                       if cfg.mla else 2 * cfg.n_kv_heads * cfg.head_dim)
        act_bytes = tokens * (cfg.d_model * 2.0 * cfg.n_layers
                              + cache_entry * 2.0 * cfg.n_layers)
        return StepCost(flops, 2.0 * n_active, act_bytes)

    # decode: one token per sequence against a cache of length S
    tokens = B
    dense = 2.0 * n_mat * tokens + 2.0 * B * cfg.d_model * cfg.vocab_size
    attn = cfg.n_layers * _layer_seq_flops(cfg, B, 1, S, False)
    flops = dense + attn
    cache_entry = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                   if cfg.mla else 2 * cfg.n_kv_heads * cfg.head_dim)
    if cfg.ssm is not None and cfg.family == "ssm":
        cache_bytes = 4.0 * B * cfg.n_layers * cfg.n_heads * cfg.ssm.head_dim ** 2
    elif cfg.ssm is not None:
        d_in = cfg.ssm.expand * cfg.d_model
        H = d_in // cfg.ssm.head_dim
        n_attn = cfg.n_layers // max(cfg.hybrid_attn_every, 1)
        cache_bytes = (4.0 * B * cfg.n_layers * H * cfg.ssm.d_state
                       * cfg.ssm.head_dim
                       + 2.0 * B * min(S, cfg.window or S) * n_attn
                       * 2 * cfg.n_kv_heads * cfg.head_dim)
    else:
        skv = S
        if cfg.window and cfg.global_every:
            k = cfg.global_every
            skv = ((k - 1) * min(S, cfg.window) + S) / k
        cache_bytes = B * skv * cache_entry * 2.0 * cfg.n_layers
    # decode reads all active weights + the whole cache once per token
    return StepCost(flops, 2.0 * n_active, cache_bytes)
