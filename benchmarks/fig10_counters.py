"""Paper Fig. 10 — counter-based false-sharing diagnosis.

Registry entry: the three Jacobi-1D layouts with measured counters are
declared in ``repro.suite.catalog`` and executed by the shared suite
runner (the counter columns come from a ``derived`` formatter).
"""
from repro.suite import run_module


def run(quick: bool = True) -> list[str]:
    return run_module("fig10_counters", quick)
