"""Paper Fig. 10 — counter-based false-sharing diagnosis.

PAPI L1 miss / exclusive-line-request counters become (a) the analytic
native-tile traffic model (exact for affine patterns) and (b) XLA
cost_analysis counters, reported for the three Jacobi-1D layouts:
unified, independent (unpadded rows), independent tile-padded.
"""
from repro.core import Driver, DriverConfig, jacobi1d
from repro.core.measure import NATIVE_TILE_BYTES

from .common import emit


def run(quick: bool = True) -> list[str]:
    out = []
    tile_elems = NATIVE_TILE_BYTES // 4
    n = (1 << 14) + 2
    variants = [
        ("unified", DriverConfig(template="unified", programs=4, ntimes=4,
                                 reps=1, measured=True)),
        ("indep_unpadded", DriverConfig(template="independent", programs=4,
                                        ntimes=4, reps=1, measured=True)),
        ("indep_padded", DriverConfig(template="independent", programs=4,
                                      ntimes=4, reps=1, pad=tile_elems,
                                      measured=True)),
    ]
    for name, cfg in variants:
        d = Driver(lambda env: jacobi1d(), cfg)
        rec = d.run([n])[0]
        shared = rec.extra.get("shared_write_tiles", -1)
        fetches = rec.extra.get("fetches", -1)
        out.append(
            f"fig10/{name}/n{n},{rec.seconds*1e6:.2f},"
            f"shared_tiles={shared};fetches={fetches};gbs={rec.gbs:.3f}")
    return emit(out)
