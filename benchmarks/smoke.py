"""CI smoke entrypoint: one tiny config per registered workload + ledger.

    PYTHONPATH=src python -m benchmarks.smoke [--out BENCH_PR2.json]

Thin alias for ``benchmarks.run --smoke``: runs the quick-mode ladder of
every registry workload and writes per-workload wall time plus the
translation-cache hit rate (in-process and jax disk cache) to the JSON
ledger, so future PRs can assert the harness's perf trajectory instead
of guessing.
"""
from __future__ import annotations

import sys

from .run import main


if __name__ == "__main__":
    main(["--smoke", *sys.argv[1:]])
