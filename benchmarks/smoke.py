"""CI smoke entrypoint: one tiny config per registered workload + ledger.

    PYTHONPATH=src python -m benchmarks.smoke [--out BENCH_PR9.json]

Thin alias for ``benchmarks.run --smoke``: runs the quick-mode plan of
every registry workload (including the multi-axis ``mess_load_sweep``,
``pointer_chase``, ``spatter_nonuniform``, and zip-mode
``mess_calibrated`` scenarios) and writes per-workload wall time, the
translation-cache hit rate / capacity / evictions (in-process and jax
disk cache), the structured ``failures`` section (fault-isolated: a
failing workload or plan point is recorded, the batch continues), the
``param_path`` probe — strided-parametric vs specialized per-call
cost with the 1-compile-per-ladder assertion and per-side
``timing_quality`` — and the ``pallas_probe`` — pallas-backend vs
jax-backend per-call cost on the same parametric ladders, stamped with
the platform-resolved execution mode — and the ``derived`` block —
per-workload provenance (source model, mined source op, feature
vector) of the application-derived workloads synthesized from the
models' compiled HLO (``repro.suite.derived``) — to the JSON ledger,
so future PRs can assert the harness's perf trajectory (the strided
regime's ≤ 1.5x comparability floor, the pallas backend's calibrated
overhead ceiling) instead of guessing. CI asserts ``failures`` is
empty on the clean run and that ≥2 derived workloads ran failure-free
with non-degenerate feature vectors.
"""
from __future__ import annotations

import sys

from .run import main


if __name__ == "__main__":
    main(["--smoke", *sys.argv[1:]])
