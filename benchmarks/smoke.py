"""CI smoke entrypoint: one tiny config per registered workload + ledger.

    PYTHONPATH=src python -m benchmarks.smoke [--out BENCH_PR6.json]

Thin alias for ``benchmarks.run --smoke``: runs the quick-mode plan of
every registry workload (including the multi-axis ``mess_load_sweep``,
``pointer_chase``, ``spatter_nonuniform``, and zip-mode
``mess_calibrated`` scenarios) and writes per-workload wall time, the
translation-cache hit rate / capacity / evictions (in-process and jax
disk cache), the structured ``failures`` section (fault-isolated: a
failing workload or plan point is recorded, the batch continues), and
the ``param_path`` probe — strided-parametric vs specialized per-call
cost with the 1-compile-per-ladder assertion and per-side
``timing_quality`` — to the JSON ledger, so future PRs can assert the
harness's perf trajectory (and the strided regime's ≤ 1.5x
comparability floor) instead of guessing. CI asserts ``failures`` is
empty on the clean run.
"""
from __future__ import annotations

import sys

from .run import main


if __name__ == "__main__":
    main(["--smoke", *sys.argv[1:]])
