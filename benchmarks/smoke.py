"""CI smoke entrypoint: one tiny config per figure module + perf ledger.

    PYTHONPATH=src python -m benchmarks.smoke [--out BENCH_PR1.json]

Thin alias for ``benchmarks.run --smoke``: runs the quick-mode ladder of
every figure module and writes per-module wall time plus the
translation-cache hit rate to the JSON ledger, so future PRs can assert
the harness's perf trajectory instead of guessing.
"""
from __future__ import annotations

import sys

from .run import main


if __name__ == "__main__":
    main(["--smoke", *sys.argv[1:]])
