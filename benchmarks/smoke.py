"""CI smoke entrypoint: one tiny config per registered workload + ledger.

    PYTHONPATH=src python -m benchmarks.smoke [--out BENCH_PR3.json]

Thin alias for ``benchmarks.run --smoke``: runs the quick-mode plan of
every registry workload (including the multi-axis ``mess_load_sweep``,
``pointer_chase``, and ``spatter_nonuniform`` scenarios) and writes
per-workload wall time plus the translation-cache hit rate, capacity,
and eviction count (in-process and jax disk cache) to the JSON ledger,
so future PRs can assert the harness's perf trajectory instead of
guessing.
"""
from __future__ import annotations

import sys

from .run import main


if __name__ == "__main__":
    main(["--smoke", *sys.argv[1:]])
