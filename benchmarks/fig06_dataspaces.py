"""Paper Fig. 6 — unified vs independent data spaces for triad.

Unified: one array, programs take schedule(static, n/t) chunks that share
native tiles at the seams. Independent: per-program tile-padded rows.
The paper sees ~2x in L1 for independent; here the analogue is the
tile-aligned layout avoiding shared-tile writebacks.
"""
from repro.core import Driver, DriverConfig, triad
from repro.core.measure import NATIVE_TILE_BYTES

from .common import csv_line, emit, sets


def run(quick: bool = True) -> list[str]:
    out = []
    tile_elems = NATIVE_TILE_BYTES // 4
    variants = [
        ("unified", DriverConfig(template="unified", programs=4,
                                 ntimes=16, reps=2)),
        ("independent", DriverConfig(template="independent", programs=4,
                                     ntimes=16, reps=2, pad=tile_elems)),
    ]
    for name, cfg in variants:
        d = Driver(lambda env: triad(), cfg)
        d.validate()
        for rec in d.run(sets(quick)):
            out.append(csv_line(f"fig06/{name}/n{rec.n}", rec))
    return emit(out)
