"""Paper Fig. 6 — unified vs independent data spaces for triad.

Registry entry: the layout contrast is declared in
``repro.suite.catalog`` and executed by the shared suite runner.
"""
from repro.suite import run_module


def run(quick: bool = True) -> list[str]:
    return run_module("fig06_dataspaces", quick)
