"""Paper Fig. 12 — Jacobi 1D under the three memory layouts.

Registry entry: declared in ``repro.suite.catalog`` over the interior
ladder (points run at n+2 so the interior divides the program count).
"""
from repro.suite import run_module


def run(quick: bool = True) -> list[str]:
    return run_module("fig12_jacobi1d", quick)
