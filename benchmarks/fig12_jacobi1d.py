"""Paper Fig. 12 — Jacobi 1D under the three memory layouts.

Unified (shared array, program-chunked), independent (per-program rows),
independent + tile padding (the paper's `A[t_id*8][i]` fix). Reported
across the working-set ladder.
"""
from repro.core import Driver, DriverConfig, jacobi1d
from repro.core.measure import NATIVE_TILE_BYTES

from .common import csv_line, emit, sets


def run(quick: bool = True) -> list[str]:
    out = []
    tile_elems = NATIVE_TILE_BYTES // 4
    variants = [
        ("unified", DriverConfig(template="unified", programs=4,
                                 ntimes=8, reps=2, validate_n=66)),
        ("independent", DriverConfig(template="independent", programs=4,
                                     ntimes=8, reps=2, validate_n=66)),
        ("indep_padded", DriverConfig(template="independent", programs=4,
                                      ntimes=8, reps=2, pad=tile_elems,
                                      validate_n=66)),
    ]
    for name, cfg in variants:
        d = Driver(lambda env: jacobi1d(), cfg)
        d.validate()
        # interior must divide by programs: use n = k*programs + 2
        for n in sets(quick):
            rec = d.run([n + 2])[0]
            out.append(csv_line(f"fig12/{name}/n{n}", rec))
    return emit(out)
