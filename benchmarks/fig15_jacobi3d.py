"""Paper Fig. 15 — Jacobi 3D (7-pt), unified vs independent layouts."""
from repro.core import Driver, DriverConfig, jacobi3d

from .common import csv_line, emit


def run(quick: bool = True) -> list[str]:
    out = []
    grids3 = [10, 18] if quick else [10, 18, 34, 66]
    variants = [
        ("unified", DriverConfig(template="unified", programs=4,
                                 ntimes=4, reps=2, validate_n=10)),
        ("independent", DriverConfig(template="independent", programs=4,
                                     ntimes=4, reps=2, validate_n=10)),
    ]
    for name, cfg in variants:
        d = Driver(lambda env: jacobi3d(), cfg)
        d.validate()
        for n in grids3:
            rec = d.run([n])[0]
            out.append(csv_line(f"fig15/{name}/n{n}", rec))
    return emit(out)
