"""Paper Fig. 15 — Jacobi 3D (7-pt), unified vs independent layouts.

Registry entry: declared in ``repro.suite.catalog``.
"""
from repro.suite import run_module


def run(quick: bool = True) -> list[str]:
    return run_module("fig15_jacobi3d", quick)
