"""Paper Fig. 7 — bandwidth vs number of concurrent data streams.

The paper sweeps 3..20 simultaneously-read arrays and finds the peak at
11 streams (prefetch-engine occupancy). The TPU analogue is concurrent
HBM->VMEM DMA streams = concurrent BlockSpec operands; we sweep the same
k with the nstream pattern.

All k-variants share one translation cache and are staged up front:
lowering happens serially (pure Python), the per-k XLA compiles overlap
on worker threads, and the measurement loop then runs entirely against
pre-compiled executables (``Driver.run`` hits the compile cache).
"""
from repro.core import Driver, DriverConfig, nstream
from repro.core.staging import GLOBAL_CACHE, precompile

from .common import csv_line, emit


def run(quick: bool = True) -> list[str]:
    out = []
    ks = [1, 2, 3, 5, 7, 11, 15, 20] if quick else list(range(1, 21))
    n = 1 << 14
    # drivers default to GLOBAL_CACHE so the --smoke ledger sees fig07's
    # translation activity; report this module's share as a delta
    s0 = GLOBAL_CACHE.stats()
    drivers = [
        (k, Driver(lambda env, k=k: nstream(k),
                   DriverConfig(template="independent", programs=4,
                                ntimes=8, reps=2)))
        for k in ks
    ]
    # stage every variant's executable before any timing starts
    precompile([
        (lambda d=d: d.prepare([n], parallel=False)) for _, d in drivers
    ])
    for k, d in drivers:
        rec = d.run([n])[0]
        out.append(csv_line(f"fig07/streams{k}/n{n}", rec))
    s1 = GLOBAL_CACHE.stats()
    print(f"# fig07 cache: {s1['compile_hits'] - s0['compile_hits']} compile "
          f"hits / {s1['compile_misses'] - s0['compile_misses']} misses",
          flush=True)
    return emit(out)
