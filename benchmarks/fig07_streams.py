"""Paper Fig. 7 — bandwidth vs number of concurrent data streams.

Registry entry: the k-stream sweep is declared in
``repro.suite.catalog`` (one variant per k, each with its own nstream
pattern) and executed by the shared suite runner.
"""
from repro.suite import run_module


def run(quick: bool = True) -> list[str]:
    return run_module("fig07_streams", quick)
