"""Paper Fig. 7 — bandwidth vs number of concurrent data streams.

The paper sweeps 3..20 simultaneously-read arrays and finds the peak at
11 streams (prefetch-engine occupancy). The TPU analogue is concurrent
HBM->VMEM DMA streams = concurrent BlockSpec operands; we sweep the same
k with the nstream pattern.
"""
from repro.core import Driver, DriverConfig, nstream

from .common import csv_line, emit


def run(quick: bool = True) -> list[str]:
    out = []
    ks = [1, 2, 3, 5, 7, 11, 15, 20] if quick else list(range(1, 21))
    n = 1 << 14
    for k in ks:
        d = Driver(lambda env, k=k: nstream(k),
                   DriverConfig(template="independent", programs=4,
                                ntimes=8, reps=2))
        rec = d.run([n])[0]
        out.append(csv_line(f"fig07/streams{k}/n{n}", rec))
    return emit(out)
